"""Distributed cluster-volume sweeps (virtual time).

Extends the volume benches one level up: N member volumes behind
simulated network links, chunk chains placed by the real
``repro.cluster.placement.PlacementPolicy``.

  --table pipeline   pipelined chain replication vs serial client-fanout
                     at 4 nodes / K=2 (acceptance: >= 1.5x ops/s), plus
                     the single-node unreplicated reference (CI floor:
                     pipelined K=2 >= 0.6x of it — replication tax
                     bounded)
  --table scaling    nodes x K sweep, pipelined ops/s per configuration
  --table placement  ring vs spread vs balanced: rack diversity and
                     placement balance under the same workload
  --table kill       node death mid-workload: re-replication storm span
                     and regenerated block count at each K

Primary engine: ``repro.core.sim.run_cluster_sim_workload``
(deterministic virtual time; same cost model as every other table).
"""
from __future__ import annotations

import argparse
import json

from repro.core.sim import run_cluster_sim_workload

N_LBAS = 1 << 16
CHUNK_BLOCKS = 64
N_BLOCKS = 8          # blocks per replicated logical write (one group)
QDEPTH = 4


def _tenants(n: int, ops: int) -> list[dict]:
    return [{"name": f"t{j}", "n_ops": ops} for j in range(n)]


def _run(n_ops: int, **kw) -> dict:
    kw.setdefault("n_lbas", N_LBAS)
    kw.setdefault("chunk_blocks", CHUNK_BLOCKS)
    kw.setdefault("n_blocks", N_BLOCKS)
    kw.setdefault("qdepth", QDEPTH)
    kw.setdefault("tenants", _tenants(1, n_ops))
    return run_cluster_sim_workload(**kw)


def pipeline(n_ops: int = 2000) -> dict:
    """ACCEPTANCE: 4-node K=2 pipelined chain writes must sustain
    >= 1.5x the ops/s of serial per-replica (client-fanout) writes —
    cut-through forwarding overlaps the K transfers to within a block
    and the client uplinks the payload once instead of K times.  The CI
    floor (``speedup`` >= 0.6) bounds the replication tax instead:
    pipelined K=2 must keep >= 0.6x of the single-node unreplicated
    ops/s."""
    print(f"# chain replication: 1 client x {n_ops} x {N_BLOCKS}-block "
          f"writes, qd={QDEPTH}, 4 nodes, K=2 (acceptance: pipelined "
          f">= 1.5x serial; CI floor: >= 0.6x single-node)")
    rows = {}
    for label, kw in (
            ("single-node", dict(n_nodes=1, replication_k=1)),
            ("serial K=2", dict(n_nodes=4, replication_k=2,
                                mode="serial")),
            ("pipelined K=2", dict(n_nodes=4, replication_k=2,
                                   mode="pipelined"))):
        r = _run(n_ops, **kw)
        rows[label] = {"ops_s": r["ops_s"], "agg_mb_s": r["agg_mb_s"],
                       "makespan_us": r["makespan_us"]}
        print(f"{label:14s} ops/s={r['ops_s']:10.0f} "
              f"agg={r['agg_mb_s']:9.1f} MB/s "
              f"makespan={r['makespan_us']:12.0f}us")
    out = dict(rows)
    out["speedup_pipeline"] = (rows["pipelined K=2"]["ops_s"]
                               / rows["serial K=2"]["ops_s"])
    out["speedup"] = (rows["pipelined K=2"]["ops_s"]
                      / rows["single-node"]["ops_s"])
    print(f"-> pipelined vs serial: {out['speedup_pipeline']:.2f}x ops/s "
          f"(acceptance: >= 1.5x); replication tax: {out['speedup']:.2f}x "
          f"of single-node (CI floor: >= 0.6x)")
    return out


def scaling(n_ops: int = 1500) -> dict:
    print(f"# nodes x K sweep, pipelined, 1 client, qd={QDEPTH}")
    out = {}
    for n_nodes in (2, 4, 8):
        for k in (1, 2, 3):
            if k > n_nodes:
                continue
            r = _run(n_ops, n_nodes=n_nodes, replication_k=k)
            out[f"n{n_nodes}_k{k}"] = r["ops_s"]
            print(f"nodes={n_nodes} K={k}: ops/s={r['ops_s']:10.0f} "
                  f"agg={r['agg_mb_s']:9.1f} MB/s")
    return out


def placement(n_ops: int = 1500) -> dict:
    print("# placement policies at 6 nodes / 3 racks / K=3")
    out = {}
    for pol in ("ring", "spread", "balanced"):
        r = _run(n_ops, n_nodes=6, replication_k=3, racks=3, placement=pol)
        out[pol] = {"ops_s": r["ops_s"],
                    "rack_diversity": r["rack_diversity"],
                    "balance": r["balance"]}
        print(f"{pol:10s} ops/s={r['ops_s']:10.0f} "
              f"rack_div={r['rack_diversity']:.2f} "
              f"balance={r['balance']:.3f}")
    return out


def kill(n_ops: int = 1500) -> dict:
    print("# node death at 50% of the workload: re-replication storm")
    out = {}
    for k in (2, 3):
        r = _run(n_ops, n_nodes=5, replication_k=k, kill_node=1)
        c = r["counts"]
        out[f"k{k}"] = {"ops_s": r["ops_s"],
                        "storm_span_us": c["storm_span_us"],
                        "chunks_repaired": c.get("chunks_repaired", 0),
                        "rereplicated_blocks":
                            c.get("rereplicated_blocks", 0)}
        print(f"K={k}: ops/s={r['ops_s']:10.0f} "
              f"storm={c['storm_span_us']:10d}us "
              f"chunks={c.get('chunks_repaired', 0):5d} "
              f"blocks={c.get('rereplicated_blocks', 0):7d}")
    return out


def run(n_ops: int = 2000) -> dict:
    """The ``benchmarks.run`` registry entry: all four tables; the
    ``speedup`` key (pipelined K=2 / single-node) is the CI floor."""
    out = {"pipeline": pipeline(n_ops)}
    out["scaling"] = scaling(max(200, (n_ops * 3) // 4))
    out["placement"] = placement(max(200, (n_ops * 3) // 4))
    out["kill"] = kill(max(200, (n_ops * 3) // 4))
    out["speedup"] = out["pipeline"]["speedup"]
    out["speedup_pipeline"] = out["pipeline"]["speedup_pipeline"]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", default="pipeline",
                    choices=["pipeline", "scaling", "placement", "kill",
                             "all"])
    ap.add_argument("--ops", type=int, default=2000)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    if args.table == "all":
        out = run(args.ops)
    else:
        out = globals()[args.table](args.ops)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1, default=str)


if __name__ == "__main__":
    main()
