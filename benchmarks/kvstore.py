"""Paper Figure 8 (LevelDB db_bench): fillrandom / overwrite / readrandom /
readhot with value sizes 128B..4KB.

LevelDB's I/O pattern at the block device: SSTables are written as BULKY
sequential runs (2-4 MB) followed by an fsync; reads are 4K block gets.
The benchmark models db_bench workloads as that device-level stream:

  fillrandom/overwrite  - sequential ``value_blocks``-long writes per op
                          (a memtable flush/compaction run), fsync per run
  readrandom            - uniform 4K reads over the space
  readhot               - reads over a 1% hot range (OS page cache absorbs
                          most; the device sees the misses)
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.sim import run_sim_workload

POLICIES = ("raw", "dax", "btt", "pmbd", "pmbd70", "lru", "coactive",
            "caiti", "caiti-noee", "caiti-nobp")
VALUE_SIZES = (128, 512, 2048, 4096)        # bytes, as in Fig. 8
SST_MB = 2                                   # LevelDB table size


def _fill(policy: str, value_b: int, n_kv: int = 20_000,
          overwrite: bool = False) -> float:
    """Write n_kv values batched into 2MB SSTable runs + fsync each."""
    kv_per_sst = max(1, (SST_MB << 20) // max(value_b, 64))
    blocks_per_sst = (SST_MB << 20) // 4096
    n_sst = max(2, n_kv // kv_per_sst)
    seed = 1 if overwrite else 0
    m = run_sim_workload(policy, n_ops=n_sst, n_lbas=524_288,
                         cache_slots=16_384, iodepth=4,
                         value_blocks=blocks_per_sst, fsync_every=1,
                         seed=seed)
    # per-request response covers one whole SSTable write+fsync
    return m.counts["makespan_us"] / 1e6


def _read(policy: str, hot: bool, n_ops: int = 30_000) -> float:
    n_lbas = 524_288
    if hot:
        rng = np.random.default_rng(7)
        hot_lbas = rng.integers(0, n_lbas // 100, size=n_ops)
        stream = iter(hot_lbas.tolist())
        m = run_sim_workload(policy, n_ops=n_ops, n_lbas=n_lbas,
                             cache_slots=16_384, iodepth=32, read_frac=1.0,
                             lba_stream=stream)
    else:
        m = run_sim_workload(policy, n_ops=n_ops, n_lbas=n_lbas,
                             cache_slots=16_384, iodepth=32, read_frac=1.0)
    return m.counts["makespan_us"] / 1e6


def run(n_kv: int = 20_000, n_reads: int = 30_000) -> dict:
    out = {}
    for wl in ("fillrandom", "overwrite"):
        out[wl] = {}
        print(f"# fig8 {wl}: bulky SSTable writes + fsync (2MB runs)")
        for vb in VALUE_SIZES:
            out[wl][vb] = {}
            for policy in POLICIES:
                out[wl][vb][policy] = round(
                    _fill(policy, vb, n_kv=n_kv,
                          overwrite=(wl == "overwrite")), 4)
            row = " ".join(f"{p}={out[wl][vb][p]:7.3f}" for p in
                           ("btt", "pmbd", "lru", "coactive", "caiti"))
            base = out[wl][vb]
            print(f"value={vb:5d}B  {row}  "
                  f"caiti vs btt {(1-base['caiti']/base['btt'])*100:+5.1f}% "
                  f"vs lru {(1-base['caiti']/base['lru'])*100:+5.1f}%")
    for wl, hot in (("readrandom", False), ("readhot", True)):
        out[wl] = {}
        print(f"# fig8 {wl}")
        for policy in ("btt", "pmbd", "lru", "coactive", "caiti"):
            out[wl][policy] = round(_read(policy, hot, n_ops=n_reads), 4)
        row = " ".join(f"{p}={out[wl][p]:7.3f}s" for p in out[wl])
        print("  " + row)
    print("-> write-heavy: Caiti absorbs SSTable bursts and fsync finds "
          "little to drain; reads: comparable across policies (paper "
          "Fig. 8c/8d)")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    res = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
