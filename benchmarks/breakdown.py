"""Paper Figure 6: critical-path time breakdown per caching policy,
including the 'w/o EE' and 'w/o BP' Caiti ablations (Fig. 6a/6c/6d).

Reports, per policy:
  * % of critical-path time per category (cache metadata / cache write
    only / cache eviction+write / conditional bypass / WBQ enqueue /
    cache flush / others),
  * the write-handling mix of Fig. 6c (% cache-only vs eviction vs bypass),
  * mean cost of each handling class (Fig. 6d).
"""
from __future__ import annotations

import argparse
import json

from repro.core.sim import run_sim_workload

POLICIES = ("pmbd", "pmbd70", "lru", "coactive", "caiti",
            "caiti-noee", "caiti-nobp")
CATS = ("cache_metadata", "cache_write_only", "cache_eviction_and_write",
        "conditional_bypass", "wbq_enqueue", "cache_flush", "others")


def run(n_ops: int = 50_000, n_lbas: int = 1_048_576,
        cache_slots: int = 8_192) -> dict:
    out = {}
    print("# fig6a: % of critical-path time per category "
          "(uniform 4K pwrites, fsync-free, ext4 tick active)")
    hdr = " ".join(f"{c[:12]:>13s}" for c in CATS)
    print(f"{'policy':12s} {hdr}")
    for policy in POLICIES:
        m = run_sim_workload(policy, n_ops=n_ops, n_lbas=n_lbas,
                             cache_slots=cache_slots, iodepth=1)
        tot = sum(m.breakdown.get(c, 0.0) for c in CATS) or 1.0
        pct = {c: m.breakdown.get(c, 0.0) / tot * 100 for c in CATS}
        out[policy] = {"pct": pct,
                       "counts": dict(m.counts),
                       "mean_us": m.mean()}
        print(f"{policy:12s} " + " ".join(f"{pct[c]:12.1f}%" for c in CATS))
    print("\n# fig6c: write-handling mix (% of writes)")
    for policy in POLICIES:
        c = out[policy]["counts"]
        writes = n_ops
        stal = c.get("stalls", 0)
        byp = c.get("bypass", 0)
        cache_only = writes - stal - byp
        print(f"{policy:12s} cache-only={cache_only/writes*100:6.1f}% "
              f"evict+write={stal/writes*100:6.1f}% "
              f"bypass={byp/writes*100:6.1f}%")
        out[policy]["mix"] = {"cache_only": cache_only, "evict": stal,
                              "bypass": byp}
    print("\n-> Caiti: eviction-stall ~0 (eager eviction vacates slots in "
          "the issuance->arrival window, paper Fig. 7); w/o EE pushes "
          "everything to bypass; w/o BP reintroduces stalls")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    res = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
