"""Multi-tenant striped-volume sweeps (fio-like, virtual time).

Extends the paper's single-device tables to the volume manager:

  --table shards     shard-count scaling under a 4-tenant workload
                     (the acceptance contrast: 4-shard Caiti vs 1-shard)
  --table tenants    tenant-count scaling on a 4-shard volume
  --table watermark  global-bypass watermark sweep (bypass rate vs
                     aggregate throughput/latency)
  --table qos        weighted fair shares + a rate-capped tenant
  --table policies   policy comparison on the same 4-shard volume
  --table readmix    YCSB-B (95/5) / YCSB-C (100/0) style read-heavy
                     mixes, read tier on vs off, plus a degraded-read
                     (replica fallback) injection row
  --table groupcommit  fsync group-commit sweep: per-call commit vs
                     coalesced commits at a gathering window, >= 4
                     concurrent tenants (acceptance: >= 1.3x fsyncs/s)
  --table logbatch   batched log pipeline sweep: per-call chained-tx
                     log() vs LogBatcher-coalesced slot-shard passes,
                     >= 4 tenants (acceptance: >= 1.3x logged-writes/s)
  --table fairness   tier-aware WFQ: read-heavy vs write-heavy tenants
                     must each land within 20% of their weight share of
                     charged (priced) service in the contended window
  --table aio        async submission/completion frontend qd sweep:
                     queue depth 1 (blocking-equivalent) vs 8+ — ops/s
                     speedup from submission batching + overlap
                     (acceptance: >= 1.5x at qd=8 with 4 tenants)
  --table zerocopy   zero-copy data plane: copy-at-submit vs registered
                     buffer pinning at qd 1/8, plus fused vs three-pass
                     transit codec and a real-engine registered-pool row
                     (acceptance: >= 1.2x zerocopy at qd=8, >= 1.3x
                     fused transit)
  --table hedge      tail-latency data plane: hedged replica reads vs
                     unhedged with ONE 25x limping shard (fail-slow) —
                     hedged p99 must be >= 2x better at equal or better
                     throughput; a healthy-volume row shows the hedge
                     is nearly free when nothing limps

Primary engine: ``repro.core.sim.run_volume_sim_workload`` (deterministic
virtual time; same cost model as fio_like.py, printed with every table).
``--real`` runs a scaled-down threaded volume instead (functional path;
wall times reflect the 1-core container, not the paper's platform).
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

try:                                                    # python -m benchmarks
    from .common import fmt_row, fmt_volume_row, run_random_writes
except ImportError:                                     # direct script run
    sys.path.insert(0, __file__.rsplit("/", 1)[0])
    from common import fmt_row, fmt_volume_row, run_random_writes

from repro.core.sim import (CostModel, run_aio_sim_workload,  # noqa: E402
                            run_hedge_sim_workload,
                            run_volume_sim_workload)

N_LBAS = 524_288
SLOTS = 8_192
OPS = 10_000          # per tenant
WORKERS = 16          # eviction cores (volume total, all configs)


def _tenants(n: int, ops: int = OPS) -> list[dict]:
    return [{"name": f"t{j}", "n_ops": ops} for j in range(n)]


def shards(n_ops: int = OPS) -> dict:
    print(f"# shard scaling: 4 tenants x {n_ops} uniform 4K writes, "
          f"{WORKERS} shared eviction cores, {SLOTS} total slots")
    out = {}
    base = None
    for n in (1, 2, 4, 8):
        r = run_volume_sim_workload("caiti", n_shards=n, n_lbas=N_LBAS,
                                    cache_slots=SLOTS, n_workers=WORKERS,
                                    tenants=_tenants(4, n_ops))
        out[n] = r["agg_mb_s"]
        base = base or r["agg_mb_s"]
        print(fmt_volume_row(f"caiti x{n}", r) +
              f"  ({r['agg_mb_s'] / base:.2f}x vs 1 shard)")
    print(f"-> 4-shard vs single-device: {out[4] / out[1]:.2f}x aggregate "
          f"write throughput (acceptance: >= 2x)")
    return out


def tenants(n_ops: int = OPS) -> dict:
    print("# tenant scaling on a 4-shard caiti volume")
    out = {}
    for n in (1, 2, 4, 8):
        r = run_volume_sim_workload("caiti", n_shards=4, n_lbas=N_LBAS,
                                    cache_slots=SLOTS, n_workers=WORKERS,
                                    tenants=_tenants(n, n_ops))
        out[n] = r["agg_mb_s"]
        print(fmt_volume_row(f"{n} tenants", r))
    return out


def watermark(n_ops: int = OPS) -> dict:
    print("# global-bypass watermark sweep (4 shards, 4 tenants, small "
          "cache so staging pressure is real)")
    out = {}
    for wm in (0.5, 0.7, 0.9, 1.0):
        r = run_volume_sim_workload("caiti", n_shards=4, n_lbas=N_LBAS,
                                    cache_slots=1024, n_workers=8,
                                    watermark=wm,
                                    tenants=_tenants(4, n_ops))
        out[wm] = {"agg_mb_s": r["agg_mb_s"],
                   "bypass_rate": r["bypass_rate"]}
        print(fmt_volume_row(f"watermark={wm}", r))
    return out


def qos(n_ops: int = 6000) -> dict:
    print("# QoS: weights 4:2:1 + one 50 MB/s rate-capped tenant "
          "(contended-window MB/s shows the fair split)")
    ts = [{"name": "gold", "n_ops": n_ops, "weight": 4.0, "jobs": 8},
          {"name": "silver", "n_ops": n_ops, "weight": 2.0, "jobs": 8},
          {"name": "bronze", "n_ops": n_ops, "weight": 1.0, "jobs": 8},
          {"name": "capped", "n_ops": n_ops // 4, "rate_mbps": 50.0}]
    # qdepth << submitting cores: the admission window is the contended
    # resource, so the SFQ tags (weights) decide who dispatches
    r = run_volume_sim_workload("caiti", n_shards=4, n_lbas=N_LBAS,
                                cache_slots=1024, n_workers=6,
                                qdepth=8, iodepth=32, tenants=ts)
    print(fmt_volume_row("caiti x4", r))
    for name, d in r["per_tenant"].items():
        print(f"  {name:8s} w={d['weight']:<4} cap={d['rate_mbps'] or '-':<6} "
              f"contended={d['contended_mb_s']:8.1f} MB/s "
              f"own-span={d['mb_s']:8.1f} MB/s mean={d['mean_us']:7.1f}us")
    return {n: d["contended_mb_s"] for n, d in r["per_tenant"].items()}


def policies(n_ops: int = OPS) -> dict:
    print("# policy comparison, 4-shard volume, 4 tenants")
    out = {}
    for policy in ("btt", "pmbd", "lru", "coactive", "caiti",
                   "caiti-noee", "caiti-nobp"):
        r = run_volume_sim_workload(policy, n_shards=4, n_lbas=N_LBAS,
                                    cache_slots=SLOTS, n_workers=WORKERS,
                                    tenants=_tenants(4, n_ops))
        out[policy] = r["agg_mb_s"]
        print(fmt_volume_row(policy, r))
    return out


def readmix(n_ops: int = 6000) -> dict:
    """Read-heavy serving mixes: zipfian addresses (YCSB-style), read
    tier on/off, and a row with injected primary-verification failures
    (every 50th backend read detours to a replica shard)."""
    print("# read-heavy mixes, 2-shard caiti volume, zipf(1.1) addresses, "
          "8192 tier slots (tier columns via benchmarks/common.py)")
    out = {}
    mixes = (("ycsb-b 95/5", 0.95), ("ycsb-c 100/0", 1.0),
             ("90/10", 0.90))
    for name, rf in mixes:
        base = None
        for label, slots in (("no tier", 0), ("tier", 8192)):
            r = run_volume_sim_workload(
                "caiti", n_shards=2, n_lbas=16384, cache_slots=2048,
                n_workers=8, read_frac=rf, lba_dist="zipf", zipf_theta=1.1,
                tier_slots=slots, tenants=_tenants(4, n_ops))
            out[f"{name} {label}"] = {"agg_mb_s": r["agg_mb_s"],
                                      "tier_hit_rate": r["tier_hit_rate"]}
            base = base or r["agg_mb_s"]
            print(fmt_volume_row(f"{name[:10]} {label}", r) +
                  f"  ({r['agg_mb_s'] / base:.2f}x vs no tier)")
    r = run_volume_sim_workload(
        "caiti", n_shards=2, n_lbas=16384, cache_slots=2048, n_workers=8,
        read_frac=0.95, lba_dist="zipf", zipf_theta=1.1, tier_slots=8192,
        degraded_every=50, tenants=_tenants(4, n_ops))
    out["95/5 tier degraded"] = {"agg_mb_s": r["agg_mb_s"],
                                 "degraded_reads": r["degraded_reads"]}
    print(fmt_volume_row("95/5 degr/50", r))
    return out


def groupcommit(n_ops: int = 3000) -> dict:
    """ACCEPTANCE: with >= 4 concurrent tenants fsyncing every 16 writes,
    group commit (windowed leader gathering followers) must sustain
    >= 1.3x the fsyncs/s of per-call commit.  Every fsync checkpoint
    serializes on the volume commit lock and pays one applied-mark
    superblock write per shard — the round trip coalescing amortizes."""
    print("# group-commit sweep: 4 shards, 4 tenants x 4 jobs, "
          "fsync_every=16 (fsyncs/s = fsync calls / makespan)")
    out = {}
    base = None
    for label, w in (("per-call", 0.0), ("window=20us", 20.0),
                     ("window=50us", 50.0), ("window=100us", 100.0)):
        r = run_volume_sim_workload("caiti", n_shards=4, n_lbas=N_LBAS,
                                    cache_slots=4096, n_workers=WORKERS,
                                    fsync_every=16, commit_window_us=w,
                                    tenants=_tenants(4, n_ops))
        c = r["counts"]
        fsyncs_s = c.get("fsync_calls", 0) / max(r["makespan_us"] / 1e6,
                                                 1e-9)
        out[label] = {"fsyncs_s": fsyncs_s, "commits": c.get("commits", 0),
                      "fsync_calls": c.get("fsync_calls", 0),
                      "agg_mb_s": r["agg_mb_s"]}
        base = base or fsyncs_s
        print(fmt_volume_row(label, r) +
              f"  fsyncs/s={fsyncs_s:9.0f} commits={c.get('commits', 0):5d}"
              f" ({fsyncs_s / base:.2f}x vs per-call)")
    best = max(v["fsyncs_s"] for k, v in out.items() if k != "per-call")
    out["speedup"] = best / out["per-call"]["fsyncs_s"]
    print(f"-> best group-commit vs per-call: "
          f"{out['speedup']:.2f}x fsyncs/s "
          f"(acceptance: >= 1.3x at >= 4 tenants; CI floor: >= 1.0x)")
    return out


def logbatch(n_ops: int = 2500) -> dict:
    """ACCEPTANCE: with >= 4 tenants issuing 4-block chained-tx logged
    writes, the LogBatcher (window > 0: concurrent chains coalesce into
    one slot-shard pass — one tx-lock acquisition, grouped headers, one
    tail fence) must sustain >= 1.3x the logged-writes/s of per-call
    ``log()``, where every chain pays its own serialized journal pass."""
    print("# batched-log sweep: 4 shards, 4 tenants x 4 jobs, every op a "
          "4-block chained-tx logged write (logged/s = log calls / makespan)")
    out = {}
    base = None
    for label, w in (("per-call", 0.0), ("window=20us", 20.0),
                     ("window=50us", 50.0), ("window=100us", 100.0)):
        r = run_volume_sim_workload("caiti", n_shards=4, n_lbas=N_LBAS,
                                    cache_slots=4096, n_workers=WORKERS,
                                    log_blocks=4, log_window_us=w,
                                    tenants=_tenants(4, n_ops))
        c = r["counts"]
        logged_s = c.get("log_calls", 0) / max(r["makespan_us"] / 1e6, 1e-9)
        out[label] = {"logged_s": logged_s,
                      "log_batches": c.get("log_batches", 0),
                      "log_coalesced": c.get("log_coalesced", 0),
                      "agg_mb_s": r["agg_mb_s"]}
        base = base or logged_s
        print(fmt_volume_row(label, r) +
              f"  logged/s={logged_s:9.0f} "
              f"batches={c.get('log_batches', 0):5d} "
              f"({logged_s / base:.2f}x vs per-call)")
    best = max(v["logged_s"] for k, v in out.items() if k != "per-call")
    out["speedup"] = best / out["per-call"]["logged_s"]
    print(f"-> best batched log vs per-call: {out['speedup']:.2f}x "
          f"logged-writes/s (acceptance: >= 1.3x at >= 4 tenants; "
          f"CI floor: >= 1.0x)")
    return out


def fairness(n_ops: int = 4000) -> dict:
    """ACCEPTANCE: under tier-aware WFQ, a read-heavy (90% reads, mostly
    DRAM-served at tier_hit_cost_frac price), a write-heavy and a mixed
    tenant must EACH receive a charged-service share within 20% of their
    weight share while all are backlogged (qdepth << submitting cores:
    the admission window is the contended resource, so SFQ tags decide).
    Raw MB/s is also printed: the read-heavy tenant moves MORE raw bytes
    for the same charged share — that asymmetry is the point of pricing
    DRAM hits below PMem round trips."""
    print("# tier-aware WFQ fairness: weights 2:1:1, read-heavy (90%) vs "
          "write-heavy (0%) vs mixed (50%), zipf(1.1), tier on, qdepth=4")
    ts = [{"name": "rheavy", "n_ops": n_ops, "weight": 2.0, "jobs": 8,
           "read_frac": 0.90},
          {"name": "wheavy", "n_ops": n_ops, "weight": 1.0, "jobs": 8,
           "read_frac": 0.0},
          {"name": "mixed", "n_ops": n_ops, "weight": 1.0, "jobs": 8,
           "read_frac": 0.50}]
    r = run_volume_sim_workload("caiti", n_shards=2, n_lbas=16384,
                                cache_slots=1024, n_workers=4, qdepth=4,
                                tier_slots=8192, lba_dist="zipf",
                                zipf_theta=1.1, tenants=ts)
    print(fmt_volume_row("caiti x2", r))
    out = {"tier_hit_rate": r["tier_hit_rate"]}
    max_err = 0.0
    for name, d in r["per_tenant"].items():
        err = abs(d["contended_charged_share"] / d["weight_share"] - 1.0)
        max_err = max(max_err, err)
        out[name] = {"charged_share": d["contended_charged_share"],
                     "weight_share": d["weight_share"],
                     "share_err": err,
                     "contended_mb_s": d["contended_mb_s"]}
        print(f"  {name:8s} w={d['weight']:<4} "
              f"charged-share={d['contended_charged_share']:6.3f} "
              f"(weight share {d['weight_share']:6.3f}, "
              f"err {err * 100:4.1f}%) raw={d['contended_mb_s']:8.1f} MB/s")
    out["max_share_err"] = max_err
    print(f"-> worst tenant deviation from weight share: "
          f"{max_err * 100:.1f}% (acceptance: <= 20%)")
    return out


def aio(n_ops: int = OPS) -> dict:
    """ACCEPTANCE: the async submission/completion frontend at queue
    depth 8 must sustain >= 1.5x the ops/s of depth 1 (the blocking
    frontend's effective depth) with 4 tenants — submission batching
    amortizes the per-op stack cost and submitted ops overlap across
    the engine cores / shard DIMM banks instead of serializing on the
    submitting core.  A logged-write row shows the contrast with the
    chained-tx journal pass on the critical path."""
    print("# async frontend qd sweep: 4 shards, 4 tenants x 1 submitting "
          "core, ops/s = completions / makespan (CI floor: qd8/qd1 >= 1.0x)")
    out = {}
    base = None
    for qd in (1, 2, 4, 8, 16):
        r = run_aio_sim_workload("caiti", n_shards=4, n_lbas=N_LBAS,
                                 cache_slots=SLOTS, n_workers=WORKERS,
                                 qdepth=qd, tenants=_tenants(4, n_ops))
        out[f"qd{qd}"] = {"ops_s": r["ops_s"], "agg_mb_s": r["agg_mb_s"],
                          "mean_us": np.mean([d["mean_us"] for d in
                                              r["per_tenant"].values()])}
        base = base or r["ops_s"]
        print(f"{'qd=' + str(qd):12s} ops/s={r['ops_s']:12.0f} "
              f"agg={r['agg_mb_s']:9.1f} MB/s "
              f"makespan={r['makespan_us']:12.0f}us "
              f"({r['ops_s'] / base:.2f}x vs qd=1)")
    for qd in (1, 8):
        r = run_aio_sim_workload("caiti", n_shards=4, n_lbas=N_LBAS,
                                 cache_slots=SLOTS, n_workers=WORKERS,
                                 qdepth=qd, op="log", log_blocks=4,
                                 tenants=_tenants(4, max(1, n_ops // 4)))
        out[f"log qd{qd}"] = {"ops_s": r["ops_s"]}
        print(f"{'log qd=' + str(qd):12s} ops/s={r['ops_s']:12.0f} "
              f"(4-block chained-tx logged writes)")
    out["speedup"] = out["qd8"]["ops_s"] / out["qd1"]["ops_s"]
    print(f"-> qd=8 vs qd=1: {out['speedup']:.2f}x ops/s "
          f"(acceptance: >= 1.5x at 4 tenants; CI floor: >= 1.0x)")
    return out


def zerocopy(n_ops: int = OPS) -> dict:
    """ACCEPTANCE (PR 7): the zero-copy data plane.

      * registered buffers: at qd=8 with 4 tenants, pinned submission
        (``copy_mode='zerocopy'``) must sustain >= 1.2x the ops/s of the
        copying baseline (``'copy'``: every submit pays its defensive
        staging snapshot under the engine lock, where
        ``AsyncIOEngine._snapshot_locked`` runs it);
      * fused transit kernel: the one-pass gather+quantize+checksum
        spill codec must sustain >= 1.3x the pages/s of the three-pass
        composition (pack kernel, host checksum walk, copy-out).

    A real-engine row runs a small threaded volume with a registered
    pool and reports the live counters (copies avoided / bytes pinned /
    link depth) for ``_meta`` — wall time on the 1-core container is
    informational; the floors gate the virtual-time contrast."""
    from repro.core.sim import run_transit_sim_workload
    print("# zero-copy sweep: 4 shards, 4 tenants, copy-at-submit vs "
          "registered-buffer pinning (CI floors: qd8 zerocopy/copy >= "
          "1.2x, fused transit >= 1.3x)")
    out = {}
    for qd in (1, 8):
        row = {}
        for mode in ("copy", "zerocopy"):
            r = run_aio_sim_workload("caiti", n_shards=4, n_lbas=N_LBAS,
                                     cache_slots=SLOTS, n_workers=WORKERS,
                                     qdepth=qd, copy_mode=mode,
                                     tenants=_tenants(4, n_ops))
            row[mode] = {"ops_s": r["ops_s"], "agg_mb_s": r["agg_mb_s"]}
            print(f"{'qd=' + str(qd) + ' ' + mode:16s} "
                  f"ops/s={r['ops_s']:12.0f} agg={r['agg_mb_s']:9.1f} MB/s "
                  f"makespan={r['makespan_us']:12.0f}us")
        row["speedup"] = row["zerocopy"]["ops_s"] / row["copy"]["ops_s"]
        print(f"  -> qd={qd}: zerocopy/copy = {row['speedup']:.2f}x")
        out[f"qd{qd}"] = row
    out["speedup"] = out["qd8"]["speedup"]

    three = run_transit_sim_workload(n_pages=max(500, n_ops // 4),
                                     fused=False)
    fused = run_transit_sim_workload(n_pages=max(500, n_ops // 4),
                                     fused=True)
    out["transit"] = {
        "three_pass_pages_s": three["pages_s"],
        "fused_pages_s": fused["pages_s"],
        "three_pass_mb_s": three["mb_s"],
        "fused_mb_s": fused["mb_s"],
    }
    out["fused_speedup"] = fused["pages_s"] / three["pages_s"]
    print(f"{'transit 3-pass':16s} pages/s={three['pages_s']:12.0f} "
          f"({three['passes_per_page']} passes/page)")
    print(f"{'transit fused':16s} pages/s={fused['pages_s']:12.0f} "
          f"({fused['passes_per_page']} pass/page)")
    print(f"  -> fused vs three-pass: {out['fused_speedup']:.2f}x")

    # real engine: registered pool + linked chain counters (informational)
    from repro.volume import make_volume
    vol = make_volume("caiti", n_lbas=4096, n_shards=2,
                      cache_bytes=4 << 20, aio_workers=2)
    try:
        reg = vol.register_buffers(16)
        parents = []
        for i in range(64):
            buf = reg.acquire()
            buf.data[:] = i & 0xFF
            parents.append(vol.submit("write", i, data=buf, block=True))
        links = [vol.submit("read", i, link_to=t, block=True,
                            out=np.empty(vol.block_size, np.uint8))
                 for i, t in enumerate(parents)]
        for t in links:
            t.result()
        for t in parents:
            vol.wait(t)
        zc = vol.scrub()["zerocopy"]
        out["engine"] = {k: zc[k] for k in
                        ("copies_avoided", "bytes_pinned", "staging_copies",
                         "links_submitted", "link_depth_max")}
        out["engine"]["copy_on_evict"] = zc["registry"]["copy_on_evict"]
        print(f"{'real engine':16s} copies_avoided={zc['copies_avoided']} "
              f"bytes_pinned={zc['bytes_pinned']} "
              f"staging_copies={zc['staging_copies']} "
              f"copy_on_evict={zc['registry']['copy_on_evict']}")
    finally:
        vol.close()
    print(f"-> zerocopy qd8: {out['speedup']:.2f}x (floor >= 1.2x); "
          f"fused transit: {out['fused_speedup']:.2f}x (floor >= 1.3x)")
    return out


def hedge(n_ops: int = 4000) -> dict:
    """ACCEPTANCE (PR 8): the tail-latency data plane.

    With ONE shard limping at 25x (fail-slow: it never errors, mean
    throughput looks healthy because only 1/n_shards of uniform reads
    land there), hedged replica reads must bring p99 read latency to
    <= 0.5x the unhedged p99 — i.e. >= 2x better — at equal or better
    throughput.  ``p99_frac`` is LOWER-IS-BETTER (the first latency-
    style ceiling in ``check_floors.py``); ``ops_ratio`` (hedged /
    unhedged ops/s, >= 1.0) guards the equal-throughput clause.

    A healthy-volume hedged row shows the hedge is nearly free when
    nothing limps (almost no hedges fire: the delay is above healthy
    service time).  A real-engine row runs a small threaded replicated
    volume with one delayed shard and reports the live
    ``Metrics.tail_path()`` counters — the fired == won + cancelled
    balance and the scorer's limping verdict — for ``_meta``; wall time
    on the 1-core container is informational, the virtual-time contrast
    is what the floors gate."""
    print("# hedged-read sweep: 4 shards, 4 clients, uniform reads, "
          "shard 0 limping 25x (CI: p99 hedged/unhedged <= 0.5x ceiling, "
          "ops ratio >= 1.0x floor)")
    out = {}
    rows = (("unhedged limping", False, 0),
            ("hedged limping", True, 0),
            ("hedged healthy", True, None))
    for label, hedged, slow in rows:
        r = run_hedge_sim_workload(n_lbas=N_LBAS, n_ops=n_ops,
                                   hedge=hedged, slow_shard=slow)
        c = r["counts"]
        out[label] = {"p50_us": r["p50_us"], "p99_us": r["p99_us"],
                      "p999_us": r["p999_us"], "ops_s": r["ops_s"],
                      "hedges_fired": c.get("hedges_fired", 0),
                      "hedges_won": c.get("hedges_won", 0),
                      "hedges_cancelled": c.get("hedges_cancelled", 0)}
        print(f"{label:18s} p50={r['p50_us']:7.2f}us p99={r['p99_us']:7.2f}us "
              f"p99.9={r['p999_us']:7.2f}us ops/s={r['ops_s']:10.0f} "
              f"fired={c.get('hedges_fired', 0):5d} "
              f"won={c.get('hedges_won', 0):5d}")
    out["p99_frac"] = (out["hedged limping"]["p99_us"]
                       / max(out["unhedged limping"]["p99_us"], 1e-9))
    out["ops_ratio"] = (out["hedged limping"]["ops_s"]
                        / max(out["unhedged limping"]["ops_s"], 1e-9))

    # real engine: replicated threaded volume, one shard delayed —
    # live tail_path counters + scorer verdict (informational)
    from repro.volume import make_volume
    vol = make_volume("caiti", n_lbas=256, n_shards=2, replicas=2,
                      cache_bytes=1 << 20, aio_workers=2)
    try:
        for i in range(16):
            vol.write(i, bytes([i]) * vol.block_size)
        vol.flush()
        slow = vol.shards[0].impl           # lbas 0..15 all stripe to it
        orig = slow.read_ex
        def _slow_read_ex(local, out=None, **kw):
            import time as _t
            _t.sleep(0.002)
            return orig(local, out=out, **kw)
        slow.read_ex = _slow_read_ex
        try:
            for i in range(0, 16, 2):       # primaries on the slow shard
                vol.hedged_read(i, delay_s=0.0005)
        finally:
            slow.read_ex = orig
        tail = vol.scrub()["tail"]
        out["engine"] = {k: tail[k] for k in
                         ("hedges_fired", "hedges_won", "hedges_cancelled",
                          "primaries_cancelled", "hedges_unaccounted")}
        out["engine"]["states"] = tail["states"]
        print(f"{'real engine':18s} fired={tail['hedges_fired']} "
              f"won={tail['hedges_won']} "
              f"cancelled={tail['hedges_cancelled']} "
              f"states={tail['states']}")
    finally:
        vol.close()
    print(f"-> hedged/unhedged p99 under one limping shard: "
          f"{out['p99_frac']:.2f}x (ceiling <= 0.5x); "
          f"throughput ratio {out['ops_ratio']:.2f}x (floor >= 1.0x)")
    return out


def real(n_ops: int = 2000) -> dict:
    """Threaded volume on the container (functional validation only)."""
    from repro.volume import make_volume
    print("# REAL threaded volume (1-core container wall time — "
          "contrasts are not the paper's platform)")
    out = {}
    for n in (1, 4):
        vol = make_volume("caiti", n_lbas=65536, n_shards=n,
                          cache_bytes=8 << 20, shared_workers=4)
        res = run_random_writes(vol, n_ops=n_ops, n_lbas=65536, jobs=4)
        out[n] = res["mb_s"]
        snap = vol.metrics_snapshot()
        print(fmt_row(f"caiti x{n}", res,
                      extra=f"bg_evictions={snap['bg_evictions']}"))
        vol.close()
    return out


TABLES = {"shards": shards, "tenants": tenants, "watermark": watermark,
          "qos": qos, "policies": policies, "readmix": readmix,
          "groupcommit": groupcommit, "logbatch": logbatch,
          "fairness": fairness, "aio": aio, "zerocopy": zerocopy,
          "hedge": hedge}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", default="shards",
                    choices=list(TABLES) + ["all"])
    ap.add_argument("--ops", type=int, default=0)
    ap.add_argument("--real", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    print(f"cost model: {CostModel()}")
    kw = {"n_ops": args.ops} if args.ops else {}
    if args.real:
        res = real(**({"n_ops": args.ops} if args.ops else {}))
    elif args.table == "all":
        res = {name: fn(**kw) for name, fn in TABLES.items()}
    else:
        res = TABLES[args.table](**kw)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1, default=str)


if __name__ == "__main__":
    main()
