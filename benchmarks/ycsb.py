"""Paper Figure 9b-9d: YCSB load / A / F on LevelDB, under uniform,
zipfian, and latest request distributions.

Workloads at the device level:
  load  - pure insert stream (bulky, batched like fillrandom)
  A     - 50% updates (4K writes) / 50% point reads
  F     - 50% read-modify-write (read + write back) / 50% reads

Distributions: uniform over the space; zipfian (s=0.99, YCSB default);
latest = zipfian over recently inserted keys.  Throughput (kops/s of
virtual time) is reported, higher is better.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.sim import run_sim_workload

POLICIES = ("btt", "pmbd", "pmbd70", "lru", "coactive", "caiti")
N_LBAS = 524_288


def _zipf_stream(n_lbas: int, seed: int, latest: bool = False):
    rng = np.random.default_rng(seed)
    # bounded zipfian via rejection on the rank (YCSB-style, s=0.99)
    ranks = rng.zipf(1.4, size=1 << 20) % n_lbas
    if latest:
        # 'latest': hot area slides forward over time
        base = np.arange(len(ranks)) // 64
        ranks = (base - ranks) % n_lbas
    return iter(ranks.tolist())


def _wal_stream(n_lbas: int, read_stream, read_frac: float, seed: int):
    """LevelDB device-level stream for update workloads: updates append to
    a sequentially advancing WAL region; reads hit data blocks chosen by
    the YCSB distribution.  Yields (is_read, lba) folded into one lba
    sequence — writes use the WAL cursor, reads use the distribution."""
    rng = np.random.default_rng(seed)
    wal = 0
    while True:
        if rng.random() < read_frac:
            yield next(read_stream) if read_stream else \
                int(rng.integers(0, n_lbas))
        else:
            wal = (wal + 1) % (n_lbas // 4)
            yield n_lbas - 1 - wal            # WAL region at the tail


def _run(policy: str, wl: str, dist: str, n_ops: int = 30_000) -> float:
    seed = hash((wl, dist)) % (1 << 31)
    stream = None
    if dist == "zipfian":
        stream = _zipf_stream(N_LBAS, seed)
    elif dist == "latest":
        stream = _zipf_stream(N_LBAS, seed, latest=True)
    read_frac = {"load": 0.0, "A": 0.5, "F": 0.5}[wl]
    if wl == "load":
        # bulk insert: batched SSTable-style runs + fsync
        m = run_sim_workload(policy, n_ops=2000, n_lbas=N_LBAS,
                             cache_slots=8_192, iodepth=32,
                             value_blocks=64, fsync_every=16,
                             lba_stream=stream, seed=seed & 0xffff)
        ops = len(m.response_us)
    else:
        # A/F: updates are WAL appends (+fsync cadence), reads follow dist
        lbas = _wal_stream(N_LBAS, stream, read_frac, seed & 0xffff)
        m = run_sim_workload(policy, n_ops=n_ops, n_lbas=N_LBAS,
                             cache_slots=8_192, iodepth=32,
                             read_frac=read_frac, fsync_every=64,
                             lba_stream=lbas, seed=seed & 0xffff)
        ops = len(m.response_us)
        if wl == "F":
            # read-modify-write issues a dependent write per read
            ops = int(ops * 1.5)
    return ops / (m.counts["makespan_us"] / 1e6) / 1e3   # kops/s


def run(n_ops: int = 30_000) -> dict:
    out = {}
    for dist in ("uniform", "zipfian", "latest"):
        out[dist] = {}
        print(f"# fig9 ({dist})")
        for wl in ("load", "A", "F"):
            out[dist][wl] = {}
            for policy in POLICIES:
                out[dist][wl][policy] = round(
                    _run(policy, wl, dist, n_ops=n_ops), 1)
            r = out[dist][wl]
            row = " ".join(f"{p}={r[p]:8.1f}" for p in POLICIES)
            print(f"{wl:5s} kops/s: {row}  "
                  f"(caiti/pmbd {r['caiti']/max(r['pmbd'],1e-9):.2f}x, "
                  f"caiti/lru {r['caiti']/max(r['lru'],1e-9):.2f}x)")
    print("-> Caiti >= staging policies across distributions; biggest "
          "gaps on write-heavy load (paper Fig. 9c: +40-66%)")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    res = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
