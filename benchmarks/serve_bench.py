"""Serving-tier benchmark: the paper's transit policies on the paged KV
cache (real engine, smoke model, CPU wall time — relative numbers).

Scenario: more concurrent requests than the HBM pool can hold.
  * transit (eager page-out of retired/preempted sequences + bypass):
    decode keeps running; finished sequences vacate pages immediately.
  * staging (no eager page-out, no bypass): admission stalls on a full
    pool — the serving analogue of the paper's staging-cache stalls.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import PagedCacheConfig, ServeEngine


def run(n_requests: int = 10, prompt_len: int = 24, max_new: int = 8,
        pool_pages: int = 8, page_size: int = 8) -> dict:
    cfg = get_config("qwen2.5-3b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    out = {}
    for mode in ("transit", "staging"):
        cache_cfg = PagedCacheConfig(
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, page_size=page_size, n_pages=pool_pages,
            max_pages_per_seq=(prompt_len + max_new) // page_size + 2,
            eager_eviction=(mode == "transit"),
            conditional_bypass=(mode == "transit"))
        eng = ServeEngine(cfg, params, cache_cfg=cache_cfg, max_batch=3)
        rng = np.random.default_rng(0)
        for _ in range(n_requests):
            eng.submit(rng.integers(2, cfg.vocab, (prompt_len,)).tolist(),
                       max_new_tokens=max_new)
        t0 = time.perf_counter()
        try:
            done = eng.run(max_ticks=2000)
            err = ""
        except MemoryError as e:          # staging mode can exhaust the pool
            done = eng.finished
            err = str(e)
        dt = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in done)
        out[mode] = {
            "completed": len(done), "tokens": toks,
            "tok_per_s": round(toks / dt, 1),
            "pages_out": eng.metrics.count.get("pages_out", 0),
            "pages_in": eng.metrics.count.get("pages_in", 0),
            "bypass_pages": eng.metrics.count.get("bypass_pages", 0),
            "stall_error": err,
        }
        print(f"{mode:8s} completed={len(done)}/{n_requests} "
              f"tokens={toks} ({out[mode]['tok_per_s']} tok/s) "
              f"pages out/in={out[mode]['pages_out']}/"
              f"{out[mode]['pages_in']} bypass={out[mode]['bypass_pages']}"
              f"{' STALLED: ' + err if err else ''}")
    print("-> transit serving completes the backlog under pool pressure; "
          "staging admission stalls (the paper's contrast, serving-side)")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    res = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
