"""Fio-style microbenchmarks — paper Figures 2a, 5a, 5d, 5e and Table 1.

Primary engine: the deterministic virtual-time simulator
(``repro.core.sim`` — the paper's multicore mechanism cannot be timed on
this 1-core container; see the module docstring for the calibrated cost
model).  ``--real`` runs the same tables against the *threaded* reference
implementation instead (functional validation; wall times there reflect
the container, not the paper's platform).

  --table fig2a   execution time: BTT vs PMem vs DAX vs staging vs Caiti
                  (+ the fsync-every-512KB variant of Fig. 2a right)
  --table fig5    I/O-depth sweep: mean response + 99.99p tail per policy
  --table fig5e   jobs (threads) scaling
  --table table1  cache-capacity sweep
  --table meta    per-slot metadata spatial cost (paper §5.1 'Fifthly')
"""
from __future__ import annotations

import argparse
import json

from repro.core.sim import run_sim_workload

ALL = ("raw", "dax", "btt", "pmbd", "pmbd70", "lru", "coactive", "caiti")
CACHED = ("pmbd", "pmbd70", "lru", "coactive", "caiti")

# scaled defaults (paper: 64 GB space / 512 MB cache / 30 min; here:
# 2 GB space / 32 MB cache / ~50 k requests — ratios preserved)
N_LBAS = 524_288
SLOTS = 8_192
OPS = 50_000


def _row(policy: str, m, base: float | None = None) -> str:
    mk = m.counts["makespan_us"] / 1e6
    s = (f"{policy:12s} makespan={mk:8.3f}s mean={m.mean():9.2f}us "
         f"p99.99={m.pct(99.99):10.1f}us stalls={m.counts.get('stalls', 0):6d} "
         f"bypass={m.counts.get('bypass', 0):6d}")
    if base:
        s += f"  ({base / mk:.2f}x vs caiti)" if policy != "caiti" else ""
    return s


def fig2a(n_ops: int = OPS, fsync_every: int = 0) -> dict:
    out = {}
    print(f"# fig2a{' + fsync/128' if fsync_every else ''}: uniform random "
          f"4K writes, iodepth 32, cache {SLOTS} slots, space {N_LBAS} lbas")
    res = {}
    for policy in ALL:
        m = run_sim_workload(policy, n_ops=n_ops, n_lbas=N_LBAS,
                             cache_slots=SLOTS, iodepth=32,
                             fsync_every=fsync_every)
        res[policy] = m
        out[policy] = m.counts["makespan_us"] / 1e6
    for policy in ALL:
        print(_row(policy, res[policy], out["caiti"]))
    print(f"-> btt vs raw: {(out['btt']/out['raw']-1)*100:+.1f}% time "
          f"(paper +37.4%); btt vs dax {(out['btt']/out['dax']-1)*100:+.1f}% "
          f"(paper +16.6%); btt/caiti {out['btt']/out['caiti']:.2f}x "
          f"(paper 'up to 3.6x')")
    return out


def fig5(n_ops: int = 30_000, depths=(32, 128, 512, 1024)) -> dict:
    out = {}
    print("# fig5a/5d: I/O-depth sweep (mean + 99.99p response)")
    for depth in depths:
        out[depth] = {}
        print(f"-- iodepth {depth}")
        for policy in ("btt", "pmbd", "pmbd70", "lru", "coactive", "caiti"):
            m = run_sim_workload(policy, n_ops=n_ops, n_lbas=N_LBAS,
                                 cache_slots=SLOTS, iodepth=depth)
            out[depth][policy] = {"mean_us": m.mean(),
                                  "p9999_us": m.pct(99.99),
                                  "makespan_s": m.counts["makespan_us"]/1e6}
            print(_row(policy, m))
    return out


def fig5e(n_ops: int = 40_000, jobs=(1, 2, 4, 8, 16, 32)) -> dict:
    out = {}
    print("# fig5e: jobs scaling at iodepth 32")
    for j in jobs:
        out[j] = {}
        print(f"-- jobs {j}")
        for policy in ("btt", "pmbd", "lru", "coactive", "caiti"):
            m = run_sim_workload(policy, n_ops=n_ops, n_lbas=N_LBAS,
                                 cache_slots=SLOTS, iodepth=32, jobs=j)
            out[j][policy] = m.counts["makespan_us"] / 1e6
            print(_row(policy, m))
    return out


def table1(n_ops: int = 40_000, slot_counts=(2048, 4096, 8192, 16384, 32768)
           ) -> dict:
    out = {}
    print("# table1: cache-capacity sweep (mean response, iodepth 32) — "
          "the paper finds capacity hardly matters under overload")
    for slots in slot_counts:
        out[slots] = {}
        for policy in CACHED:
            m = run_sim_workload(policy, n_ops=n_ops, n_lbas=N_LBAS,
                                 cache_slots=slots, iodepth=32)
            out[slots][policy] = round(m.mean(), 2)
        row = " ".join(f"{p}={out[slots][p]:8.2f}" for p in CACHED)
        print(f"slots={slots:6d}  {row}")
    return out


def meta() -> dict:
    """Per-4K-slot metadata cost, mirroring the paper's §5.1 accounting."""
    costs = {
        "caiti":  {"paper_B": 102, "impl": {
            "lba": 8, "slot_number": 4, "state": 1, "lock+queued": 9,
            "wbq/free links": 16, "work item": 8}},
        "pmbd":   {"paper_B": 84, "impl": {
            "lba": 8, "slot_number": 4, "lock": 8, "lists": 16}},
        "lru":    {"paper_B": 84, "impl": {
            "lba": 8, "slot_number": 4, "lock": 8, "lru links": 16}},
        "coactive": {"paper_B": 102, "impl": {
            "lba": 8, "slot_number": 4, "lock": 8, "lists": 24, "bloom": 2}},
    }
    print(f"{'policy':10s} {'paper B/slot':>12s} {'impl B/slot':>12s} "
          f"{'% of 4K':>8s}")
    out = {}
    for p, info in costs.items():
        b = sum(info["impl"].values())
        out[p] = b
        print(f"{p:10s} {info['paper_B']:12d} {b:12d} {b / 4096 * 100:7.2f}%")
    return out


TABLES = {"fig2a": fig2a, "fig5": fig5, "fig5e": fig5e, "table1": table1,
          "meta": meta}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", default="fig2a", choices=list(TABLES))
    ap.add_argument("--fsync-every", type=int, default=0)
    ap.add_argument("--ops", type=int, default=0)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    kw = {}
    if args.table == "fig2a" and args.fsync_every:
        kw["fsync_every"] = args.fsync_every
    if args.ops:
        kw["n_ops"] = args.ops
    res = TABLES[args.table](**kw)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1, default=str)


if __name__ == "__main__":
    main()
