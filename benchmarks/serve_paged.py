"""KV paging benchmark: concurrent sessions swept PAST HBM+host DRAM
capacity, with the overflow spilling through the async volume.

Two legs:

  * **sim sweep** (virtual time, deterministic): the
    ``run_kv_paging_sim_workload`` session-rotation model at the
    resident bound vs >= 4x the combined HBM+host page capacity.
    ``throughput_4x_frac`` is the floored degradation (decode tokens/s
    at 4x capacity over resident-only); ``prefetch_speedup`` is the
    decode-ahead contrast (prefetch_depth > 0 vs synchronous restores
    at the same 4x load).
  * **real leg** (threaded cache + pager on a tiny striped volume):
    sessions append real KV pages, deactivate past ``host_pages`` so
    packed pages descend onto the volume (content-hash dedup for the
    shared prompt prefix), then resume through prefetch + activate.
    Asserts ZERO crc errors end to end and surfaces the
    ``kv_paging_path()`` counters.
"""
from __future__ import annotations

import argparse
import json

import jax.numpy as jnp
import numpy as np

from repro.core.metrics import Metrics
from repro.core.sim import run_kv_paging_sim_workload


def _sim_leg(rounds: int) -> dict:
    hbm_pages, host_pages, pps = 16, 16, 4
    resident = hbm_pages // pps                       # 4 sessions
    cap = (hbm_pages + host_pages) // pps             # HBM+host DRAM bound
    n4 = 4 * cap                                      # >= 4x combined DRAM
    common = dict(hbm_pages=hbm_pages, host_pages=host_pages,
                  pages_per_session=pps, page_blocks=8, shared_pages=1,
                  tokens_per_turn=16, rounds=rounds, decode_us=20.0)
    base = run_kv_paging_sim_workload(n_sessions=resident, **common)
    x4 = run_kv_paging_sim_workload(n_sessions=n4, **common)
    x4_sync = run_kv_paging_sim_workload(n_sessions=n4, prefetch_depth=0,
                                         **common)
    out = {
        "resident_sessions": resident,
        "sessions_4x": n4,
        "tokens_s_resident": base["tokens_s"],
        "tokens_s_4x": x4["tokens_s"],
        "tokens_s_4x_sync": x4_sync["tokens_s"],
        "throughput_4x_frac": x4["tokens_s"] / base["tokens_s"],
        "prefetch_speedup": x4["tokens_s"] / x4_sync["tokens_s"],
        "spills": x4["spills"],
        "dedup_hits": x4["dedup_hits"],
        "restores_vol": x4["restores_vol"],
        "prefetch_hits": x4["prefetch_hits"],
    }
    print(f"sim    resident={resident} 4x={n4} sessions: "
          f"{out['tokens_s_resident']:.0f} -> {out['tokens_s_4x']:.0f} "
          f"tok/s ({out['throughput_4x_frac']:.3f}x, floor 0.5) | "
          f"prefetch {out['prefetch_speedup']:.3f}x vs sync | "
          f"spills={out['spills']} dedup={out['dedup_hits']} "
          f"restores={out['restores_vol']}")
    return out


def _real_leg(n_sessions: int, tokens_each: int) -> dict:
    from repro.serve import KVPager, PagedCacheConfig, PagedKVCache
    from repro.volume.volume import make_volume

    m = Metrics()
    vol = make_volume(n_lbas=4096, n_shards=2, aio_workers=2,
                      cache_bytes=1 << 22)
    pager = KVPager(vol, capacity_blocks=2048, metrics=m)
    cfg = PagedCacheConfig(n_layers=2, n_kv_heads=2, head_dim=8,
                           page_size=4, n_pages=8, host_pages=2,
                           max_pages_per_seq=8, read_tier_pages=8)
    cache = PagedKVCache(cfg, metrics=m, pager=pager)
    rng = np.random.default_rng(0)
    # shared prompt prefix: one page of identical tokens across sessions
    prefix = [(rng.normal(size=(2, 8)).astype(np.float32),
               rng.normal(size=(2, 8)).astype(np.float32))
              for _ in range(cfg.page_size)]
    sids = []
    for _s in range(n_sessions):
        sid = cache.new_sequence()
        sids.append(sid)
        for k, v in prefix:
            cache.append_token(sid, [jnp.asarray(k)] * cfg.n_layers,
                               [jnp.asarray(v)] * cfg.n_layers)
        for _t in range(tokens_each - cfg.page_size):
            k = rng.normal(size=(2, 8)).astype(np.float32)
            v = rng.normal(size=(2, 8)).astype(np.float32)
            cache.append_token(sid, [jnp.asarray(k)] * cfg.n_layers,
                               [jnp.asarray(v)] * cfg.n_layers)
        cache.deactivate(sid)                 # spills past host_pages
    for sid in sids:                          # resume through the pager
        cache.prefetch(sid)
        cache.activate(sid)
        q = jnp.asarray(rng.normal(size=(1, 2, 8)), jnp.float32)
        cache.attention(0, q, [sid], use_kernel=False)
        cache.deactivate(sid)
    for sid in sids:
        cache.release(sid)
    path = m.kv_paging_path()
    assert path["kv_restore_crc_errors"] == 0, path
    assert m.count.get("transit_crc_errors", 0) == 0
    assert cache.free_pages() == cfg.n_pages, "pool pages leaked"
    assert pager.stats()["records"] == 0, "pager records leaked"
    print(f"real   {n_sessions} sessions x {tokens_each} tok: "
          f"spills={path['kv_spills']} dedup={path['kv_dedup_hits']} "
          f"(rate {path['dedup_rate']:.2f}) "
          f"restores={path['kv_restores']} "
          f"prefetch_hit_rate={path['prefetch_hit_rate']:.2f} "
          f"crc_errors={path['kv_restore_crc_errors']}")
    return path


def run(rounds: int = 3, n_sessions: int = 6, tokens_each: int = 8) -> dict:
    out = _sim_leg(rounds)
    out["real"] = _real_leg(n_sessions, tokens_each)
    print("-> paging holds decode throughput at 4x DRAM capacity; the "
          "volume absorbs the overflow with zero crc errors")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    res = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
