"""Checkpoint-engine benchmark — the paper's technique as the ML-systems
substrate (transit vs staging for checkpoint I/O).

Measures, on the REAL threaded implementation (functional wall time on this
container, not the simulator):

  * save/commit latency for a synthetic model state through the Caiti
    block store vs staging policies,
  * the 'fsync cliff': commit cost right after a burst of puts (staging
    drains everything at the barrier; transit has already moved it),
  * async save overlap: train-loop step time with save_async in flight,
  * crash-restart: kill mid-save, reopen, verify the previous generation
    restores bit-exactly (block-level atomicity end-to-end).
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from repro.ckpt import CheckpointEngine, make_blockstore


def _state(mb: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    n = (mb << 20) // 8 // 4
    return {f"w{i}": rng.standard_normal(n // 8).astype(np.float32)
            for i in range(8)}


def save_commit(policies=("caiti", "caiti-noee", "pmbd", "lru"),
                state_mb: int = 64) -> dict:
    out = {}
    state = _state(state_mb)
    print(f"# save+commit of a {state_mb}MB state per device policy "
          f"(real threads, RAM pool)")
    for policy in policies:
        store = make_blockstore(policy=policy, capacity_bytes=1 << 30,
                                cache_bytes=16 << 20)
        eng = CheckpointEngine(store, staging_bytes=32 << 20)
        t0 = time.perf_counter()
        eng.save(0, state)
        dt = time.perf_counter() - t0
        t1 = time.perf_counter()
        eng.save(1, state)          # second save: cache warm/occupied
        dt2 = time.perf_counter() - t1
        out[policy] = {"first_s": round(dt, 3), "second_s": round(dt2, 3)}
        print(f"{policy:12s} first={dt:7.3f}s second={dt2:7.3f}s "
              f"({state_mb / dt:6.1f} MB/s)")
        eng.close()
    return out


def async_overlap(state_mb: int = 32, steps: int = 8) -> dict:
    """Step time with an async save in flight vs without."""
    state = _state(state_mb)

    def fake_step():                       # a compute-ish step (~30ms)
        a = np.random.default_rng(1).standard_normal((700, 700))
        for _ in range(3):
            a = a @ a.T / 700
        return a.sum()

    store = make_blockstore(policy="caiti", capacity_bytes=1 << 30)
    eng = CheckpointEngine(store)
    ts = []
    for i in range(steps):
        t0 = time.perf_counter()
        fake_step()
        ts.append(time.perf_counter() - t0)
    base = float(np.median(ts))
    ts = []
    for i in range(steps):
        if i % 2 == 0:
            eng.save_async(i, state)
        t0 = time.perf_counter()
        fake_step()
        ts.append(time.perf_counter() - t0)
    eng.wait()
    overl = float(np.median(ts))
    eng.close()
    print(f"# async-save overlap: step {base*1e3:.1f}ms alone vs "
          f"{overl*1e3:.1f}ms with save_async in flight "
          f"(+{(overl/base-1)*100:.0f}%)")
    return {"step_ms": base * 1e3, "step_with_save_ms": overl * 1e3}


def crash_restart() -> dict:
    """Commit gen1; start gen2 but 'crash' before its commit; reopen and
    verify gen1 restores exactly."""
    with tempfile.TemporaryDirectory() as td:
        pool = os.path.join(td, "pool.bin")
        state1 = _state(8, seed=1)
        store = make_blockstore(pool, policy="caiti",
                                capacity_bytes=256 << 20)
        eng = CheckpointEngine(store)
        eng.save(0, state1)
        # gen2 staged but NOT committed (simulate crash: skip commit+close)
        state2 = _state(8, seed=2)
        prefix = "step%010d" % 1
        for k, v in state2.items():
            store.put(f"{prefix}/{k}/0", v.tobytes())
        del eng, store                      # drop without commit
        store2 = make_blockstore(pool, policy="caiti",
                                 capacity_bytes=256 << 20)
        eng2 = CheckpointEngine(store2)
        got, step = eng2.restore(like=state1)
        ok = step == 0 and all(
            np.array_equal(np.asarray(got[k]), state1[k]) for k in state1)
        eng2.close()
        print(f"# crash-restart: uncommitted gen invisible, gen@step0 "
              f"restored bit-exact: {'OK' if ok else 'FAIL'}")
        return {"ok": bool(ok)}


def run(state_mb: int = 64, steps: int = 8) -> dict:
    return {"save_commit": save_commit(state_mb=state_mb),
            "async_overlap": async_overlap(state_mb=max(8, state_mb // 2),
                                           steps=steps),
            "crash_restart": crash_restart()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    res = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
