"""Benchmark aggregator: one section per paper table/figure + the ML-side
substrate benches + the volume-manager sweeps.

    python -m benchmarks.run              # everything (paper-scale ops)
    python -m benchmarks.run --fast       # reduced op counts (CI perf)
    python -m benchmarks.run --smoke      # tiny sizes: every table must
                                          # run end to end (CI gate)
    python -m benchmarks.run --list       # show every registered table
    python -m benchmarks.run --only fig6,volume_groupcommit

Every table lives in the registry below — adding a benchmark module
without registering it here is what let the volume ``readmix`` and
group-commit sweeps go invisible to ``run.py`` (they had to be invoked
directly).  Writes JSON artifacts under experiments/bench/.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

#: every sim table pins its RNG to this seed; recorded in the artifact's
#: ``_meta`` so two artifacts are only compared apples-to-apples
SEED = 0


def _section(name: str):
    print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}", flush=True)


def registry_version(tables) -> str:
    """Fingerprint of the registered table set.  Embedded in every
    ``--json`` artifact and re-derived by ``check_floors.py``: comparing
    artifacts produced by different registries (a table added, renamed
    or dropped between runs) is not apples-to-apples, and this makes
    that mismatch loud instead of silent."""
    return hashlib.sha1(",".join(sorted(tables)).encode()).hexdigest()[:12]


def _registry(ops: int, fast: bool, smoke: bool = False) -> dict:
    """name -> (description, thunk).  ``ops`` is the base op count; each
    entry scales it the way the old inline sections did.  ``smoke``
    additionally shrinks the tables whose cost is NOT governed by
    ``ops`` (fixed sweeps, real-thread state sizes) so the CI gate
    really runs tiny."""
    try:                                        # python -m benchmarks.run
        from . import breakdown, ckpt_bench, cluster_bench, fio_like, \
            fsync_sweep, kvstore, roofline, scenarios, serve_bench, \
            serve_paged, volume_bench, ycsb
    except ImportError:                         # python benchmarks/run.py
        sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
        import breakdown, ckpt_bench, cluster_bench, fio_like, \
            fsync_sweep, kvstore, roofline, scenarios, serve_bench, \
            serve_paged, volume_bench, ycsb

    return {
        "fig2a": ("random-write execution time (sim)",
                  lambda: fio_like.fig2a(n_ops=ops)),
        "fig2a_fsync": ("random writes with fsync every 128 (sim)",
                        lambda: fio_like.fig2a(n_ops=ops, fsync_every=128)),
        "fig2b": ("fsync cost vs write volume (sim)",
                  lambda: fsync_sweep.run(
                      intervals=(128, 512, 2048) if smoke
                      else fsync_sweep.INTERVALS)),
        "fig5": ("I/O depth sweep (sim)",
                 lambda: fio_like.fig5(n_ops=ops // 2,
                                       depths=(32, 128) if fast
                                       else (32, 128, 512, 1024))),
        "fig5e": ("jobs scaling (sim)",
                  lambda: fio_like.fig5e(n_ops=ops // 2,
                                         jobs=(1, 4) if fast
                                         else (1, 2, 4, 8, 16, 32))),
        "table1": ("cache-size sweep (sim)",
                   lambda: fio_like.table1(n_ops=ops // 2)),
        "meta": ("metadata spatial cost",
                 lambda: fio_like.meta()),
        "fig6": ("breakdown + ablations (sim)",
                 lambda: breakdown.run(n_ops=ops)),
        "fig8": ("LevelDB-style workloads (sim)",
                 lambda: kvstore.run(n_kv=2_000 if smoke else 20_000,
                                     n_reads=ops // 2)),
        "fig9": ("YCSB A/F x uniform/zipfian/latest (sim)",
                 lambda: ycsb.run(n_ops=ops // 2)),
        "ckpt": ("Caiti as checkpoint substrate (real threads)",
                 lambda: ckpt_bench.run(state_mb=16 if smoke else 64,
                                        steps=4 if smoke else 8)),
        "serve": ("transit vs staging on the paged KV tier (real engine)",
                  lambda: serve_bench.run(n_requests=4 if smoke else 10,
                                          max_new=4 if smoke else 8)),
        "serve_paged": ("KV paging past DRAM: sessions at 4x HBM+host "
                        "capacity spilling through the async volume "
                        "(sim + real pager)",
                        lambda: serve_paged.run(
                            rounds=2 if smoke else 3,
                            n_sessions=4 if smoke else 6,
                            tokens_each=8)),
        "volume_shards": ("striped multi-device scaling (sim)",
                          lambda: volume_bench.shards(n_ops=ops // 5)),
        "volume_qos": ("per-tenant QoS fair shares (sim)",
                       lambda: volume_bench.qos(n_ops=ops // 10)),
        "volume_readmix": ("read-heavy mixes, tier on/off + degraded "
                           "injection (sim)",
                           lambda: volume_bench.readmix(n_ops=ops // 10)),
        "volume_groupcommit": ("fsync group-commit sweep, per-call vs "
                               "coalesced (sim)",
                               lambda: volume_bench.groupcommit(
                                   n_ops=ops // 10)),
        "volume_logbatch": ("batched log pipeline sweep, per-call vs "
                            "LogBatcher-coalesced (sim)",
                            lambda: volume_bench.logbatch(n_ops=ops // 10)),
        "volume_fairness": ("tier-aware WFQ fairness: read/write-heavy "
                            "tenants vs weight share (sim)",
                            lambda: volume_bench.fairness(n_ops=ops // 2)),
        "volume_aio": ("async frontend queue-depth sweep, qd1 vs qd8+ "
                       "(sim)",
                       lambda: volume_bench.aio(n_ops=ops // 10)),
        "volume_zerocopy": ("zero-copy data plane: pinned vs copy-at-"
                            "submit, fused vs three-pass transit (sim)",
                            lambda: volume_bench.zerocopy(n_ops=ops // 10)),
        "volume_hedge": ("tail-latency data plane: hedged replica reads "
                         "vs unhedged under one limping shard (sim)",
                         lambda: volume_bench.hedge(n_ops=max(1000, ops))),
        "cluster": ("distributed cluster volume: pipelined chain "
                    "replication, placement, kill storm (sim)",
                    lambda: cluster_bench.run(n_ops=max(200, ops // 10))),
        "scenarios": ("self-tuning control plane vs frozen knobs on four "
                      "adversarial phase-change traces (sim)",
                      lambda: scenarios.run(n_ops=ops)),
        "roofline": ("dry-run derived roofline terms (deliverable g)",
                     lambda: len(roofline.run("experiments/dryrun",
                                              mesh="pod16x16"))),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced op counts (CI perf mode)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny op counts; assert every table runs end to "
                         "end (CI gate — catches benchmark drift)")
    ap.add_argument("--list", action="store_true",
                    help="list every registered table and exit")
    ap.add_argument("--only", default="",
                    help="comma-separated table names to run")
    ap.add_argument("--out", default="experiments/bench")
    ap.add_argument("--json", default=None,
                    help="also write the results JSON to this exact path "
                         "(CI uploads it as the BENCH_smoke artifact and "
                         "gates perf floors on it)")
    args = ap.parse_args()

    ops = 2_000 if args.smoke else 12_000 if args.fast else 50_000
    tables = _registry(ops, fast=args.fast or args.smoke, smoke=args.smoke)

    if args.list:
        width = max(len(n) for n in tables)
        for name, (desc, _fn) in tables.items():
            print(f"{name:{width}s}  {desc}")
        return
    only = [s for s in args.only.split(",") if s]
    for name in only:
        assert name in tables, \
            f"unknown table {name!r} (see --list): {sorted(tables)}"

    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    results = {}
    failures = []
    for name, (desc, fn) in tables.items():
        if only and name not in only:
            continue
        _section(f"{name} — {desc}")
        try:
            results[name] = fn()
        except Exception as e:            # smoke must see every failure
            failures.append((name, e))
            print(f"[benchmarks.run] FAILED {name}: {e!r}", flush=True)
            if not args.smoke:
                raise

    # artifact provenance: seed + registry fingerprint travel WITH the
    # results so floor gates can refuse cross-registry comparisons
    mode = "smoke" if args.smoke else "fast" if args.fast else "full"
    results["_meta"] = {
        "seed": SEED,
        "registry_version": registry_version(tables),
        "tables_registered": sorted(tables),
        "mode": mode,
        "base_ops": ops,
    }
    # zero-copy data-plane counters from the real-engine row travel in
    # _meta so artifact diffs surface pin-rate regressions at a glance
    zc = results.get("volume_zerocopy", {}).get("engine")
    if zc:
        results["_meta"]["zerocopy_engine"] = zc
    with open(os.path.join(args.out, "results.json"), "w") as f:
        json.dump(results, f, indent=1, default=str)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, default=str)
    n_tables = sum(1 for k in results if not k.startswith("_"))
    print(f"\n[benchmarks.run] {n_tables} tables in "
          f"{time.time() - t0:.1f}s -> {args.out}/results.json")
    if failures:
        print(f"[benchmarks.run] {len(failures)} table(s) FAILED: "
              f"{[n for n, _ in failures]}")
        sys.exit(1)


if __name__ == "__main__":
    main()
