"""Benchmark aggregator: one section per paper table/figure + the ML-side
substrate benches.  ``python -m benchmarks.run [--fast]``.

Writes JSON artifacts under experiments/bench/ and prints each table.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _section(name: str):
    print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced op counts (CI mode)")
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    results = {}

    from . import breakdown, ckpt_bench, fio_like, fsync_sweep, kvstore, \
        roofline, serve_bench, volume_bench, ycsb

    ops = 12_000 if args.fast else 50_000

    _section("fig2a — random-write execution time (sim)")
    results["fig2a"] = fio_like.fig2a(n_ops=ops)
    _section("fig2a+fsync — with fsync every 128 writes (sim)")
    results["fig2a_fsync"] = fio_like.fig2a(n_ops=ops, fsync_every=128)
    _section("fig2b — fsync cost vs write volume (sim)")
    results["fig2b"] = fsync_sweep.run()
    _section("fig5 — I/O depth sweep (sim)")
    results["fig5"] = fio_like.fig5(n_ops=ops // 2,
                                    depths=(32, 128) if args.fast
                                    else (32, 128, 512, 1024))
    _section("fig5e — jobs scaling (sim)")
    results["fig5e"] = fio_like.fig5e(n_ops=ops // 2,
                                      jobs=(1, 4) if args.fast
                                      else (1, 2, 4, 8, 16, 32))
    _section("table1 — cache-size sweep (sim)")
    results["table1"] = fio_like.table1(n_ops=ops // 2)
    _section("meta — metadata spatial cost")
    results["meta"] = fio_like.meta()
    _section("fig6 — breakdown + ablations (sim)")
    results["fig6"] = breakdown.run(n_ops=ops)
    _section("fig8 — LevelDB-style workloads (sim)")
    results["fig8"] = kvstore.run()
    _section("fig9 — YCSB A/F x uniform/zipfian/latest (sim)")
    results["fig9"] = ycsb.run()
    _section("ckpt — Caiti as checkpoint substrate (real threads)")
    results["ckpt"] = ckpt_bench.run()
    _section("serve — transit vs staging on the paged KV tier (real engine)")
    results["serve"] = serve_bench.run()
    _section("volume — striped multi-device scaling (sim)")
    results["volume_shards"] = volume_bench.shards(n_ops=ops // 5)
    _section("volume — per-tenant QoS fair shares (sim)")
    results["volume_qos"] = volume_bench.qos(n_ops=ops // 10)
    _section("roofline — dry-run derived terms (deliverable g)")
    rows = roofline.run("experiments/dryrun", mesh="pod16x16")
    results["roofline_rows"] = len(rows)

    with open(os.path.join(args.out, "results.json"), "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"\n[benchmarks.run] done in {time.time()-t0:.1f}s -> "
          f"{args.out}/results.json")


if __name__ == "__main__":
    main()
