"""Roofline tables from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun), computes
the three per-device roofline terms on TPU v5e constants, identifies the
dominant term, and prints the full (arch x shape x mesh) table plus the
MODEL_FLOPS/HLO_FLOPS usefulness ratio.

Terms (all per device, per step):
  compute    = HLO_FLOPs / 197e12          [s]   (bf16 MXU peak)
  memory     = HLO_bytes / 819e9           [s]   (HBM bandwidth)
  collective = wire_bytes / 50e9           [s]   (ICI per link)

HLO_FLOPs / bytes / wire_bytes come from the trip-multiplied HLO cost model
(repro.launch.hlo_cost) over the post-SPMD partitioned module — i.e.
per-device numbers.  MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE),
divided across devices, times 3 for a train step's fwd+bwd ratio already
being inside the 6 (2 fwd + 4 bwd); decode/prefill use 2·N·D_tokens.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one token per sequence
    "long_500k": 1,
}


def model_flops(rec: dict) -> float:
    """Analytic useful FLOPs for the whole step (all devices)."""
    n_active = rec.get("active_params") or rec.get("params")
    toks = SHAPE_TOKENS[rec["shape"]]
    if rec["shape"] == "train_4k":
        return 6.0 * n_active * toks
    return 2.0 * n_active * toks


def load_cells(path: str, tag: str = "") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        rec = json.loads(open(f).read())
        if (rec.get("tag") or "") != tag:
            continue
        cells.append(rec)
    return cells


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "OK":
        return None
    hc = rec.get("hlo_cost") or {}
    if "flops" not in hc:
        return None
    n_dev = rec.get("n_devices", 256)
    t_comp = hc["flops"] / PEAK_FLOPS
    t_mem = hc["bytes"] / HBM_BW
    t_coll = hc.get("wire_bytes", 0.0) / ICI_BW
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])
    mf = model_flops(rec) / n_dev
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "bottleneck": dom[0],
        "bound_s": dom[1],
        "useful_ratio": mf / hc["flops"] if hc["flops"] else 0.0,
        "roofline_frac": t_comp / dom[1] if dom[1] else 0.0,
        "hbm_gb": (rec.get("memory", {}).get("argument_size_in_bytes", 0)
                   + rec.get("memory", {}).get("temp_size_in_bytes", 0))
        / 1e9,
    }


def run(path: str = "experiments/dryrun", tag: str = "",
        mesh: str | None = None) -> list[dict]:
    rows = []
    print(f"# roofline over {path} (tag={tag or '-'}) — per-device terms, "
          f"TPU v5e: {PEAK_FLOPS/1e12:.0f}TF bf16, {HBM_BW/1e9:.0f}GB/s HBM, "
          f"{ICI_BW/1e9:.0f}GB/s ICI")
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':10s} {'comp_s':>9s} "
           f"{'mem_s':>9s} {'coll_s':>9s} {'bound':>10s} {'roofl%':>7s} "
           f"{'useful%':>8s} {'HBM_GB':>7s}")
    print(hdr)
    skips = []
    for rec in load_cells(path, tag):
        if mesh and rec["mesh"] != mesh:
            continue
        if rec.get("status") == "SKIP":
            skips.append(rec)
            continue
        row = roofline_row(rec)
        if row is None:
            print(f"{rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:10s} "
                  f"  <{rec.get('status')}>")
            continue
        rows.append(row)
        print(f"{row['arch']:22s} {row['shape']:12s} {row['mesh']:10s} "
              f"{row['t_compute_s']:9.4f} {row['t_memory_s']:9.4f} "
              f"{row['t_collective_s']:9.4f} {row['bottleneck']:>10s} "
              f"{row['roofline_frac']*100:6.1f}% "
              f"{row['useful_ratio']*100:7.1f}% {row['hbm_gb']:7.1f}")
    for rec in skips:
        print(f"{rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:10s}   "
              f"SKIP ({rec.get('reason', '')[:60]})")
    if rows:
        worst = sorted(rows, key=lambda r: r["roofline_frac"])[:3]
        coll = sorted(rows, key=lambda r: -r["t_collective_s"])[:3]
        print("\nworst roofline fraction:",
              [(r["arch"], r["shape"], r["mesh"],
                f"{r['roofline_frac']*100:.1f}%") for r in worst])
        print("most collective-bound:",
              [(r["arch"], r["shape"], r["mesh"],
                f"{r['t_collective_s']:.3f}s") for r in coll])
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = run(args.path, args.tag, args.mesh)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
