"""Shared harness for the paper-replication benchmarks.

Scaling note (stated next to every result): the paper drives 64 GB through
a 36-core Optane box; this container has one core and no PMem, so volumes
are scaled (hundreds of MB) and the PMem/DRAM cost ratio is injected by
``repro.core.pmem.LatencyModel`` (calibrated from the paper's cited FAST'20
measurements).  The *contrasts* (Caiti vs staging policies, fsync cliffs,
stall breakdowns) are the reproduction target, not absolute microseconds.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.core import LatencyModel, make_device

#: policies compared throughout (paper §5 Setup)
ALL_POLICIES = ("dax", "raw", "btt", "pmbd", "pmbd70", "lru", "coactive",
                "caiti")
CACHED_POLICIES = ("pmbd", "pmbd70", "lru", "coactive", "caiti")

#: default latency injection — the paper's PMem:DRAM gap (Yang et al. [82])
PMEM_LAT = LatencyModel()


def make_bench_device(policy: str, *, data_mb: int = 256,
                      cache_mb: int = 64, n_workers: int = 4,
                      record_latencies: bool = False,
                      latency: LatencyModel = PMEM_LAT):
    n_lbas = (data_mb << 20) // 4096
    return make_device(policy, n_lbas=n_lbas, block_size=4096,
                       cache_bytes=cache_mb << 20, n_workers=n_workers,
                       latency=latency, record_latencies=record_latencies)


class PeriodicFlusher:
    """The ext4 journal tick: an async REQ_PREFLUSH every ``period`` s."""

    def __init__(self, dev, period: float = 0.5) -> None:
        self.dev = dev
        self.period = period
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self) -> None:
        while not self._stop.wait(self.period):
            self.dev.flush()

    def close(self) -> None:
        self._stop.set()
        self._t.join(timeout=2.0)


def run_random_writes(dev, *, n_ops: int, n_lbas: int, jobs: int = 1,
                      fsync_every: int = 0, seed: int = 0,
                      read_frac: float = 0.0) -> dict:
    """Uniform random 4K writes (the paper's fio workload).  Returns
    wall-time and aggregate metrics.  ``jobs`` = fio numjobs (threads);
    ``fsync_every`` inserts an fsync per job after that many writes."""
    per = n_ops // jobs
    block = np.random.default_rng(seed).integers(
        0, 256, size=4096, dtype=np.uint8).tobytes()
    errs = []

    def worker(j):
        rng = np.random.default_rng(seed + 1000 + j)
        lbas = rng.integers(0, n_lbas, size=per)
        reads = rng.random(per) < read_frac if read_frac else None
        try:
            for i, lba in enumerate(lbas):
                if reads is not None and reads[i]:
                    dev.read(int(lba))
                else:
                    dev.write(int(lba), block)
                if fsync_every and (i + 1) % fsync_every == 0:
                    dev.fsync()
        except BaseException as e:       # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(j,)) for j in range(jobs)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dev.fsync()
    wall = time.perf_counter() - t0
    if errs:
        raise errs[0]
    res = {"wall_s": wall, "ops": n_ops,
           "mb_s": n_ops * 4096 / wall / 1e6,
           "us_per_op": wall / n_ops * 1e6,
           "bypass_rate": bypass_rate(dev, n_ops)}
    if read_frac and hasattr(dev, "metrics"):
        # layered read path summary (transit/tier/backend split)
        res["read_path"] = dev.metrics.read_path()
    return res


def fmt_row(name: str, res: dict, extra: str = "") -> str:
    s = (f"{name:10s} wall={res['wall_s']:7.3f}s "
         f"{res['mb_s']:7.1f} MB/s {res['us_per_op']:6.2f} us/op")
    if "bypass_rate" in res:
        s += f" bypass={res['bypass_rate']*100:5.1f}%"
    return s + (f" {extra}" if extra else "")


def bypass_rate(dev, n_writes: int) -> float:
    """Fraction of writes that took the conditional-bypass path
    (single devices expose .metrics, volumes aggregate over shards)."""
    if hasattr(dev, "metrics_snapshot"):
        count = dev.metrics_snapshot()["bypass_writes"]
    else:
        count = dev.metrics.snapshot()["count"].get("bypass_writes", 0)
    return count / max(1, n_writes)


def fmt_volume_row(name: str, res: dict) -> str:
    """One line per policy/config for volume runs: the paper-style
    breakdown plus the volume columns (bypass rate, read-tier hit rate,
    degraded reads, per-tenant MB/s)."""
    s = (f"{name:14s} makespan={res['makespan_us']/1e6:8.3f}s "
         f"agg={res['agg_mb_s']:8.1f} MB/s "
         f"bypass={res['bypass_rate']*100:5.1f}% "
         f"stalls={res['counts'].get('stalls', 0):5d}")
    if res.get("tier_hit_rate"):
        s += f" tier={res['tier_hit_rate']*100:5.1f}%"
    if res.get("degraded_reads"):
        s += f" degraded={res['degraded_reads']:d}"
    tenants = res.get("per_tenant", {})
    if tenants:
        cols = " ".join(
            f"{t}={d['mb_s']:7.1f}" for t, d in sorted(tenants.items()))
        s += f" | per-tenant MB/s: {cols}"
    return s
