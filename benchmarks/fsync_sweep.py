"""Paper Figure 2b: fsync time vs data written between consecutive fsyncs.

The staging policies' fsync cost grows with the buffered volume (the drain
is the fsync); Caiti's stays flat because eager eviction has already
transited almost everything.  Sweep: one fsync after every
512KB .. 128MB of 4K writes (128 .. 32768 blocks).
"""
from __future__ import annotations

import argparse
import json

from repro.core.sim import run_sim_workload

POLICIES = ("btt", "pmbd", "pmbd70", "lru", "coactive", "caiti")
# blocks between fsyncs: 512KB, 2MB, 8MB, 32MB, 128MB
INTERVALS = (128, 512, 2048, 8192, 32768)


def run(n_lbas: int = 524_288, cache_slots: int = 32_768,
        intervals: tuple = INTERVALS) -> dict:
    out = {}
    print("# fig2b: mean fsync cost vs write volume between fsyncs "
          "(cache 128MB-equcomputed slots so staging CAN buffer the burst)")
    for blocks in intervals:
        n_ops = max(4, 3) * blocks + blocks // 2   # a few fsync periods
        out[blocks] = {}
        for policy in POLICIES:
            m = run_sim_workload(policy, n_ops=n_ops, n_lbas=n_lbas,
                                 cache_slots=cache_slots, iodepth=32,
                                 fsync_every=blocks)
            n_fsync = max(1, n_ops // blocks)
            fsync_us = m.breakdown.get("cache_flush", 0.0) / n_fsync
            out[blocks][policy] = round(fsync_us, 1)
        row = " ".join(f"{p}={out[blocks][p]:10.1f}us" for p in POLICIES)
        print(f"fsync every {blocks:6d} blocks ({blocks*4//1024:5d} KB): {row}")
    print("-> staging fsync cost grows ~linearly in buffered volume; "
          "Caiti stays flat (paper Fig. 2b)")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    res = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
