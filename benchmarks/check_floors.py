"""CI perf/quality gate over a ``benchmarks/run.py --json`` artifact.

Fails (exit 1) when a coalescing sweep lost its win outright: the
``volume_logbatch`` or ``volume_groupcommit`` best-vs-per-call speedup
dropping below 1.0x means batching/group commit became a pessimization.
This is a FLOOR, not a ratchet — the acceptance bars (>= 1.3x at real op
counts) live in the sim-backed tests; smoke-sized runs are noisy enough
that ratcheting on them would flake, but a sub-1.0x result is wrong at
any size.

    python benchmarks/check_floors.py BENCH_smoke.json

Tables listed in FLOORS must be PRESENT in the artifact (a missing table
is the registry-drift failure smoke exists to catch), unless explicitly
skipped with --allow-missing.
"""
from __future__ import annotations

import argparse
import json
import sys

# table name in the results JSON -> minimum acceptable "speedup" value
FLOORS = {
    "volume_logbatch": 1.0,
    "volume_groupcommit": 1.0,
    # async frontend: qd8 dropping below qd1 means the submission/
    # completion split became a pessimization
    "volume_aio": 1.0,
}


def check(results: dict, allow_missing: bool = False) -> list[str]:
    problems = []
    for table, floor in FLOORS.items():
        if table not in results:
            if not allow_missing:
                problems.append(f"{table}: missing from results "
                                f"(benchmark registry drift?)")
            continue
        entry = results[table]
        speedup = entry.get("speedup") if isinstance(entry, dict) else None
        if speedup is None:
            problems.append(f"{table}: no 'speedup' key in results")
            continue
        speedup = float(speedup)
        status = "OK" if speedup >= floor else "FAIL"
        print(f"[check_floors] {table}: speedup {speedup:.2f}x "
              f"(floor {floor:.1f}x) {status}")
        if speedup < floor:
            problems.append(f"{table}: speedup {speedup:.2f}x is below the "
                            f"{floor:.1f}x floor")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", help="results JSON from benchmarks/run.py --json")
    ap.add_argument("--allow-missing", action="store_true",
                    help="tolerate absent tables (partial --only runs)")
    args = ap.parse_args()
    with open(args.path) as f:
        results = json.load(f)
    problems = check(results, allow_missing=args.allow_missing)
    if problems:
        for p in problems:
            print(f"[check_floors] FAIL: {p}", file=sys.stderr)
        sys.exit(1)
    print("[check_floors] all perf floors hold")


if __name__ == "__main__":
    main()
