"""CI perf/quality gate over a ``benchmarks/run.py --json`` artifact.

Fails (exit 1) when a coalescing sweep lost its win outright: the
``volume_logbatch`` or ``volume_groupcommit`` best-vs-per-call speedup
dropping below 1.0x means batching/group commit became a pessimization.
This is a FLOOR, not a ratchet — the acceptance bars (>= 1.3x at real op
counts) live in the sim-backed tests; smoke-sized runs are noisy enough
that ratcheting on them would flake, but a sub-1.0x result is wrong at
any size.

    python benchmarks/check_floors.py BENCH_smoke.json

Tables listed in FLOORS must be PRESENT in the artifact (a missing table
is the registry-drift failure smoke exists to catch), unless explicitly
skipped with --allow-missing.
"""
from __future__ import annotations

import argparse
import json
import sys

# table name in the results JSON -> minimum acceptable "speedup" value;
# a dict value floors several keys of the same table at once.  A per-key
# spec may itself be a dict to pick the direction: {"min": x} is the
# default lower bound (throughput-style, higher is better); {"max": x}
# is a CEILING for latency-style ratios where lower is better — e.g.
# hedged-p99 / unhedged-p99 must stay at or below the bar
FLOORS = {
    "volume_logbatch": 1.0,
    "volume_groupcommit": 1.0,
    # async frontend: qd8 dropping below qd1 means the submission/
    # completion split became a pessimization
    "volume_aio": 1.0,
    # zero-copy data plane: registered-buffer pinning must beat
    # copy-at-submit at qd=8, and the fused transit kernel must beat
    # the three-pass composition — both contrasts are the tentpole's
    # reason to exist, so losing either outright fails the gate
    "volume_zerocopy": {"speedup": 1.2, "fused_speedup": 1.3},
    # tail-latency data plane: with ONE 25x limping shard, hedged p99
    # must be >= 2x better than unhedged (p99_frac is hedged/unhedged,
    # lower is better) without giving up throughput
    "volume_hedge": {"p99_frac": {"max": 0.5}, "ops_ratio": 1.0},
    # cluster replication tax: pipelined K=2 at 4 nodes must keep
    # >= 0.6x of the single-node unreplicated ops/s (the acceptance bar
    # — pipelined >= 1.5x serial fanout — lives in the sim tests)
    "cluster": 0.6,
    # self-tuning control plane: the tuned run must reach at least the
    # frozen-knob throughput on EVERY adversarial trace (a controller
    # that loses to doing nothing is a bug, not noise), and on the
    # phase-change trace tuned p99 must not regress past frozen p99
    "scenarios": {"phase_change_ops_ratio": 1.0,
                  "diurnal_ops_ratio": 1.0,
                  "churn_ops_ratio": 1.0,
                  "ckpt_serve_ops_ratio": 1.0,
                  "phase_change_p99_ratio": {"max": 1.0}},
    # KV paging past DRAM: decode tokens/s with sessions at 4x the
    # HBM+host page capacity must hold >= 0.5x of the resident-only
    # run, and decode-ahead prefetch must never lose to synchronous
    # restores (both legs deterministic virtual time)
    "serve_paged": {"throughput_4x_frac": 0.5, "prefetch_speedup": 1.0},
}

# Registered tables with NO floor must be waived here EXPLICITLY, with
# the reason a floor does not apply.  tests/test_ci_registry.py asserts
# FLOORS | WAIVERS covers the registry exactly (and that the two sets
# are disjoint), so adding a bench table forces a conscious decision:
# gate it or write down why not.
WAIVERS = {
    "fig2a": "absolute exec-time table; contrast lives in fig6 ablations",
    "fig2a_fsync": "absolute exec-time table (fsync variant of fig2a)",
    "fig2b": "fsync cost curve; shape-checked in tests, no single ratio",
    "fig5": "iodepth sweep; monotonicity asserted in sim tests",
    "fig5e": "jobs sweep; monotonicity asserted in sim tests",
    "table1": "cache-size sweep; no pairwise contrast to floor",
    "meta": "static metadata spatial cost; exact values asserted in tests",
    "fig6": "ablation breakdown; per-feature wins asserted in sim tests",
    "fig8": "LevelDB-style workload table; absolute throughputs only",
    "fig9": "YCSB grid; absolute throughputs only",
    "ckpt": "real-thread wall times on a shared CI box — too noisy",
    "serve": "real-engine wall times on a shared CI box — too noisy",
    "volume_shards": "scaling bar (>= 2x at 4 shards) lives in sim tests",
    "volume_qos": "fair-share splits asserted in tests/test_volume_qos",
    "volume_readmix": "tier win bars live in the read-tier sim tests",
    "volume_fairness": "WFQ share error bars live in the fairness tests",
    "roofline": "dry-run derived terms; counts asserted in tests",
}


def check_meta(results: dict) -> list[str]:
    """Provenance gate: the artifact's embedded ``_meta`` (seed +
    registry fingerprint, written by ``benchmarks/run.py --json``) must
    match the CURRENT registry — floors compared across different
    registries or seeds are not apples-to-apples.  Skipped (with a
    warning) for artifacts predating the meta block or when the
    registry cannot be imported here."""
    meta = results.get("_meta")
    if not isinstance(meta, dict):
        print("[check_floors] WARN: artifact has no _meta block "
              "(pre-provenance artifact); skipping registry check")
        return []
    print(f"[check_floors] artifact meta: seed={meta.get('seed')} "
          f"registry={meta.get('registry_version')} "
          f"mode={meta.get('mode')}")
    try:
        import run as bench_run
    except ImportError:
        try:
            from benchmarks import run as bench_run
        except ImportError:
            print("[check_floors] WARN: benchmarks.run not importable; "
                  "skipping registry-version check")
            return []
    problems = []
    if meta.get("seed") != bench_run.SEED:
        problems.append(f"artifact seed {meta.get('seed')!r} != current "
                        f"bench seed {bench_run.SEED!r}")
    try:
        current = bench_run.registry_version(
            bench_run._registry(1, fast=True, smoke=True))
    except ImportError as e:        # bench deps absent in this env
        print(f"[check_floors] WARN: registry not importable ({e}); "
              f"skipping registry-version check")
        return problems
    if meta.get("registry_version") != current:
        problems.append(
            f"artifact registry_version {meta.get('registry_version')!r} "
            f"!= current {current!r} (table set changed — regenerate the "
            f"artifact before comparing floors)")
    return problems


def check(results: dict, allow_missing: bool = False) -> list[str]:
    problems = []
    for table, floor in FLOORS.items():
        if table not in results:
            if not allow_missing:
                problems.append(f"{table}: missing from results "
                                f"(benchmark registry drift?)")
            continue
        entry = results[table]
        keyed = floor if isinstance(floor, dict) else {"speedup": floor}
        for key, spec in keyed.items():
            val = entry.get(key) if isinstance(entry, dict) else None
            if val is None:
                problems.append(f"{table}: no {key!r} key in results")
                continue
            if isinstance(spec, dict):
                ceiling = "max" in spec
                bar = float(spec["max"] if ceiling else spec["min"])
            else:
                ceiling, bar = False, float(spec)
            val = float(val)
            ok = val <= bar if ceiling else val >= bar
            kind = "ceiling" if ceiling else "floor"
            status = "OK" if ok else "FAIL"
            print(f"[check_floors] {table}: {key} {val:.2f}x "
                  f"({kind} {bar:.1f}x) {status}")
            if not ok:
                side = "above" if ceiling else "below"
                problems.append(f"{table}: {key} {val:.2f}x is {side} the "
                                f"{bar:.1f}x {kind}")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", help="results JSON from benchmarks/run.py --json")
    ap.add_argument("--allow-missing", action="store_true",
                    help="tolerate absent tables (partial --only runs)")
    args = ap.parse_args()
    with open(args.path) as f:
        results = json.load(f)
    problems = check_meta(results)
    problems += check(results, allow_missing=args.allow_missing)
    if problems:
        for p in problems:
            print(f"[check_floors] FAIL: {p}", file=sys.stderr)
        sys.exit(1)
    print("[check_floors] all perf floors hold")


if __name__ == "__main__":
    main()
