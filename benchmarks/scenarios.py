"""Adversarial scenario matrix: self-tuning control plane vs frozen knobs.

Each scenario replays the SAME multi-phase trace twice on the virtual-
time volume sim (``repro.core.sim.run_autotune_sim_workload``):

  frozen   knobs stay at the conservative defaults for the whole trace
           (commit/log windows 0, watermark 0.9, hedge 1000us)
  tuned    a REAL ``repro.volume.autotune.Controller`` observes one
           signal window per control tick and retunes the knobs online

The scenarios are adversarial by construction — each one changes the
workload's character mid-trace so any FIXED knob setting is wrong for
at least one phase:

  phase_change  YCSB-A with per-op fsync pressure -> YCSB-C zipf reads
                (fsync coalescing must open, then stop mattering)
  diurnal       logged-write bursts alternating with think-time read
                lulls (the log window must earn its keep in bursts
                without hurting the lulls)
  churn         tenants arrive and leave across phases (2 fsync-heavy
                -> 6 mixed -> 3 logged-write writers); the coalescing
                population the controller sees keeps shifting
  ckpt_serve    sequential-scan restore reads, then zipf serving reads
                concurrent with a logged + fsynced checkpoint writer

The CI floor (benchmarks/check_floors.py) is direction-aware: tuned
must reach >= 1.0x the frozen throughput on EVERY scenario, and on the
phase-change trace tuned p99 must stay at or below frozen p99.  Those
are floors, not the acceptance bars — the convergence/clamp-safety
assertions live in tests/test_autotune.py.
"""
from __future__ import annotations

import argparse
import json
import sys

if __package__ in (None, ""):                           # direct script run
    sys.path.insert(0, __file__.rsplit("/", 1)[0])

from repro.core.sim import CostModel, run_autotune_sim_workload  # noqa: E402
from repro.volume.autotune import make_default_controller        # noqa: E402


def _trace_pair(name: str, phases: list[dict], **kw) -> dict:
    """Run one trace frozen then tuned; print the contrast row."""
    frozen = run_autotune_sim_workload("caiti", phases=phases,
                                       autotune=None, **kw)
    tuned = run_autotune_sim_workload("caiti", phases=phases,
                                      autotune=make_default_controller(),
                                      **kw)
    ops_ratio = tuned["ops_s"] / max(frozen["ops_s"], 1e-9)
    p99_ratio = tuned["p99_us"] / max(frozen["p99_us"], 1e-9)
    moves = tuned.get("autotune", {}).get("total_moves", 0)
    print(f"{name:14s} frozen={frozen['ops_s']:10.0f} ops/s "
          f"tuned={tuned['ops_s']:10.0f} ops/s  "
          f"ratio={ops_ratio:.2f}x  p99={p99_ratio:.2f}x  "
          f"moves={moves}")
    for pname, ph in tuned["per_phase"].items():
        fr = frozen["per_phase"][pname]
        print(f"    {pname:12s} tuned={ph['ops_s']:10.0f} ops/s "
              f"frozen={fr['ops_s']:10.0f} ops/s "
              f"({ph['ops_s'] / max(fr['ops_s'], 1e-9):.2f}x)")
    return {"frozen_ops_s": frozen["ops_s"], "tuned_ops_s": tuned["ops_s"],
            "ops_ratio": ops_ratio, "p99_ratio": p99_ratio,
            "moves": moves, "knob_final": tuned.get("knob_final", {}),
            "n_knob_moves_applied": len(tuned.get("knob_trace", []))}


def _mixed(n: int, per: int, *, read_frac: float = 0.5,
           fsync_every: int = 0, log_blocks: int = 0, jobs: int = 2,
           think_us: float = 0.0, tag: str = "t") -> list[dict]:
    return [{"name": f"{tag}{j}", "n_ops": per, "jobs": jobs,
             "read_frac": read_frac, "fsync_every": fsync_every,
             "log_blocks": log_blocks, "think_us": think_us}
            for j in range(n)]


def run(n_ops: int = 6000) -> dict:
    """All four scenarios; returns the flat floor keys CI gates on."""
    per = max(600, n_ops // 4)          # ops per tenant per phase
    print(f"# tuned-vs-frozen on 4 adversarial traces "
          f"({per} ops/tenant/phase, 4 shards, virtual time)")
    out: dict = {}

    out["phase_change"] = _trace_pair("phase_change", [
        {"name": "ycsb_a", "tenants": _mixed(4, per, read_frac=0.5,
                                             fsync_every=4)},
        {"name": "ycsb_c", "lba_dist": "zipf",
         "tenants": _mixed(4, per, read_frac=1.0)},
    ], seed=1)

    out["diurnal"] = _trace_pair("diurnal", [
        {"name": "burst_am", "tenants": _mixed(4, per, read_frac=0.1,
                                               log_blocks=4,
                                               fsync_every=8)},
        {"name": "lull", "tenants": _mixed(4, per // 2, read_frac=0.8,
                                           think_us=200.0)},
        {"name": "burst_pm", "tenants": _mixed(4, per, read_frac=0.1,
                                               log_blocks=4,
                                               fsync_every=8)},
    ], seed=2)

    out["churn"] = _trace_pair("churn", [
        {"name": "two_syncers", "tenants": _mixed(2, per,
                                                  read_frac=0.2,
                                                  fsync_every=4,
                                                  jobs=4)},
        {"name": "six_mixed", "tenants": _mixed(6, per, read_frac=0.5,
                                                fsync_every=8)},
        {"name": "three_loggers", "tenants": _mixed(3, per,
                                                    read_frac=0.0,
                                                    log_blocks=4,
                                                    tag="w")},
    ], seed=3)

    out["ckpt_serve"] = _trace_pair("ckpt_serve", [
        {"name": "restore", "lba_dist": "seq",
         "tenants": _mixed(2, per, read_frac=1.0, jobs=4)},
        {"name": "serve_ckpt", "lba_dist": "zipf",
         "tenants": _mixed(3, per, read_frac=1.0, tag="s") +
         _mixed(1, per, read_frac=0.0, log_blocks=8,
                fsync_every=16, jobs=4, tag="ckpt")},
    ], seed=4)

    # flat floor keys so check_floors.py can gate without nesting
    for name, r in list(out.items()):
        out[f"{name}_ops_ratio"] = r["ops_ratio"]
    out["phase_change_p99_ratio"] = out["phase_change"]["p99_ratio"]
    worst = min(out[f"{n}_ops_ratio"]
                for n in ("phase_change", "diurnal", "churn", "ckpt_serve"))
    print(f"-> tuned vs frozen: worst-scenario throughput ratio "
          f"{worst:.2f}x (floor >= 1.0x); phase-change p99 ratio "
          f"{out['phase_change_p99_ratio']:.2f}x (ceiling <= 1.0x)")
    return out


TABLES = {"scenarios": run}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", default="scenarios", choices=list(TABLES))
    ap.add_argument("--ops", type=int, default=0)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    print(f"cost model: {CostModel()}")
    kw = {"n_ops": args.ops} if args.ops else {}
    res = TABLES[args.table](**kw)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1, default=str)


if __name__ == "__main__":
    main()
