"""Simulator-level invariants that mirror the paper's section-level claims
(cheap versions of the benchmark tables, run in CI)."""
import numpy as np

from repro.core.sim import CostModel, run_sim_workload


def _makespan(policy, **kw):
    base = dict(n_ops=6000, n_lbas=65536, cache_slots=1024, iodepth=32)
    base.update(kw)
    return run_sim_workload(policy, **base).counts["makespan_us"]


def test_paper_ordering_btt_dax_raw():
    """§3: time(BTT) > time(DAX) > time(raw PMem)."""
    raw = _makespan("raw")
    dax = _makespan("dax")
    btt = _makespan("btt")
    assert raw < dax < btt
    # and the calibrated ratios stay near the paper's study
    assert 1.25 < btt / raw < 1.55
    assert 1.08 < btt / dax < 1.30


def test_caiti_beats_every_baseline():
    caiti = _makespan("caiti")
    for p in ("btt", "pmbd", "pmbd70", "lru", "coactive"):
        assert caiti < _makespan(p), p


def test_caiti_speedup_in_paper_band():
    """'up to 3.6x' over BTT — calibrated regime should land 2.5-4.5x."""
    ratio = _makespan("btt") / _makespan("caiti")
    assert 2.5 < ratio < 4.5, ratio


def test_fsync_flat_for_caiti_growing_for_staging():
    """Fig 2b: staging fsync cost grows with buffered volume, Caiti ~flat."""
    def fsync_cost(policy, blocks):
        m = run_sim_workload(policy, n_ops=blocks * 3, n_lbas=65536,
                             cache_slots=32768, iodepth=32,
                             fsync_every=blocks)
        return m.breakdown.get("cache_flush", 0.0) / 3
    for policy, grows in (("lru", True), ("pmbd", True), ("caiti", False)):
        small = fsync_cost(policy, 128)
        large = fsync_cost(policy, 4096)
        if grows:
            assert large > small * 8, (policy, small, large)
        else:
            assert large < max(small, 1.0) * 8, (policy, small, large)


def test_caiti_tail_latency_flat_vs_staging_spiky():
    """Fig 3/5d: staging p99.99 >> p50; Caiti's tail stays tight."""
    caiti = run_sim_workload("caiti", n_ops=20000, n_lbas=262144,
                             cache_slots=2048, iodepth=32)
    lru = run_sim_workload("lru", n_ops=20000, n_lbas=262144,
                           cache_slots=2048, iodepth=32)
    assert caiti.pct(99.99) < caiti.pct(50) * 3
    assert lru.pct(99.99) > lru.pct(50) * 10


def test_breakdown_caiti_no_stall_ablations_shift():
    """Fig 6: Caiti has ~0 eviction stalls; w/o EE bypasses; w/o BP stalls
    once fill rate exceeds the eviction pool's drain rate (8 jobs)."""
    full = run_sim_workload("caiti", n_ops=8000, n_lbas=1 << 20,
                            cache_slots=512, iodepth=1)
    noee = run_sim_workload("caiti-noee", n_ops=8000, n_lbas=1 << 20,
                            cache_slots=512, iodepth=1)
    nobp = run_sim_workload("caiti-nobp", n_ops=16000, n_lbas=1 << 20,
                            cache_slots=512, iodepth=32, jobs=8)
    assert full.counts.get("stalls", 0) == 0
    assert full.counts.get("bypass", 0) <= noee.counts.get("bypass", 0)
    assert noee.counts.get("bypass", 0) > 1000
    assert nobp.counts.get("stalls", 0) > 100


def test_cache_size_insensitive_under_overload():
    """Table 1: mean response within a small band across capacities."""
    means = [run_sim_workload("caiti", n_ops=8000, n_lbas=262144,
                              cache_slots=s, iodepth=32).mean()
             for s in (256, 1024, 4096)]
    assert max(means) / min(means) < 1.25, means


def test_jobs_scaling_caiti_stays_ahead():
    """Fig 5e: Caiti leads at low thread counts; at high counts BOTH
    saturate the aggregate PMem bandwidth and converge (the paper's
    throughput curves flatten the same way) — Caiti never loses."""
    for jobs in (1, 4, 16):
        c = _makespan("caiti", jobs=jobs, n_ops=8000)
        b = _makespan("btt", jobs=jobs, n_ops=8000)
        assert c <= b * 1.02, jobs
    assert _makespan("caiti", jobs=1, n_ops=8000) < \
        0.5 * _makespan("btt", jobs=1, n_ops=8000)


def test_media_bandwidth_is_respected():
    """Throughput can never exceed the aggregate PMem bank bandwidth."""
    cost = CostModel()
    m = run_sim_workload("caiti", n_ops=30000, n_lbas=1 << 20,
                         cache_slots=1 << 14, iodepth=256, jobs=8)
    mk_us = m.counts["makespan_us"]
    # every one of the 30k blocks must ultimately cross the media
    min_time = 30000 * cost.btt_write() / cost.n_banks
    assert mk_us > min_time * 0.95, (mk_us, min_time)
