"""Elastic scaling: a checkpoint saved on mesh A restores onto mesh B
(different shape) with identical values — the restart-with-resize path of
a production fleet.  Runs in a subprocess with 8 forced host devices."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str) -> str:
    code = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n" +
            textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


def test_checkpoint_resharded_across_meshes(tmp_path):
    pool = str(tmp_path / "pool.bin")
    _run(f"""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.ckpt import CheckpointEngine, make_blockstore
    from repro.configs import get_config
    from repro.models import build_model
    from repro.parallel import make_ctx, named, param_spec_tree

    cfg = get_config('internlm2-1.8b', smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # save on a (2, 4) mesh
    mesh_a = jax.make_mesh((2, 4), ('data', 'model'))
    shard_a = named(param_spec_tree(jax.eval_shape(lambda: params), mesh_a),
                    mesh_a)
    p_a = jax.device_put(params, shard_a)
    store = make_blockstore({pool!r}, capacity_bytes=512 << 20)
    eng = CheckpointEngine(store)
    eng.save(0, p_a)
    eng.close()

    # restore onto a (4, 2) mesh — different TP degree
    mesh_b = jax.make_mesh((4, 2), ('data', 'model'))
    shard_b = named(param_spec_tree(jax.eval_shape(lambda: params), mesh_b),
                    mesh_b)
    store2 = make_blockstore({pool!r}, capacity_bytes=512 << 20)
    eng2 = CheckpointEngine(store2)
    p_b, step = eng2.restore(like=params, shardings=shard_b)
    eng2.close()
    assert step == 0

    # values identical, shardings follow mesh B
    for la, lb in zip(jax.tree.leaves(params), jax.tree.leaves(p_b)):
        np.testing.assert_array_equal(
            np.asarray(la, np.float32), np.asarray(lb, np.float32))
    leaf_b = jax.tree.leaves(p_b)[0]
    assert leaf_b.sharding.mesh.shape['model'] == 2
    print('elastic reshard OK')
    """)


def test_trainer_resumes_on_resized_mesh(tmp_path):
    """Train 3 steps on mesh (2,4), checkpoint, resume 2 steps on (4,2):
    losses must continue the single-mesh trajectory (data schedule is
    mesh-independent)."""
    pool = str(tmp_path / "pool2.bin")
    _run(f"""
    import jax, jax.numpy as jnp, numpy as np
    from repro.ckpt import CheckpointEngine, make_blockstore
    from repro.configs import get_config
    from repro.data import SyntheticLM
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.train.loop import TrainConfig, Trainer

    cfg = get_config('internlm2-1.8b', smoke=True)
    model = build_model(cfg)
    src = SyntheticLM(cfg.vocab, seq=32, global_batch=8)

    def mk_trainer(eng, steps):
        return Trainer(model, AdamW(lr=1e-3), src, ckpt=eng,
                       cfg=TrainConfig(total_steps=steps, ckpt_every=100,
                                       async_ckpt=False))

    # reference: 5 steps uninterrupted (single device)
    ref = mk_trainer(None, 5).run(jax.random.PRNGKey(0))

    store = make_blockstore({pool!r}, capacity_bytes=512 << 20)
    eng = CheckpointEngine(store)
    out1 = mk_trainer(eng, 3).run(jax.random.PRNGKey(0))
    assert out1['last_step'] == 2
    out2 = mk_trainer(eng, 5).run(jax.random.PRNGKey(0))
    assert out2['last_step'] == 4
    np.testing.assert_allclose(out2['losses'], ref['losses'][3:5],
                               rtol=1e-4, atol=1e-5)
    eng.close()
    print('resume-after-resize OK')
    """)
