"""Async submission/completion frontend: engine semantics, error paths
(per-ticket failures, never stack-wide), deterministic seeded
interleavings via tests/aio_harness.py, the eviction-drain completion
callbacks, the overlapped blockstore/serve integrations, and the
sim-backed queue-depth acceptance claim."""
import threading

import numpy as np
import pytest

from aio_harness import (AsyncRun, VersionedObjects, blk,
                         check_versioned_invariants, fail_shard_writes,
                         random_schedule, run_crash_point,
                         volume_lba_on_shard)
from repro.core import SimulatedCrash
from repro.core.sim import run_aio_sim_workload, SimVolume, CostModel
from repro.volume import (BackpressureError, CancelledError, SubmitError,
                          TenantSpec, make_volume)


# --------------------------------------------------------- engine basics
def test_submit_poll_roundtrip_threaded():
    vol = make_volume("caiti", n_lbas=1024, n_shards=2,
                      cache_bytes=64 * 4096)
    try:
        tw = vol.submit("write", 5, data=blk(7))
        tm = vol.submit("write_multi", 64, blocks=[blk(1 + i)
                                                   for i in range(4)])
        assert tw.result() == 0 and tm.result() == 0
        tr = vol.submit("read", 5)
        assert bytes(tr.result()) == blk(7)
        for i in range(4):
            assert bytes(vol.read(64 + i)) == blk(1 + i)
        # result()/wait() CONSUMED those completions — the ring must not
        # grow for wait()-only consumers
        assert vol.poll() == []
        t2 = vol.submit("write", 6, data=blk(8))
        vol.aio_engine().drain()
        done = vol.poll()                    # un-waited tickets DO poll
        assert [t.tid for t in done] == [t2.tid]
        st = vol.metrics_snapshot()["aio"]
        assert st["completed"] == 4 and st["failed"] == 0
        assert st["open"] == 0 and st["cq_depth"] == 0
    finally:
        vol.close()


def test_inline_mode_is_deterministic_submission_order():
    """n_workers=0: nothing runs until poll(); ops execute inline in
    submission order, one per poll(1) step — the harness's replayable
    schedule."""
    vol = make_volume("caiti", n_lbas=512, n_shards=2,
                      cache_bytes=64 * 4096)
    try:
        eng = vol.aio_engine(n_workers=0)
        a = eng.submit("write", 3, data=blk(1))
        b = eng.submit("write", 3, data=blk(2))
        c = eng.submit("read", 3)
        assert not a.done and not b.done and not c.done
        out = eng.poll(1)
        assert [t.tid for t in out] == [a.tid] and a.ok
        out = eng.poll()                    # runs the rest, in order
        assert [t.tid for t in out] == [b.tid, c.tid]
        assert bytes(c.value) == blk(2)     # b executed before c
    finally:
        vol.close()


def test_inline_wait_stops_at_the_awaited_ticket():
    """REGRESSION: wait()/result() in deterministic mode must not run
    ops submitted AFTER the awaited ticket — the replayable schedule
    advances only as far as the caller asked."""
    vol = make_volume("caiti", n_lbas=512, n_shards=2,
                      cache_bytes=64 * 4096)
    try:
        eng = vol.aio_engine(n_workers=0)
        a = eng.submit("write", 0, data=blk(1))
        b = eng.submit("write", 1, data=blk(2))
        assert eng.wait(a).ok
        assert not b.done                    # b still queued, untouched
        eng.wait(a)                          # already done: no side run
        assert not b.done
        eng.poll()
        assert b.ok
        # a ticket that completes AT the deadline is not a timeout
        c = eng.submit("write", 2, data=blk(3))
        assert eng.wait(c, timeout=0.0).ok
    finally:
        vol.close()


def test_async_fsync_barrier_covers_earlier_chains():
    """An async fsync dispatches only after every earlier ticket
    completed, then checkpoints through the GroupCommitter — the
    applied mark covers the chains submitted before it."""
    vol = make_volume("caiti", n_lbas=1024, n_shards=2,
                      cache_bytes=64 * 4096)
    try:
        eng = vol.aio_engine(n_workers=0)
        tm = eng.submit("write_multi", 8, blocks=[blk(i) for i in range(4)])
        ts = eng.submit("fsync")
        eng.poll()
        assert tm.ok and ts.ok
        assert vol.journal.applied_txid == vol.journal.last_txid() >= 1
        st = vol.metrics_snapshot()
        assert st["group_commit"]["calls"] >= 1
    finally:
        vol.close()


def test_flush_ticket_completes_via_eviction_drain_callbacks():
    """op='flush' never parks a worker in CaitiCache.flush: the ticket
    registers drain waiters and completes from the eviction pool's
    completion path (inline mode has no workers at all, so ONLY the
    callbacks can complete it)."""
    vol = make_volume("caiti", n_lbas=2048, n_shards=2,
                      cache_bytes=1024 * 4096)
    try:
        eng = vol.aio_engine(n_workers=0)
        for lba in range(128):
            vol.write(lba, blk(lba))
        t = eng.submit("flush")
        eng.wait(t, timeout=30.0)
        assert t.ok
        assert vol.occupancy() == 0.0       # everything drained
    finally:
        vol.close()


def test_flush_ticket_drains_staging_configs():
    """REGRESSION: on a no-eager-eviction volume the flush ticket must
    first KICK the queued WBQs (like the blocking flush does) — it used
    to complete with every write still staged in DRAM."""
    vol = make_volume("caiti-noee", n_lbas=2048, n_shards=2,
                      cache_bytes=1024 * 4096)
    try:
        eng = vol.aio_engine(n_workers=0)
        for lba in range(128):
            vol.write(lba, blk(lba))
        assert vol.occupancy() > 0          # noee: parked in transit
        t = eng.submit("flush")
        eng.wait(t, timeout=30.0)
        assert t.ok
        assert vol.occupancy() == 0.0       # really drained, like flush()
    finally:
        vol.close()


def test_cache_drain_waiter_contract():
    """CaitiCache.add_drain_waiter: False (not registered) when already
    drained; otherwise fires exactly once when the backlog enqueued at
    registration time has landed."""
    vol = make_volume("caiti", n_lbas=512, n_shards=1,
                      cache_bytes=256 * 4096)
    try:
        cache = vol.shards[0].impl
        vol.fsync()
        assert cache.add_drain_waiter(lambda: None) is False
        fired = threading.Event()
        for lba in range(64):
            vol.write(lba, blk(lba))
        if cache.add_drain_waiter(fired.set):
            assert fired.wait(10.0)
        else:                               # pool already drained it all
            assert cache._completed >= cache._enqueued
    finally:
        vol.close()


# ----------------------------------------------------------- error paths
def test_journal_ring_overflow_fails_ticket_not_ring():
    """A write_multi exceeding the journal ring fails ITS ticket; the
    ring keeps serving."""
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1,
                      journal_slots=4, journal_span=2)
    try:
        eng = vol.aio_engine(n_workers=0)
        big = eng.submit("write_multi", 0,
                         blocks=[blk(i) for i in range(10)])  # > 8 max
        ok = eng.submit("write_multi", 32, blocks=[blk(i) for i in range(4)])
        eng.poll()
        assert big.done and isinstance(big.error, AssertionError)
        assert "exceeds" in str(big.error)
        assert ok.ok
        assert bytes(vol.read(32)) == blk(0)
        with pytest.raises(AssertionError):
            big.result()
    finally:
        vol.close()


def test_injected_device_error_is_per_ticket():
    """An IOError from one shard's BTT surfaces on the one ticket whose
    op hit it — other tenants' tickets (and later submissions) keep
    completing."""
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1)
    try:
        eng = vol.aio_engine(n_workers=0)
        bad_lba = volume_lba_on_shard(vol, 0)
        good_lba = volume_lba_on_shard(vol, 1)
        inj = fail_shard_writes(vol, 0)
        t_bad = eng.submit("write", bad_lba, data=blk(1), tenant="a")
        t_good = eng.submit("write", good_lba, data=blk(2), tenant="b")
        eng.poll()
        assert isinstance(t_bad.error, IOError)
        assert t_good.ok
        inj["restore"]()
        t_retry = eng.submit("write", bad_lba, data=blk(3), tenant="a")
        eng.poll()
        assert t_retry.ok
        assert bytes(vol.read(bad_lba)) == blk(3)
        st = eng.stats()
        assert st["failed"] == 1 and st["completed"] == 2
    finally:
        vol.close()


def test_submit_after_close_fails_ticket():
    vol = make_volume("caiti", n_lbas=256, n_shards=2,
                      cache_bytes=32 * 4096)
    eng = vol.aio_engine()
    eng.close()
    t = vol.submit("write", 0, data=blk(1))
    assert t.done and isinstance(t.error, SubmitError)
    assert "close" in str(t.error)
    vol.close()


def test_unknown_op_fails_ticket():
    vol = make_volume("caiti", n_lbas=256, n_shards=2,
                      cache_bytes=32 * 4096)
    try:
        t = vol.submit("trim", 0)
        assert t.done and isinstance(t.error, SubmitError)
    finally:
        vol.close()


def test_cancel_queued_ticket_but_not_dispatched():
    vol = make_volume("caiti", n_lbas=256, n_shards=2,
                      cache_bytes=32 * 4096)
    try:
        eng = vol.aio_engine(n_workers=0)
        a = eng.submit("write", 0, data=blk(1))
        b = eng.submit("write", 1, data=blk(2))
        assert eng.cancel(b) is True
        assert isinstance(b.error, CancelledError)
        eng.poll()
        assert a.ok
        assert eng.cancel(a) is False       # already executed
        assert eng.stats()["cancelled"] == 1
        # cancelled write really never ran
        assert bytes(vol.read(0)) == blk(1)
        assert bytes(vol.read(1)) != blk(2)
    finally:
        vol.close()


def test_tenant_over_inflight_bound_fails_ticket_not_deadlock():
    """A tenant exceeding its in-flight window gets a FAILED ticket
    immediately — the submit never blocks and the ring never deadlocks;
    another tenant's window is unaffected; completions reopen the
    window."""
    vol = make_volume("caiti", n_lbas=512, n_shards=2,
                      cache_bytes=64 * 4096,
                      tenants=[TenantSpec("a"), TenantSpec("b")])
    try:
        eng = vol.aio_engine(n_workers=0, max_inflight_per_tenant=2)
        t1 = eng.submit("write", 0, data=blk(1), tenant="a")
        t2 = eng.submit("write", 1, data=blk(2), tenant="a")
        t3 = eng.submit("write", 2, data=blk(3), tenant="a")   # over bound
        assert t3.done and isinstance(t3.error, BackpressureError)
        assert "in-flight bound" in str(t3.error)
        tb = eng.submit("write", 3, data=blk(4), tenant="b")   # b unaffected
        assert not tb.done
        eng.poll()                           # completions reopen the window
        assert t1.ok and t2.ok and tb.ok
        t4 = eng.submit("write", 2, data=blk(5), tenant="a")
        eng.poll()
        assert t4.ok
    finally:
        vol.close()


def test_aio_engine_mode_conflict_asserts():
    """Requesting a mode that contradicts the live engine must fail
    loudly — the crash harness depends on really getting inline mode."""
    vol = make_volume("caiti", n_lbas=256, n_shards=2,
                      cache_bytes=32 * 4096)
    try:
        vol.aio_engine(n_workers=2)
        vol.aio_engine()                     # no explicit ask: fine
        vol.aio_engine(n_workers=2)          # matching ask: fine
        with pytest.raises(AssertionError, match="workers"):
            vol.aio_engine(n_workers=0)
    finally:
        vol.close()


def test_blocking_submit_waits_out_window():
    """submit(block=True): the in-flight bound becomes blocking
    backpressure — in deterministic mode the submitter executes queued
    ops itself to make room, and the op is never refused."""
    vol = make_volume("caiti", n_lbas=512, n_shards=2,
                      cache_bytes=64 * 4096)
    try:
        eng = vol.aio_engine(n_workers=0, max_inflight_per_tenant=2)
        t1 = eng.submit("write", 0, data=blk(1))
        t2 = eng.submit("write", 1, data=blk(2))
        t3 = eng.submit("write", 2, data=blk(3), block=True)
        assert t1.ok                        # executed to free the window
        assert not t3.done or t3.error is None
        eng.poll()
        assert t2.ok and t3.ok
        assert eng.stats()["failed"] == 0   # refusals never surfaced
    finally:
        vol.close()


def test_threaded_backpressure_never_deadlocks():
    """Threaded mode under a flood: over-bound submits fail fast, every
    in-bound ticket completes, the ring drains."""
    vol = make_volume("caiti", n_lbas=2048, n_shards=2,
                      cache_bytes=256 * 4096)
    try:
        eng = vol.aio_engine(n_workers=2, max_inflight_per_tenant=8)
        tickets = [eng.submit("write", i, data=blk(i), tenant="t")
                   for i in range(64)]
        refused = [t for t in tickets if t.done
                   and isinstance(t.error, SubmitError)]
        eng.drain(timeout=30.0)
        served = [t for t in tickets if t.ok]
        assert len(refused) + len(served) == 64
        assert served                        # some really went through
        for t in served:
            assert t.error is None
    finally:
        vol.close()


# --------------------------------------------- seeded interleavings (harness)
@pytest.mark.parametrize("seed", range(6))
def test_seeded_interleaving_clean_run_invariants(seed):
    """Seeded submit/poll/sync/fsync interleavings with no crash: every
    object reads back whole at its final version, nothing completed is
    lost."""
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1,
                      journal_slots=16, journal_span=2)
    try:
        objs = VersionedObjects(n_objects=3, n_blocks=4, stride=16)
        objs.write_base(vol)
        rng = np.random.default_rng(seed)
        run = AsyncRun(vol).run(random_schedule(rng, objs, n_steps=24))
        check_versioned_invariants(objs, run, vol, crashed=False)
    finally:
        vol.close()


@pytest.mark.parametrize("seed", range(3))
def test_seeded_interleaving_crash_recovery_invariants(tmp_path, seed):
    """Seeded interleavings + a crash at seeded write points: after
    reopen+recovery every object is whole (never torn) and no completed
    ticket (or returned sync write) is rolled back."""
    kw = dict(policy="btt", n_lbas=256, n_shards=2, stripe_blocks=1,
              journal_slots=16, journal_span=2, backend="file")
    rng = np.random.default_rng(1000 + seed)
    points = sorted(set(int(p) for p in rng.integers(1, 120, size=4)))
    for p in points:
        cell = {}

        def prep(vol):
            cell["objs"] = VersionedObjects(n_objects=3, n_blocks=4,
                                            stride=16)
            cell["objs"].write_base(vol)

        def sched():
            srng = np.random.default_rng(seed)
            return random_schedule(srng, cell["objs"], n_steps=24)

        done, crashed, run, vol2 = run_crash_point(
            str(tmp_path / f"s{seed}p{p}"), p, sched, vol_kw=kw,
            prep_fn=prep)
        try:
            check_versioned_invariants(cell["objs"], run, vol2, crashed)
        finally:
            vol2.close()


def test_crash_mid_poll_fails_queued_tickets_and_kills_ring(tmp_path):
    """Power loss inside an async chain: the crash propagates from
    poll() (the machine died), queued tickets fail, later submits are
    refused — no half-alive ring."""
    path = str(tmp_path / "dead")
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1,
                      backend="file", path=path)
    eng = vol.aio_engine(n_workers=0)
    from aio_harness import crash_on_nth_btt_write
    crash_on_nth_btt_write(vol, 3)
    a = eng.submit("write_multi", 8, blocks=[blk(i) for i in range(4)])
    b = eng.submit("write", 64, data=blk(9))
    with pytest.raises(SimulatedCrash):
        eng.poll()
    assert isinstance(a.error, SimulatedCrash)
    assert isinstance(b.error, SubmitError)          # queued: ring died
    t = eng.submit("write", 65, data=blk(1))
    assert isinstance(t.error, SubmitError)


# ----------------------------------------------------- integration paths
def test_blockstore_overlapped_puts_and_gets(tmp_path):
    from repro.ckpt.blockstore import make_blockstore
    path = str(tmp_path / "store")
    kw = dict(policy="caiti", capacity_bytes=16 << 20,
              cache_bytes=4 << 20, n_shards=2, aio=True)
    st = make_blockstore(path, **kw)
    assert st._aio
    payload = np.random.default_rng(3).integers(
        0, 256, size=200_000, dtype=np.uint8).tobytes()
    st.put("x", payload)
    st.put("y", b"tiny")
    assert st.get("x") == payload            # settles in-flight puts
    gen = st.commit()
    st.close()
    st2 = make_blockstore(path, **kw)
    assert st2.generation == gen
    assert st2.get("x") == payload
    assert st2.get("y") == b"tiny"
    # flow-control probes (window-full refusals) are NOT failures: a
    # clean restore leaves the per-ticket failure metric at zero
    assert st2.dev.metrics_snapshot()["aio"]["failed"] == 0
    st2.close()


def test_blockstore_close_surfaces_inflight_put_errors():
    """REGRESSION: closing an aio store with a failed in-flight put must
    raise (the sync path raises in put()) — and settle every sibling
    ticket so nothing foreign lingers on the shared completion ring."""
    from repro.ckpt.blockstore import BlockStore
    vol = make_volume("btt", n_lbas=4096, n_shards=2, stripe_blocks=1)
    st = BlockStore(vol, 4096, aio=True)
    inj = fail_shard_writes(vol, 0)
    st.put("k", b"x" * 20_000)               # blocks land on both shards
    with pytest.raises(IOError):
        st.close()
    assert vol.poll() == []                  # siblings consumed
    # the failed put's key must not stay readable (torn blocks): the
    # sync path never registers a failed key either
    assert "k" not in st.directory
    inj["restore"]()
    vol.close()


def test_serve_async_request_log_roundtrip():
    """AsyncRequestLog: retired-request records ride the async frontend
    overlapped with the caller, drain() settles + fsyncs, and the log
    reads back record for record."""
    import json
    from repro.serve.engine import AsyncRequestLog
    vol = make_volume("caiti", n_lbas=2048, n_shards=2,
                      cache_bytes=64 * 4096)
    try:
        log = AsyncRequestLog(vol)
        recs = [{"req_id": i, "prompt": [1, 2, i], "tokens": [4] * (i + 1)}
                for i in range(8)]
        for r in recs:
            log.append(r)
        assert log.drain() == 0
        lba = 0
        for want in recs:
            raw = bytes(vol.read(lba))
            n = int.from_bytes(raw[:4], "little")
            buf = raw[4:]
            blocks = 1
            while len(buf) < n:
                buf += bytes(vol.read(lba + blocks))
                blocks += 1
            assert json.loads(buf[:n].decode()) == want
            lba += blocks
    finally:
        vol.close()


def test_request_log_backpressure_never_drops_records():
    """REGRESSION: a retirement burst deeper than the engine's in-flight
    window must settle oldest-first and retry — never silently drop a
    record — and wait()-consumed completions keep the ring empty."""
    import json
    from repro.serve.engine import AsyncRequestLog
    vol = make_volume("caiti", n_lbas=2048, n_shards=2,
                      cache_bytes=64 * 4096)
    try:
        vol.aio_engine(n_workers=2, max_inflight_per_tenant=4)
        log = AsyncRequestLog(vol)
        recs = [{"req_id": i, "tokens": [i] * 8} for i in range(32)]
        for r in recs:                       # 32 >> window of 4
            log.append(r)
        assert log.logged == 32
        assert log.drain() == 0 and not log.errors
        assert vol.poll() == []              # ring fully consumed
        lba = 0
        for want in recs:
            raw = bytes(vol.read(lba))
            n = int.from_bytes(raw[:4], "little")
            assert json.loads(raw[4:4 + n].decode()) == want
            lba += 1
    finally:
        vol.close()


def test_request_log_is_a_ring_and_never_overruns_the_volume():
    """REGRESSION: the log allocates from a bounded ring — a serve loop
    retiring more records than the capacity wraps (overwriting oldest)
    instead of writing past the volume and failing every ticket."""
    import json
    from repro.serve.engine import AsyncRequestLog
    vol = make_volume("caiti", n_lbas=256, n_shards=2,
                      cache_bytes=64 * 4096)
    try:
        log = AsyncRequestLog(vol, capacity_blocks=8)
        recs = [{"req_id": i} for i in range(30)]
        for r in recs:
            log.append(r)
        assert log.drain() == 0 and not log.errors
        assert log.wraps >= 3
        # the ring's current generation reads back intact
        raw = bytes(vol.read((30 - 1) % 8))  # 1 block/record, base 0
        n = int.from_bytes(raw[:4], "little")
        assert json.loads(raw[4:4 + n].decode()) == recs[-1]
    finally:
        vol.close()


def test_serve_engine_wires_request_log():
    """ServeEngine._retire appends to the log and run() drains it."""
    from repro.serve.engine import AsyncRequestLog, Request, ServeEngine
    vol = make_volume("caiti", n_lbas=1024, n_shards=2,
                      cache_bytes=64 * 4096)
    try:
        log = AsyncRequestLog(vol)
        eng = ServeEngine.__new__(ServeEngine)   # no model needed here
        eng.request_log = log
        eng.finished = []

        class _Cache:
            def deactivate(self, sid):
                pass

            def release(self, sid):
                pass

        eng.cache = _Cache()
        req = Request(0, [1, 2, 3])
        req.out_tokens = [7, 8]
        eng._retire(req)
        assert log.logged == 1
        assert log.drain() == 0
    finally:
        vol.close()


# ------------------------------------------------------------ sim claims
def test_sim_volume_submit_poll_semantics():
    vol = SimVolume("caiti", CostModel(), n_shards=2, cache_slots=512,
                    aio_workers=2)
    t1 = vol.submit(0.0, "write", 10)
    t2 = vol.submit(0.0, "write", 20)
    d1, d2 = vol.complete_time(t1), vol.complete_time(t2)
    assert d1 > 0 and d2 > 0
    assert vol.poll(min(d1, d2) - 1e-6) == []    # neither complete yet
    done = vol.poll(max(d1, d2))
    assert sorted(done) == sorted([t1, t2])      # both retired, exactly
    assert vol.poll(1e9) == []                   # ring drained
    assert vol.counts()["aio_submits"] == 2


def test_sim_aio_qd8_speedup_acceptance():
    """ACCEPTANCE: the async frontend at queue depth 8 sustains >= 1.5x
    the ops/s of depth 1 with 4 tenants — submission batching +
    overlap across engine cores and shard DIMM banks."""
    kw = dict(n_shards=4, n_lbas=262144, cache_slots=8192, n_workers=16,
              tenants=[{"name": f"t{j}", "n_ops": 2000} for j in range(4)])
    r1 = run_aio_sim_workload("caiti", qdepth=1, **kw)
    r8 = run_aio_sim_workload("caiti", qdepth=8, **kw)
    assert r8["ops_s"] >= 1.5 * r1["ops_s"], (r1["ops_s"], r8["ops_s"])
    # depth also helps end-to-end bytes, not just op accounting
    assert r8["agg_mb_s"] > r1["agg_mb_s"]


def test_sim_aio_qd_monotone_through_8():
    """More depth never hurts through the acceptance point (the window
    is the only knob changing)."""
    kw = dict(n_shards=4, n_lbas=262144, cache_slots=8192, n_workers=16,
              tenants=[{"name": f"t{j}", "n_ops": 1200} for j in range(4)])
    prev = 0.0
    for qd in (1, 2, 4, 8):
        r = run_aio_sim_workload("caiti", qdepth=qd, **kw)
        assert r["ops_s"] >= prev * 0.98, (qd, prev, r["ops_s"])
        prev = r["ops_s"]
