"""Zero-copy data plane: registered buffer pools (pin instead of copy,
copy-on-evict, cancel-releases-buffers), IO_LINK ticket chains (in-order
completion, ECANCELED cascade, crash sweep at every link boundary), the
linked blockstore commit, and the sim-backed acceptance floors
(zerocopy >= 1.2x copying at qd=8, fused transit >= 1.3x three-pass)."""
import numpy as np
import pytest

from aio_harness import (AsyncRun, blk, check_chain_invariants,
                         crash_sweep, fail_shard_writes,
                         volume_lba_on_shard)
from repro.volume import (CancelledError, LinkCancelledError, make_volume)
from repro.core.sim import run_aio_sim_workload, run_transit_sim_workload


# ------------------------------------------------- registered buffer pool
def test_registered_write_pins_instead_of_copying():
    """A registered buffer rides to the media without a staging copy;
    completion releases it back to the pool, and the engine counters
    (mirrored into the volume's Metrics) record the avoided copy."""
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1)
    try:
        eng = vol.aio_engine(n_workers=0)
        reg = vol.register_buffers(4)
        buf = reg.acquire()
        buf.data[:] = 7
        assert reg.free_count() == 3
        t = eng.submit("write", 9, data=buf)
        assert reg.stats()["pinned"] == 1      # pinned, not copied
        eng.poll()
        assert t.ok
        assert bytes(vol.read(9)) == blk(7)
        assert reg.free_count() == 4           # completion released it
        st = eng.stats()
        assert st["copies_avoided"] == 1 and st["staging_copies"] == 0
        assert st["bytes_pinned"] == vol.block_size
        zc = vol.scrub()["zerocopy"]
        assert zc["copies_avoided"] == 1
        assert zc["registry"]["copy_on_evict"] == 0
        assert vol.metrics.zerocopy_path()["pin_rate"] == 1.0
    finally:
        vol.close()


def test_unregistered_mutable_payload_snapshots_at_submit():
    """An unregistered numpy payload is snapshotted under the engine
    lock — the caller scribbling on it after submit must not tear the
    write (and the copy is counted as a staging copy)."""
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1)
    try:
        eng = vol.aio_engine(n_workers=0)
        arr = np.full(vol.block_size, 5, np.uint8)
        t = eng.submit("write", 3, data=arr)
        arr[:] = 99                            # after submit, before poll
        eng.poll()
        assert t.ok
        assert bytes(vol.read(3)) == blk(5)    # the SNAPSHOT landed
        st = eng.stats()
        assert st["staging_copies"] == 1 and st["copies_avoided"] == 0
    finally:
        vol.close()


def test_copy_on_evict_when_caller_reuses_slot_before_durability():
    """Exhausting the pool steals the oldest still-QUEUED pinned buffer:
    its payload snapshots into the ticket (the write stays correct) and
    the slot is reused — the only copy on the zero-copy path, paid only
    for early slot reuse."""
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1)
    try:
        eng = vol.aio_engine(n_workers=0)
        reg = vol.register_buffers(2)
        tickets = []
        for i in range(4):                     # 4 writes through 2 buffers
            buf = reg.acquire()
            buf.data[:] = 10 + i
            tickets.append(eng.submit("write", i, data=buf))
        assert reg.stats()["copy_on_evict"] == 2
        eng.poll()
        for i, t in enumerate(tickets):
            assert t.ok
            assert bytes(vol.read(i)) == blk(10 + i)   # steals didn't tear
        assert reg.free_count() == 2
        st = eng.stats()
        assert st["copies_avoided"] == 4 and st["staging_copies"] == 2
    finally:
        vol.close()


def test_read_lands_directly_in_registered_out_buffer():
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1)
    try:
        eng = vol.aio_engine(n_workers=0)
        vol.write(17, blk(42))
        reg = vol.register_buffers(2)
        buf = reg.acquire()
        t = eng.submit("read", 17, out=buf)
        eng.poll()
        assert t.ok
        assert bytes(buf.data) == blk(42)      # landed in the caller's array
        assert reg.free_count() == 2           # released after completion
        # plain caller-owned arrays work as landing targets too
        out = np.zeros(vol.block_size, np.uint8)
        t2 = eng.submit("read", 17, out=out)
        eng.poll()
        assert t2.ok and bytes(out) == blk(42)
    finally:
        vol.close()


def test_cancel_mid_chain_releases_buffers_and_cascades():
    """Satellite 3: cancelling a still-queued pinned write returns its
    registered buffer to the pool from the completion path and fails
    every linked dependent with ECANCELED — no leaked pins, no silently
    dropped dependents."""
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1)
    try:
        eng = vol.aio_engine(n_workers=0)
        reg = vol.register_buffers(2)
        buf = reg.acquire()
        buf.data[:] = 1
        w = eng.submit("write", 0, data=buf)
        f = eng.submit("fsync", link_to=w)
        r = eng.submit("read", 0, link_to=f)
        assert reg.free_count() == 1
        assert eng.cancel(w) is True
        assert isinstance(w.error, CancelledError)
        assert isinstance(f.error, LinkCancelledError)
        assert isinstance(r.error, LinkCancelledError)
        assert reg.free_count() == 2           # pin released by the cancel
        eng.poll()
        assert bytes(vol.read(0)) != blk(1)    # cancelled write never ran
        assert eng.stats()["link_cancelled"] == 2
    finally:
        vol.close()


def test_sync_write_surfaces_accept_registered_handles():
    """``StripedVolume.write`` / ``write_multi`` unwrap RegisteredBuf
    handles — a caller can point its pinned pool buffers at the sync
    path without manually dereferencing ``.data``."""
    vol = make_volume("btt", n_lbas=64, n_shards=2, stripe_blocks=1)
    try:
        reg = vol.register_buffers(2)
        a, b = reg.acquire(), reg.acquire()
        a.data[:] = 21
        b.data[:] = 22
        vol.write(0, a)
        vol.write_multi(1, [b, a])
        assert bytes(vol.read(0)) == blk(21)
        assert bytes(vol.read(1)) == blk(22)
        assert bytes(vol.read(2)) == blk(21)
    finally:
        vol.close()


def test_request_log_registered_pool_pins_block_lists():
    """write_multi block lists from a caller OTHER than the blockstore
    ride pinned buffers: the serve-plane request log appends through its
    registered pool, the engine avoids the staging copies, every buffer
    returns to the pool once the tickets settle, and the records read
    back intact."""
    import json
    from repro.serve.engine import AsyncRequestLog
    vol = make_volume("caiti", n_lbas=2048, n_shards=2,
                      cache_bytes=64 * 4096)
    try:
        log = AsyncRequestLog(vol, registered_buffers=4)
        recs = [{"req_id": i, "tokens": [i] * 3000} for i in range(6)]
        for r in recs:
            log.append(r)
        assert log.drain() == 0 and not log.errors
        st = vol.aio_engine().stats()
        assert st["copies_avoided"] >= len(recs)   # blocks pinned, not staged
        reg = log._reg
        assert reg.free_count() == len(reg)        # nothing leaked
        lba = 0
        for want in recs:
            raw = bytes(vol.read(lba))
            n = int.from_bytes(raw[:4], "little")
            buf = raw[4:]
            blocks = 1
            while len(buf) < n:
                buf += bytes(vol.read(lba + blocks))
                blocks += 1
            assert json.loads(buf[:n].decode()) == want
            lba += blocks
    finally:
        vol.close()


# ----------------------------------------------------- linked SQE chains
def test_linked_chain_executes_in_order_without_poll_roundtrips():
    """write -> fsync -> read-back submitted as ONE chain: the engine
    sequences them internally (no poll round-trip between links) and the
    read observes the linked write."""
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1)
    try:
        run = AsyncRun(vol)
        run.run([
            ("submit_write", "w", 8, blk(11)),
            ("link_fsync", "f", "w"),
            ("link_read", "r", "f", 8),
            ("poll", None),
        ])
        assert run.ok_tickets() == {"w", "f", "r"}
        assert bytes(run.tickets["r"].value) == blk(11)
        assert run.completion_order.index("w") \
            < run.completion_order.index("f") \
            < run.completion_order.index("r")
        st = run.eng.stats()
        assert st["links_submitted"] == 2
        assert st["link_depth_max"] == 2
    finally:
        vol.close()


def test_failed_link_cancels_chain_never_silently_drops():
    """A device error on the chain head fails the head with the REAL
    error and every dependent with ECANCELED — all of them surface on
    the completion ring; an unrelated ticket is untouched."""
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1)
    try:
        eng = vol.aio_engine(n_workers=0)
        bad = volume_lba_on_shard(vol, 0)
        good = volume_lba_on_shard(vol, 1)
        inj = fail_shard_writes(vol, 0)
        w = eng.submit("write", bad, data=blk(1))
        f = eng.submit("fsync", link_to=w)
        r = eng.submit("read", bad, link_to=f)
        other = eng.submit("write", good, data=blk(2))
        done = eng.poll()
        assert {t.tid for t in done} \
            == {w.tid, f.tid, r.tid, other.tid}    # real CQEs, none dropped
        assert isinstance(w.error, IOError)
        assert isinstance(f.error, LinkCancelledError)
        assert isinstance(r.error, LinkCancelledError)
        assert other.ok
        assert eng.stats()["link_cancelled"] == 2
        inj["restore"]()
        # the ring is still alive: a fresh chain on the same lba works
        w2 = eng.submit("write", bad, data=blk(3))
        r2 = eng.submit("read", bad, link_to=w2)
        eng.poll()
        assert w2.ok and r2.ok and bytes(r2.value) == blk(3)
    finally:
        vol.close()


def test_link_to_already_completed_parent():
    """Linking to a parent that already finished is legal: an OK parent
    gates nothing, a FAILED parent cancels the child at submit — but
    still as a ring completion, never an exception from submit()."""
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1)
    try:
        eng = vol.aio_engine(n_workers=0)
        ok_parent = eng.submit("write", 1, data=blk(4))
        eng.poll()
        assert ok_parent.ok
        child = eng.submit("read", 1, link_to=ok_parent)
        eng.poll()
        assert child.ok and bytes(child.value) == blk(4)

        inj = fail_shard_writes(vol, 0)
        bad = volume_lba_on_shard(vol, 0)
        failed_parent = eng.submit("write", bad, data=blk(5))
        eng.poll()
        assert isinstance(failed_parent.error, IOError)
        orphan = eng.submit("read", bad, link_to=failed_parent)
        assert isinstance(orphan.error, LinkCancelledError)
        assert orphan.tid in {t.tid for t in eng.poll()}   # real CQE
        inj["restore"]()
    finally:
        vol.close()


def test_linked_chain_crash_sweep(tmp_path):
    """Satellite 1: crash at EVERY BTT write point under two interleaved
    write -> fsync -> read-verify chains.  At every crash point:
    dependents never complete before their parent, a failed link
    cancels (never silently drops) its chain, and a chain whose linked
    fsync completed OK is durable across recovery."""
    kw = dict(policy="btt", n_lbas=256, n_shards=2, stripe_blocks=1,
              journal_slots=16, journal_span=2, backend="file")
    chains = [["w1", "f1", "r1"], ["w2", "f2", "r2"]]

    def sched():
        return [
            ("submit_write", "w1", 8, blk(11)),
            ("link_fsync", "f1", "w1"),
            ("link_read", "r1", "f1", 8),
            ("submit_multi", "w2", 32, [blk(21 + i) for i in range(3)]),
            ("link_fsync", "f2", "w2"),
            ("link_read", "r2", "f2", 32),
            ("poll", None),
        ]

    def check(n, done, crashed, run, vol2):
        check_chain_invariants(run, chains)
        t = run.tickets
        if "r1" in run.ok_tickets():
            assert bytes(t["r1"].value) == blk(11)
        if "r2" in run.ok_tickets():
            assert bytes(t["r2"].value) == blk(21)
        # linked-fsync durability: an OK barrier pins its chain's write
        if "f1" in run.ok_tickets():
            assert bytes(vol2.read(8)) == blk(11)
        if "f2" in run.ok_tickets():
            for i in range(3):
                assert bytes(vol2.read(32 + i)) == blk(21 + i)

    points = crash_sweep(tmp_path, sched, check, vol_kw=kw)
    assert points > 3          # the sweep really visited link boundaries


def test_blockstore_linked_commit_roundtrip(tmp_path):
    """The aio blockstore commit rides IO_LINK chains (write -> fsync
    barriers sequenced in-engine): a reopened store sees the committed
    generation, and the zero-copy counters show the linked chain +
    pinned put payloads."""
    from repro.ckpt.blockstore import make_blockstore
    path = str(tmp_path / "store")
    kw = dict(policy="caiti", capacity_bytes=16 << 20,
              cache_bytes=4 << 20, n_shards=2, aio=True)
    st = make_blockstore(path, **kw)
    payload = np.random.default_rng(7).integers(
        0, 256, size=150_000, dtype=np.uint8).tobytes()
    st.put("a", payload)
    st.put("b", b"small")
    gen = st.commit()
    zc = st.dev.scrub()["zerocopy"]
    assert zc["links_submitted"] >= 1          # commit chained in-engine
    assert zc["copies_avoided"] >= 1           # puts pinned, not staged
    st.close()
    st2 = make_blockstore(path, **kw)
    assert st2.generation == gen
    assert st2.get("a") == payload
    assert st2.get("b") == b"small"
    st2.close()


# ------------------------------------------------- fused transit kernel
# Deterministic twin of the hypothesis property in test_kernels.py (that
# module skips wholesale when hypothesis is absent — this sweep keeps
# the fused-kernel equivalence in tier-1 either way).
@pytest.mark.parametrize("P,page,F,seed", [
    (6, 8, 64, 0), (8, 16, 128, 1), (4, 32, 96, 2),
])
def test_fused_transit_kernel_matches_three_pass(P, page, F, seed):
    """Fused crc+quantize+gather (one Pallas pass) vs the three-pass
    composition: q and crc bit-identical, scales/dequant allclose, crc
    pinned to zlib.adler32 — interpret=True AND the jitted wrappers."""
    import zlib
    import jax.numpy as jnp
    from repro.kernels import (gather_quantize_crc, scatter_dequantize_crc)
    from repro.kernels import ref
    from repro.kernels.block_transit import (
        gather_quantize_crc_pallas, scatter_dequantize_crc_pallas)

    rng = np.random.default_rng(seed)
    pool = jnp.asarray(rng.standard_normal((P, page, F)), jnp.float32)
    ids = jnp.asarray(rng.permutation(P)[:3], jnp.int32)

    qr, sr = ref.gather_quantize_ref(pool, ids)
    crc_r = ref.transit_crc_ref(qr)
    for pi, crc in zip(np.asarray(qr), crc_r):
        assert int(crc) == zlib.adler32(pi.tobytes())

    for q, sc, crc in (
            gather_quantize_crc_pallas(pool, ids, interpret=True),
            gather_quantize_crc(pool, ids)):
        assert np.array_equal(np.asarray(q), np.asarray(qr))
        np.testing.assert_allclose(np.asarray(sc), np.asarray(sr),
                                   rtol=1e-6)
        assert np.array_equal(np.asarray(crc), crc_r)

    exp = ref.scatter_dequantize_ref(jnp.zeros_like(pool), ids, qr, sr)
    for new_pool, crc in (
            scatter_dequantize_crc_pallas(jnp.zeros_like(pool), ids,
                                          qr, sr, interpret=True),
            scatter_dequantize_crc(jnp.zeros_like(pool), ids, qr, sr)):
        assert np.array_equal(np.asarray(crc), crc_r)
        np.testing.assert_allclose(np.asarray(new_pool), np.asarray(exp),
                                   atol=1e-6, rtol=1e-6)


# -------------------------------------------------- sim acceptance floors
def test_sim_zerocopy_qd8_acceptance():
    """Registered-buffer pinning vs copy-at-submit through the virtual-
    time engine: at qd=8 with 4 tenants the zero-copy plane must clear
    the 1.2x CI floor (the staging memcpy serializes under the engine
    lock; pinning removes it)."""
    tenants = [{"name": f"t{j}", "n_ops": 400} for j in range(4)]
    kw = dict(n_shards=4, n_lbas=65536, cache_slots=2048, n_workers=8,
              qdepth=8)
    copy = run_aio_sim_workload("caiti", copy_mode="copy",
                                tenants=tenants, **kw)
    zero = run_aio_sim_workload("caiti", copy_mode="zerocopy",
                                tenants=tenants, **kw)
    assert copy["counts"]["staging_copies"] == 1600
    assert zero["counts"]["copies_avoided"] == 1600
    assert zero["ops_s"] / copy["ops_s"] >= 1.2


def test_sim_zerocopy_contrast_grows_with_queue_depth():
    """The staging copy is a lock-held serial cost, so its tax grows
    with concurrency: the zerocopy/copy ratio at qd=8 must exceed the
    qd=1 ratio (at qd=1 there is nothing to serialize against)."""
    tenants = [{"name": f"t{j}", "n_ops": 300} for j in range(4)]
    kw = dict(n_shards=4, n_lbas=65536, cache_slots=2048, n_workers=8)
    ratios = {}
    for qd in (1, 8):
        copy = run_aio_sim_workload("caiti", copy_mode="copy", qdepth=qd,
                                    tenants=tenants, **kw)
        zero = run_aio_sim_workload("caiti", copy_mode="zerocopy",
                                    qdepth=qd, tenants=tenants, **kw)
        ratios[qd] = zero["ops_s"] / copy["ops_s"]
    assert ratios[8] > ratios[1] >= 1.0


def test_sim_fused_transit_acceptance():
    """One fused pass (crc + quantize + gather) vs the three-pass
    composition over the same pages: >= 1.3x pages/s (CI floor), with
    the identical PMem DMA cost on both sides — the win is pure pass
    elimination."""
    three = run_transit_sim_workload(n_pages=2000, fused=False)
    fused = run_transit_sim_workload(n_pages=2000, fused=True)
    assert three["passes_per_page"] == 3
    assert fused["passes_per_page"] == 1
    assert fused["pages_s"] / three["pages_s"] >= 1.3
    assert fused["mb_s"] > three["mb_s"]
