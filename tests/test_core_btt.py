"""BTT: CoW write atomicity, Flog recovery, persistence."""
import os
import threading

import numpy as np
import pytest

from repro.core import BTT, PMemSpace, SimulatedCrash


def _blk(x: int, size: int = 4096) -> bytes:
    return bytes([x % 256]) * size


def test_write_read_roundtrip():
    pmem = PMemSpace(128, block_size=4096)
    btt = BTT(pmem, n_lbas=64, nfree=4)
    for lba in range(16):
        btt.write(lba, _blk(lba + 1))
    for lba in range(16):
        assert bytes(btt.read(lba)) == _blk(lba + 1)


def test_unwritten_reads_zero():
    pmem = PMemSpace(128)
    btt = BTT(pmem, n_lbas=64, nfree=4)
    assert bytes(btt.read(5)) == b"\x00" * 4096


def test_overwrite_is_out_of_place():
    """CoW: the pba backing an lba changes on every write."""
    pmem = PMemSpace(128)
    btt = BTT(pmem, n_lbas=64, nfree=4)
    btt.write(7, _blk(1))
    p1 = btt._load_map(7)
    btt.write(7, _blk(2))
    p2 = btt._load_map(7)
    assert p1 != p2
    assert bytes(btt.read(7)) == _blk(2)


def test_recovery_rolls_forward_lost_map_commit():
    """Crash between flog append and map update: recovery redoes the map
    (kernel btt_freelist_init semantics — data was fully persisted)."""
    pmem = PMemSpace(128)
    btt = BTT(pmem, n_lbas=64, nfree=2)
    btt.write(3, _blk(9))
    # manually simulate: flog written for a NEW write, map not updated
    lane = 0
    free = btt._lane_free[lane]
    pmem.write_block(btt._data_base + free, np.frombuffer(_blk(10), np.uint8))
    seq = btt._lane_seq[lane] + 1
    old = btt._load_map(3)
    btt._write_flog(lane, seq % 2, 3, old, free, seq)
    # CRASH here: map never updated. Recover on a fresh driver:
    btt2 = BTT(pmem, n_lbas=64, fresh=False)
    assert btt2.recovery_stats["redone_lanes"] >= 1
    assert bytes(btt2.read(3)) == _blk(10)      # rolled forward


def test_recovery_keeps_committed_state():
    pmem = PMemSpace(128)
    btt = BTT(pmem, n_lbas=64, nfree=4)
    for lba in range(8):
        btt.write(lba, _blk(lba + 100))
    btt2 = BTT(pmem, n_lbas=64, fresh=False)
    btt2.recover()
    for lba in range(8):
        assert bytes(btt2.read(lba)) == _blk(lba + 100)


def test_torn_data_write_never_visible():
    """A crash mid data-copy leaves the OLD block intact (the free block
    took the torn write; map still points at the old pba)."""
    pmem = PMemSpace(128)
    btt = BTT(pmem, n_lbas=64, nfree=2)
    btt.write(11, _blk(1))

    calls = {"n": 0}

    def crash_mid(label):
        if label == "pmem_write_mid":
            calls["n"] += 1
            raise SimulatedCrash(label)

    pmem.crash_hook = crash_mid
    with pytest.raises(SimulatedCrash):
        btt.write(11, _blk(2))
    pmem.crash_hook = None
    btt2 = BTT(pmem, n_lbas=64, fresh=False)
    btt2.recover()
    assert bytes(btt2.read(11)) == _blk(1)      # old data intact
    assert calls["n"] == 1


def test_file_backed_persistence(tmp_path):
    path = str(tmp_path / "pool.bin")
    pmem = PMemSpace(128, backend="file", path=path)
    btt = BTT(pmem, n_lbas=64, nfree=4)
    btt.write(5, _blk(42))
    btt.flush()
    pmem.close()
    pmem2 = PMemSpace(128, backend="file", path=path)
    btt2 = BTT(pmem2, n_lbas=64, fresh=False)
    assert bytes(btt2.read(5)) == _blk(42)
    pmem2.close()


def test_concurrent_writers_distinct_lbas():
    pmem = PMemSpace(600)
    btt = BTT(pmem, n_lbas=512, nfree=8)
    errs = []

    def worker(base):
        try:
            for i in range(40):
                btt.write(base + i, _blk(base + i))
        except BaseException as e:
            errs.append(e)

    ts = [threading.Thread(target=worker, args=(j * 50,)) for j in range(6)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
    for j in range(6):
        for i in range(40):
            assert bytes(btt.read(j * 50 + i)) == _blk(j * 50 + i)


def test_concurrent_writers_same_lba_last_wins_consistently():
    pmem = PMemSpace(128)
    btt = BTT(pmem, n_lbas=8, nfree=4)

    def worker(v):
        for _ in range(30):
            btt.write(3, _blk(v))

    ts = [threading.Thread(target=worker, args=(v,)) for v in (1, 2, 3)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    # whatever won, the block must be UNTORN: all bytes identical
    data = bytes(btt.read(3))
    assert data == bytes([data[0]]) * 4096
    assert data[0] in (1, 2, 3)
