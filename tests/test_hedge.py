"""Tail-latency data plane: hedged replica reads under a limping shard.

Fail-slow ("limplock") is the failure mode fail-stop machinery never
sees: one device 10-100x slow, nothing erroring, p99 collapsed while
mean throughput looks healthy.  These tests pin the hedge path's whole
contract — the sim acceptance contrast (hedged p99 >= 2x better than
unhedged at one 25x limping shard, CI-gated via ``check_floors.py``),
the counter balance (``hedges_fired == hedges_won + hedges_cancelled``,
``hedges_unaccounted == 0``), and the threaded engine's fault sweep:
slow-then-die, slow-then-recover, the both-complete race (the loser's
one CQE is consumed exactly once), cancelled reads never landing
partial data in a caller's ``out=`` array, and pinned registered
buffers always returning to the pool."""
import time

import numpy as np

from aio_harness import (AsyncRun, blk, slow_shard_reads,
                         volume_lba_on_shard)
from repro.core.sim import run_hedge_sim_workload
from repro.volume import CancelledError, make_volume


# ------------------------------------------------- sim acceptance floors
def test_sim_hedged_p99_acceptance():
    """The headline contrast: one 25x limping shard, hedged vs unhedged
    at equal offered load.  Hedged p99 must be >= 2x better WITHOUT
    giving up throughput (the closed loop un-stalls, so hedged ops/s is
    at least the unhedged rate), and every fired hedge retires as
    exactly one of won/cancelled."""
    kw = dict(n_lbas=65536, n_ops=3000, n_shards=4,
              slow_shard=0, slow_factor=25.0)
    un = run_hedge_sim_workload("btt", hedge=False, **kw)
    he = run_hedge_sim_workload("btt", hedge=True, **kw)
    assert un["p99_us"] / he["p99_us"] >= 2.0
    assert he["ops_s"] >= un["ops_s"]
    c = he["counts"]
    assert c.get("hedges_fired", 0) \
        == c.get("hedges_won", 0) + c.get("hedges_cancelled", 0)
    assert c.get("hedges_won", 0) > 0          # hedges actually escaped
    # fail-slow's signature: the unhedged MEAN looks survivable (only
    # 1/n_shards of reads limp) while p99 sits at the limping device
    assert un["p99_us"] > 4.0 * un["p50_us"]


def test_sim_healthy_volume_fires_no_hedges():
    """With no limping shard the hedge delay (3x an unqueued read) sits
    above every healthy completion — the hedge path must cost nothing
    when nothing is wrong."""
    he = run_hedge_sim_workload("btt", hedge=True, slow_shard=None,
                                n_lbas=65536, n_ops=2000)
    assert he["counts"].get("hedges_fired", 0) == 0


def test_sim_counters_balance_across_delay_settings():
    """The won/cancelled split shifts with the hedge delay, but the
    balance invariant holds at every setting (including a degenerate
    zero delay that hedges every read)."""
    for delay in (0.0, 2.0, 10.0):
        r = run_hedge_sim_workload("btt", n_lbas=65536, n_ops=1500,
                                   hedge_delay_us=delay)
        c = r["counts"]
        assert c.get("hedges_fired", 0) \
            == c.get("hedges_won", 0) + c.get("hedges_cancelled", 0)


# --------------------------------------------- threaded engine: limping
def test_threaded_hedge_escapes_limping_shard():
    """A read whose primary copy lives on a stalled shard must be served
    by the replica leg well before the stall clears, with the loser
    cancelled through the engine (counters balance, primary recalled)."""
    vol = make_volume("btt", n_lbas=64, n_shards=2, replicas=2,
                      stripe_blocks=1, aio_workers=2)
    try:
        lba = volume_lba_on_shard(vol, 0)
        vol.write(lba, blk(9))
        inj = slow_shard_reads(vol, 0, 0.05)
        t0 = time.perf_counter()
        data = vol.hedged_read(lba, delay_s=0.002)
        dt = time.perf_counter() - t0
        assert bytes(data) == blk(9)
        assert dt < 0.045                      # escaped the 50 ms stall
        tp = vol.metrics.tail_path()
        assert tp["hedges_fired"] == 1
        assert tp["hedges_won"] == 1
        assert tp["primaries_cancelled"] == 1
        assert tp["hedges_unaccounted"] == 0
        inj["restore"]()
    finally:
        vol.close()


def test_threaded_hedge_slow_then_die():
    """Fail-slow turning fail-stop mid-read: the primary stalls, the
    hedge fires and wins, and the primary's later death is absorbed by
    the discard path — the caller saw only the good result, and data
    acked before the fault is still there afterwards."""
    vol = make_volume("btt", n_lbas=64, n_shards=2, replicas=2,
                      stripe_blocks=1, aio_workers=2)
    try:
        lba = volume_lba_on_shard(vol, 0)
        vol.write(lba, blk(5))                 # acked before the fault
        inj = slow_shard_reads(vol, 0, 0.03, die_after=1)
        data = vol.hedged_read(lba, delay_s=0.002)
        assert bytes(data) == blk(5)
        tp = vol.metrics.tail_path()
        assert tp["hedges_fired"] == 1
        assert tp["hedges_won"] + tp["hedges_cancelled"] == 1
        inj["restore"]()
        assert bytes(vol.read(lba)) == blk(5)  # no acked write lost
    finally:
        vol.close()


def test_threaded_hedge_failover_when_primary_errors_first():
    """The winner-failed branch: the primary dies BEFORE the (also slow)
    hedge completes.  Hedging subsumes failover — the other leg is
    settled and served instead of surfacing the primary's error."""
    vol = make_volume("btt", n_lbas=64, n_shards=2, replicas=2,
                      stripe_blocks=1, aio_workers=2)
    try:
        lba = volume_lba_on_shard(vol, 0)
        vol.write(lba, blk(6))
        inj0 = slow_shard_reads(vol, 0, 0.004, die_after=1)
        inj1 = slow_shard_reads(vol, 1, 0.02)
        data = vol.hedged_read(lba, delay_s=0.001)
        assert bytes(data) == blk(6)
        tp = vol.metrics.tail_path()
        assert tp["hedges_fired"] == 1
        assert tp["hedges_won"] == 1           # served despite being slow
        assert tp["hedges_unaccounted"] == 0
        inj0["restore"]()
        inj1["restore"]()
    finally:
        vol.close()


def test_threaded_hedge_slow_then_recover():
    """After the shard recovers, reads complete inside the hedge delay
    again and the hedge path goes quiet — no new hedges fired."""
    vol = make_volume("btt", n_lbas=64, n_shards=2, replicas=2,
                      stripe_blocks=1, aio_workers=2)
    try:
        lba = volume_lba_on_shard(vol, 0)
        vol.write(lba, blk(7))
        inj = slow_shard_reads(vol, 0, 0.03, recover_after=1)
        d1 = vol.hedged_read(lba, delay_s=0.002)   # stalls -> hedge wins
        d2 = vol.hedged_read(lba, delay_s=0.002)   # recovered: fast path
        assert bytes(d1) == blk(7) and bytes(d2) == blk(7)
        tp = vol.metrics.tail_path()
        assert tp["hedges_fired"] == 1             # only the first read
        assert tp["hedges_unaccounted"] == 0
        inj["restore"]()
    finally:
        vol.close()


def test_threaded_both_complete_race_consumes_single_cqe():
    """Both legs complete before the cancel reaches the loser: the loser
    keeps its real result, its ONE completion is consumed exactly once,
    and no stale CQE is left on the ring (no double completion)."""
    vol = make_volume("btt", n_lbas=64, n_shards=2, replicas=2,
                      stripe_blocks=1, aio_workers=2)
    try:
        eng = vol.aio_engine()
        lba = volume_lba_on_shard(vol, 0)
        vol.write(lba, blk(3))
        inj = slow_shard_reads(vol, 0, 0.01)
        orig_wait_any = eng.wait_any

        def wait_any_both(tickets, **kw):
            # force the race: let BOTH legs finish before hedged_read
            # gets to cancel the loser
            w = orig_wait_any(tickets, **kw)
            for t in tickets:
                eng.wait(t, timeout=5.0)
            return w

        eng.wait_any = wait_any_both
        try:
            data = vol.hedged_read(lba, delay_s=0.002)
        finally:
            eng.wait_any = orig_wait_any
        assert bytes(data) == blk(3)
        tp = vol.metrics.tail_path()
        assert tp["hedges_fired"] == 1
        assert tp["hedges_won"] == 1
        assert tp["hedges_unaccounted"] == 0
        assert eng.poll() == []                # loser CQE never re-surfaces
        inj["restore"]()
    finally:
        vol.close()


# ------------------------------- cancelled reads never land partial data
def test_cancelled_queued_read_never_touches_out():
    """Satellite regression: a QUEUED read cancelled before dispatch must
    leave the caller's ``out=`` array byte-for-byte untouched (driven
    through the deterministic inline schedule)."""
    vol = make_volume("btt", n_lbas=64, n_shards=2, stripe_blocks=1)
    try:
        run = AsyncRun(vol)
        run.run([("sync_write", 5, blk(8))])
        out = np.full(vol.block_size, 0xEE, np.uint8)
        run.run([
            ("submit_read_out", "r", 5, out),
            ("cancel", "r"),
            ("poll", None),
        ])
        assert isinstance(run.tickets["r"].error, CancelledError)
        assert np.all(out == 0xEE)             # sentinel intact
    finally:
        vol.close()


def test_cancelled_running_read_never_lands_partial_data():
    """The hedge-loser discard path: a read cancelled while RUNNING (mid
    media stall) completes later on its worker, but its landing into the
    caller's ``out=`` array is suppressed — the sentinel survives."""
    vol = make_volume("btt", n_lbas=64, n_shards=2, replicas=2,
                      stripe_blocks=1, aio_workers=2)
    try:
        eng = vol.aio_engine()
        lba = volume_lba_on_shard(vol, 0)
        vol.write(lba, blk(4))
        inj = slow_shard_reads(vol, 0, 0.03)
        out = np.full(vol.block_size, 0xAB, np.uint8)
        t = eng.submit("read", lba, out=out)
        time.sleep(0.005)                      # let it reach the stall
        assert eng.cancel(t) is True
        deadline = time.time() + 2.0
        while not t.done and time.time() < deadline:
            eng.poll()
            time.sleep(0.002)
        assert t.done
        assert isinstance(t.error, CancelledError)
        assert np.all(out == 0xAB)             # no partial landing
        inj["restore"]()
        # the path itself still works: an uncancelled read lands
        t2 = eng.submit("read", lba, out=out)
        eng.wait(t2, timeout=2.0)
        assert bytes(out) == blk(4)
    finally:
        vol.close()


def test_hedge_loser_releases_registered_out_buffer():
    """Every cancelled hedge releases its pinned buffers: a hedged read
    landing in a REGISTERED buffer whose primary leg is discarded must
    return the pin to the pool once the loser drains — no leaked
    registered buffers, ever."""
    vol = make_volume("btt", n_lbas=64, n_shards=2, replicas=2,
                      stripe_blocks=1, aio_workers=2)
    try:
        lba = volume_lba_on_shard(vol, 0)
        vol.write(lba, blk(7))
        reg = vol.register_buffers(2)
        buf = reg.acquire()
        buf.data[:] = 0xCD
        inj = slow_shard_reads(vol, 0, 0.02)
        res = vol.hedged_read(lba, out=buf, delay_s=0.002)
        assert res is buf
        assert bytes(buf.data) == blk(7)       # hedge win copied once
        inj["restore"]()
        eng = vol.aio_engine()
        deadline = time.time() + 2.0
        while reg.free_count() != len(reg) and time.time() < deadline:
            eng.poll()
            time.sleep(0.002)
        assert reg.free_count() == len(reg)    # discarded leg released it
        tp = vol.metrics.tail_path()
        assert tp["hedges_fired"] == 1
        assert tp["hedges_unaccounted"] == 0
    finally:
        vol.close()
