"""Checkpoint engine: roundtrip, atomic commit, retention, async, elastic
restore, codec."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointEngine, make_blockstore


def _state(seed=0):
    r = np.random.default_rng(seed)
    return {"w": {"a": r.standard_normal((64, 32)).astype(np.float32),
                  "b": r.standard_normal((7,)).astype(np.float32)},
            "step": np.int32(5),
            "m": r.standard_normal((1 << 14,)).astype(np.float32)}


def test_roundtrip_exact():
    store = make_blockstore(capacity_bytes=64 << 20)
    eng = CheckpointEngine(store)
    s = _state()
    eng.save(3, s)
    got, step = eng.restore(like=s)
    assert step == 3
    for path in ("w/a".split(),):
        pass
    assert np.array_equal(np.asarray(got["w"]["a"]), s["w"]["a"])
    assert np.array_equal(np.asarray(got["m"]), s["m"])
    assert int(got["step"]) == 5
    eng.close()


def test_latest_and_retention():
    store = make_blockstore(capacity_bytes=128 << 20)
    eng = CheckpointEngine(store, keep=2)
    for step in (1, 2, 3, 4):
        eng.save(step, _state(step))
    assert eng.list_steps() == [3, 4]
    got, step = eng.restore(like=_state())
    assert step == 4
    assert np.array_equal(np.asarray(got["m"]), _state(4)["m"])
    # older generations GC'd from the directory
    assert not any(k.startswith("step0000000001/")
                   for k in eng.store.keys())
    eng.close()


def test_async_save_then_restore():
    store = make_blockstore(capacity_bytes=64 << 20)
    eng = CheckpointEngine(store)
    s = _state(9)
    eng.save_async(7, s)
    eng.wait()
    got, step = eng.restore(like=s)
    assert step == 7
    assert np.array_equal(np.asarray(got["m"]), s["m"])
    eng.close()


def test_int8_codec_bounded_error():
    store = make_blockstore(capacity_bytes=64 << 20)
    eng = CheckpointEngine(store, codec="int8")
    s = {"m": np.random.default_rng(0).standard_normal(1 << 13
                                                       ).astype(np.float32)}
    eng.save(1, s)
    got, _ = eng.restore(like=s)
    err = np.abs(np.asarray(got["m"]) - s["m"]).max()
    step = np.abs(s["m"]).max() / 127.0
    assert err <= step * 0.75
    eng.close()


def test_restore_with_jax_state():
    """Save/restore a real (params, opt) pytree including bf16 leaves."""
    params = {"w": jnp.ones((8, 8), jnp.bfloat16) * 1.5,
              "b": jnp.arange(4, dtype=jnp.float32)}
    store = make_blockstore(capacity_bytes=64 << 20)
    eng = CheckpointEngine(store)
    eng.save(0, params)
    got, _ = eng.restore(like=params)
    assert got["w"].dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(got["w"], np.float32),
                          np.asarray(params["w"], np.float32))
    eng.close()


def test_elastic_restore_with_shardings():
    """Cross-'mesh' restore: target shardings on the 1-device mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    params = {"w": jnp.ones((16, 8), jnp.float32)}
    store = make_blockstore(capacity_bytes=64 << 20)
    eng = CheckpointEngine(store)
    eng.save(0, params)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got, _ = eng.restore(like=params, shardings=sh)
    assert got["w"].sharding == sh["w"]
    eng.close()


def test_uncommitted_generation_invisible(tmp_path):
    pool = str(tmp_path / "pool.bin")
    s1 = _state(1)
    store = make_blockstore(pool, capacity_bytes=64 << 20)
    eng = CheckpointEngine(store)
    eng.save(0, s1)
    # stage step-1 objects WITHOUT commit, then 'crash'
    for k, v in _state(2).items():
        if isinstance(v, dict):
            continue
        store.put(f"step{1:010d}/{k}/0", np.asarray(v).tobytes())
    del eng, store
    store2 = make_blockstore(pool, capacity_bytes=64 << 20)
    eng2 = CheckpointEngine(store2)
    got, step = eng2.restore(like=s1)
    assert step == 0
    assert np.array_equal(np.asarray(got["m"]), s1["m"])
    eng2.close()


def test_generation_bump_allocator_wraps():
    """Writing many generations beyond capacity reuses space after GC."""
    store = make_blockstore(capacity_bytes=16 << 20)
    eng = CheckpointEngine(store, keep=1)
    s = {"m": np.zeros(1 << 18, np.float32)}       # 1 MB
    for step in range(12):
        s["m"][:] = step
        eng.save(step, s)
    got, step = eng.restore(like=s)
    assert step == 11
    assert float(np.asarray(got["m"])[0]) == 11.0
    eng.close()
