"""TransitBuffer edge paths: the no-bypass blocking branch, sink-error
propagation through flush(), and close() after errors (previously
untested)."""
import threading
import time

import pytest

from repro.core import TransitBuffer


def test_nobypass_put_blocks_until_drain():
    """With bypass disabled, put() on a full buffer must BLOCK until the
    background drain frees capacity — never invoke the sink inline."""
    sunk = []
    gate = threading.Event()

    def slow_sink(item):
        gate.wait(5.0)
        sunk.append(item)

    tb = TransitBuffer(slow_sink, capacity_bytes=100, n_workers=1,
                       eager=True, bypass=False)
    tb.put("a", 60)                       # fits; worker blocks on gate
    done = threading.Event()

    def overfill():
        tb.put("b", 60)                   # 60+60 > 100: must wait
        done.set()

    t = threading.Thread(target=overfill, daemon=True)
    t.start()
    time.sleep(0.1)
    assert not done.is_set(), "put must block while the buffer is full"
    assert tb.staged_bytes() == 60        # nothing bypassed inline
    gate.set()                            # drain proceeds, capacity frees
    assert done.wait(5.0)
    tb.flush()
    assert sorted(sunk) == ["a", "b"]
    assert tb.metrics.count.get("bypass_writes", 0) == 0
    tb.close()


def test_bypass_sinks_inline_when_full():
    gate = threading.Event()
    sunk = []

    def slow_sink(item):
        if item == "slow":
            gate.wait(5.0)
        sunk.append(item)

    tb = TransitBuffer(slow_sink, capacity_bytes=100, n_workers=1,
                       eager=True, bypass=True)
    tb.put("slow", 80)
    assert tb.put("b", 80) == "bypass"    # full -> sunk synchronously
    assert "b" in sunk                    # inline, before any drain
    assert tb.metrics.count["bypass_writes"] == 1
    gate.set()
    tb.close()


def test_flush_raises_sink_error_once():
    def sink(item):
        if item == "bad":
            raise ValueError("sink exploded")

    tb = TransitBuffer(sink, capacity_bytes=1 << 20, n_workers=2)
    tb.put("ok", 10)
    tb.put("bad", 10)
    with pytest.raises(ValueError, match="sink exploded"):
        tb.flush()
    # the error was consumed: the buffer is usable again afterwards
    tb.put("ok2", 10)
    tb.flush()
    tb.close()


def test_close_after_error_propagates_then_recovers():
    fail = {"on": True}

    def sink(item):
        if fail["on"]:
            raise RuntimeError("still broken")

    tb = TransitBuffer(sink, capacity_bytes=1 << 20, n_workers=1)
    tb.put("x", 10)
    with pytest.raises(RuntimeError):
        tb.close()                        # close -> flush -> surfaced error
    fail["on"] = False
    tb.close()                            # errors drained: clean shutdown
    for w in tb._workers:
        assert not w.is_alive()


def test_lazy_mode_defers_sink_until_flush():
    sunk = []
    tb = TransitBuffer(sunk.append, capacity_bytes=1 << 20, n_workers=1,
                       eager=False)
    for i in range(5):
        tb.put(i, 10)
    time.sleep(0.05)
    assert sunk == []                     # nothing transits before flush
    tb.flush()
    assert sorted(sunk) == [0, 1, 2, 3, 4]
    tb.close()
