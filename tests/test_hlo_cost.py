"""Validate the HLO cost model against programs with known FLOP counts.

These pin the roofline pipeline's core convention: totals() must count a
scanned (while-loop) body times its trip count, must see through remat, and
must report per-device numbers on SPMD-partitioned modules.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloCost


def _cost_of(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return HloCost(txt).totals()


def test_single_matmul_flops():
    m, k, n = 256, 512, 128
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    t = _cost_of(lambda a, b: a @ b, a, b)
    expect = 2.0 * m * k * n
    assert t["flops"] == pytest.approx(expect, rel=0.01), t["flops"]


def test_scan_multiplies_trip_count():
    m = 128
    a = jax.ShapeDtypeStruct((m, m), jnp.float32)
    trips = 24

    def scanned(x):
        def body(h, _):
            return jnp.tanh(h @ h), None
        h, _ = jax.lax.scan(body, x, None, length=trips)
        return h

    t = _cost_of(scanned, a)
    expect = trips * 2.0 * m ** 3
    # XLA may add a small epilogue; require within 10%
    assert t["flops"] == pytest.approx(expect, rel=0.1), \
        (t["flops"], expect)


def test_grad_with_remat_counts_recompute():
    m = 128
    a = jax.ShapeDtypeStruct((m, m), jnp.float32)
    trips = 8

    def loss(x):
        @jax.checkpoint
        def body(h, _):
            return jnp.tanh(h @ h), None
        h, _ = jax.lax.scan(body, x, None, length=trips)
        return jnp.sum(h)

    t_plain = _cost_of(lambda x: jax.grad(
        lambda y: jnp.sum(jnp.tanh(y @ y)))(x), a)
    t = _cost_of(lambda x: jax.grad(loss)(x), a)
    # fwd + recompute + 2 bwd matmul-grads ≈ 4 matmuls per layer
    lo = trips * 3.5 * 2.0 * m ** 3
    hi = trips * 5.0 * 2.0 * m ** 3
    assert lo < t["flops"] < hi, (t["flops"], lo, hi)
    assert t_plain["flops"] > 0


def test_bytes_reasonable_for_copy():
    n = 1 << 20
    a = jax.ShapeDtypeStruct((n,), jnp.float32)
    t = _cost_of(lambda x: x * 2.0, a)
    # one read + one write of 4 MB, modest overhead allowed
    assert 8e6 * 0.9 < t["bytes"] < 8e6 * 3, t["bytes"]
