"""Distributed cluster volume (repro.cluster): placement properties,
chain-replicated write/read semantics, crc-ledger failover, heartbeat
failure detection + re-replication, the node-kill pipeline sweep (no
acknowledged write is ever lost, property-swept over EVERY pipelined
write step), per-ticket isolation on the async frontend, the sim-backed
acceptance contrasts, and the ckpt/serve integrations riding a cluster
unchanged."""
import numpy as np
import pytest

from aio_harness import blk, cluster_kill_sweep
from repro.cluster import (ClusterUnavailableError, NetLink,
                           NetworkPartitionError, NodeDownError, NodeInfo,
                           PlacementPolicy, make_cluster)
from repro.core.metrics import EWMA_ALPHA, Metrics
from repro.core.sim import run_cluster_sim_workload
from repro.volume import make_volume


class Clock:
    """Injectable manual clock for deterministic heartbeat timeouts."""

    def __init__(self) -> None:
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def small_cluster(**kw):
    kw.setdefault("policy", "btt")
    kw.setdefault("n_lbas", 128)
    kw.setdefault("n_nodes", 4)
    kw.setdefault("replication_k", 2)
    kw.setdefault("chunk_blocks", 16)
    kw.setdefault("node_shards", 2)
    kw.setdefault("stripe_blocks", 4)
    kw.setdefault("journal_slots", 8)
    kw.setdefault("journal_span", 4)
    return make_cluster(**kw)


# ----------------------------------------------------------- placement
def test_placement_chain_shape_and_rack_diversity():
    nodes = [NodeInfo(f"n{i}", rack=i % 3) for i in range(6)]
    for policy in ("ring", "spread", "balanced"):
        p = PlacementPolicy(nodes, k=3, policy=policy)
        for chunk in range(24):
            chain = p.assign(chunk, 16)
            assert len(chain) == 3 and len(set(chain)) == 3
            # K=3 over 3 racks: every chain must span all racks for the
            # topology-aware policies
            if policy != "ring":
                assert p.rack_diversity(chain) == 3, (policy, chain)


def test_placement_capacity_balance():
    nodes = [NodeInfo(f"n{i}", rack=i % 3) for i in range(6)]
    p = PlacementPolicy(nodes, k=2, policy="spread")
    for chunk in range(100):
        p.assign(chunk, 8)
    # spread-K keeps placed blocks within a tight band of the mean
    assert p.balance() < 1.2, p.placed


def test_placement_balanced_avoids_slow_node():
    nodes = [NodeInfo(f"n{i}", rack=0) for i in range(4)]
    p = PlacementPolicy(nodes, k=2, policy="balanced", load_weight=50.0)
    for _ in range(8):
        p.observe_load(0, 500.0)       # node 0 is limping (fail-slow)
    hits = sum(1 for c in range(40) if 0 in p.assign(c, 1))
    # the load-shaded score steers chains away from the slow node
    assert hits < 10, hits


def test_placement_replacement_prefers_fresh_rack():
    nodes = [NodeInfo("a", rack=0), NodeInfo("b", rack=1),
             NodeInfo("c", rack=0), NodeInfo("d", rack=2)]
    p = PlacementPolicy(nodes, k=2, policy="spread")
    # chain [0, 1] loses node 1 (rack 1): candidates {2 (rack 0), 3
    # (rack 2)} — rack diversity against survivor rack 0 picks node 3
    assert p.replacement([0, 1], dead=1, alive=[0, 2, 3]) == 3
    # no candidate outside the chain -> stays under-replicated
    assert p.replacement([0, 1], dead=1, alive=[0]) is None


def test_netlink_virtual_time_accounting():
    link = NetLink(latency_us=5.0, mb_s=2048.0)
    dur = link.xfer_us(4096)
    assert dur == pytest.approx(5.0 + 2.0)
    link.account(4096)
    link.account(8192)
    s = link.stats()
    assert s["bytes_moved"] == 12288 and s["msgs"] == 2
    assert s["vtime_us"] == pytest.approx(dur + 5.0 + 4.0)


# ------------------------------------------------------- metrics EWMAs
def test_metrics_service_time_ewma():
    m = Metrics()
    m.observe("svc::node0", 100)
    m.observe("svc::node0", 200)
    m.observe("svc::node1", 50)
    m.observe("other", 1)                 # outside the svc:: prefix
    per = m.per_node()
    assert set(per) == {"node0", "node1"}
    want = (100 + EWMA_ALPHA * (200 - 100)) / 1e3
    assert per["node0"]["ewma_us"] == pytest.approx(want)
    assert per["node0"]["n"] == 2
    assert per["node0"]["max_us"] == pytest.approx(0.2)
    m.reset()
    assert m.per_node() == {}


def test_volume_surfaces_per_shard_service_times():
    vol = make_volume("caiti", n_lbas=1024, n_shards=2,
                      cache_bytes=64 * 4096)
    try:
        for i in range(8):
            vol.write(i, blk(i))
        vol.read(0)
        vol.submit("write", 100, data=blk(1)).result()
        snap = vol.metrics_snapshot()
        svc = snap["per_shard_svc"]
        assert any(k.startswith("shard") for k in svc)
        assert "aio::write" in svc and svc["aio::write"]["n"] >= 1
        scrub = vol.scrub()
        assert scrub["divergent"] == 0
        assert scrub["per_shard_svc"] == vol.metrics.per_node()
    finally:
        vol.close()


# ----------------------------------------------------- cluster basics
def test_cluster_write_read_roundtrip_and_async_surface():
    cl = small_cluster(policy="caiti")
    try:
        for lba in range(0, 48, 4):
            cl.write_multi(lba, [blk(lba + i) for i in range(4)])
        for lba in range(48):
            assert bytes(cl.read(lba)) == blk(lba)
        # every block must be durable on K distinct nodes
        chain = cl._chains[0]
        assert len(set(chain)) == 2
        for ni in chain:
            assert bytes(cl.nodes[ni].volume.read(0)) == blk(0)
        # the async frontend is the SAME engine the striped volume uses
        t = cl.submit("write", 100, data=blk(9))
        assert t.result() == 0
        assert bytes(cl.submit("read", 100).result()) == blk(9)
        assert cl.submit("fsync").result() == 0
        snap = cl.metrics_snapshot()
        assert snap["acked_writes"] >= 13
        assert any(k.startswith("node") for k in snap["per_node_svc"])
    finally:
        cl.close()


def test_cluster_chunk_splitting_and_atomic_bound():
    cl = small_cluster()
    try:
        # a write spanning chunks commits chunk group by chunk group
        cl.write_multi(14, [blk(70 + i) for i in range(6)])
        for i in range(6):
            assert bytes(cl.read(14 + i)) == blk(70 + i)
        assert cl._chains.keys() >= {0, 1}
        # whole-object atomicity is bounded by one placement chunk
        assert cl.max_atomic_write_blocks() <= cl.cfg.chunk_blocks
    finally:
        cl.close()


def test_unacked_write_resolves_to_old_version_via_failover():
    """Kill the middle chain member mid-pipeline (K=3): the primary
    holds the torn-in new image, the live tail still holds the acked old
    one — verified reads must fail over past the crc mismatch and keep
    serving the ACKED version."""
    clock = Clock()
    cl = small_cluster(n_lbas=64, replication_k=3, now_fn=clock)
    try:
        cl.write_multi(0, [blk(1)] * 4)
        victim = cl._chains[0][1]        # middle chain member

        def hook(step, phase, ni):
            if phase == "xfer" and ni == victim:
                cl.kill_node(ni)

        cl.step_hook = hook
        with pytest.raises(NodeDownError):
            cl.write_multi(0, [blk(99)] * 4)
        cl.step_hook = None
        for lba in range(4):
            assert bytes(cl.read(lba)) == blk(1)
        snap = cl.metrics_snapshot()
        assert snap["verify_failures"] >= 4      # torn primary detected
        assert snap["degraded_reads"] >= 4       # served by the tail
        # heal: declare the death, re-replicate, repair the divergence
        clock.t = 100.0
        st = cl.rereplicator.run_once()
        assert st["declared_dead"] == [victim]
        # the repair swapped the dead member out of the live chain
        assert victim not in cl._chains[0]
        assert st["chunks_repaired"] >= 1
        assert cl.resync() >= 4
        assert cl.scrub()["divergent_blocks"] == 0
        for lba in range(4):
            assert bytes(cl.read(lba)) == blk(1)
    finally:
        cl.close()


def test_partition_is_suspected_then_declared_dead():
    clock = Clock()
    cl = small_cluster(now_fn=clock, heartbeat_timeout=5.0)
    try:
        cl.write_multi(0, [blk(3)] * 4)
        victim = cl._chains[0][1]
        cl.partition_node(victim)
        # a partitioned node refuses deliveries but is NOT dead yet
        with pytest.raises(NetworkPartitionError):
            cl.nodes[victim].deliver(4096, clock())
        clock.t = 3.0
        cl.heartbeat_tick()                 # reachable nodes beat
        assert cl.monitor.check() == []     # within the timeout
        clock.t = 10.0
        # past the timeout the failure detector cannot tell a partition
        # from a crash — suspicion is death (HDFS semantics)
        st = cl.rereplicator.run_once()
        assert st["declared_dead"] == [victim]
        assert not cl.nodes[victim].alive
        assert st["chunks_repaired"] >= 1
        assert cl.scrub()["under_replicated"] == []
        for lba in range(4):
            assert bytes(cl.read(lba)) == blk(3)
    finally:
        cl.close()


def test_no_live_replica_raises_unavailable():
    cl = small_cluster(n_nodes=2, n_lbas=32)
    try:
        cl.write(0, blk(1))
        for n in cl.nodes:
            n.kill()
        with pytest.raises(ClusterUnavailableError):
            cl.read(0)
    finally:
        cl.close()


def test_async_per_ticket_isolation_on_node_death():
    """A node death fails the tickets whose chains need it — never the
    ring: ops on unaffected chains keep completing, and after
    re-replication the repaired chain serves writes again."""
    clock = Clock()
    cl = small_cluster(n_lbas=256, chunk_blocks=16, now_fn=clock,
                       aio_workers=2)
    try:
        for chunk in range(8):
            cl.write(chunk * 16, blk(chunk))
        dead = cl._chains[0][0]
        affected = [c for c, ch in sorted(cl._chains.items())
                    if dead in ch]
        clean = [c for c, ch in sorted(cl._chains.items())
                 if dead not in ch]
        assert affected and clean
        cl.kill_node(dead)
        t_bad = cl.submit("write", affected[0] * 16 + 1, data=blk(40))
        t_good = cl.submit("write", clean[0] * 16 + 1, data=blk(41))
        cl.wait(t_bad)
        cl.wait(t_good)
        assert isinstance(t_bad.error, NodeDownError)
        assert t_good.ok
        # the engine survives; repaired chains accept writes again
        clock.t = 100.0
        st = cl.rereplicator.run_once()
        assert st["chunks_repaired"] == len(affected)
        t3 = cl.submit("write", affected[0] * 16 + 1, data=blk(42))
        assert t3.result() == 0
        assert bytes(cl.read(affected[0] * 16 + 1)) == blk(42)
    finally:
        cl.close()


# ------------------------------------------------------ the kill sweep
def test_kill_sweep_no_acked_write_lost():
    """ACCEPTANCE: fail-stop the involved node at EVERY pipelined-write
    step (transfer, durable member write, ack — swept until a run sees
    no kill) and assert, after heartbeat detection + re-replication:

      * whole-object: every object reads back exactly ONE version,
        never a torn mix;
      * no acknowledged write is ever lost: the surviving version is >=
        every version whose cluster write RETURNED (ack = K durable
        tails + ledger update);
      * re-replication restores K live copies of every chunk.
    """
    from aio_harness import VersionedObjects

    clock = Clock()
    acked: dict[int, int] = {}

    def make():
        clock.t = 0.0
        cl = small_cluster(n_lbas=128, n_nodes=4, replication_k=2,
                           chunk_blocks=16, now_fn=clock)
        objs = VersionedObjects(n_objects=4, n_blocks=4, stride=16,
                                base_lba=8)
        objs.write_base(cl)              # un-instrumented base (acked v0)
        cl._step_no = 0                  # sweep counts version-write steps
        acked.clear()
        acked.update({o: 0 for o in range(objs.n_objects)})
        cl._objs = objs
        return cl

    def schedule(cl):
        objs = cl._objs
        for o in range(objs.n_objects):
            lba, v, blocks = objs.next_version(o)
            try:
                cl.write_multi(lba, blocks)
                acked[o] = v             # returned == acknowledged
            except Exception:
                pass                     # unacked: either version is fine

    def check(n, fired, cl):
        objs = cl._objs
        clock.t = 100.0
        st = cl.rereplicator.run_once()
        if fired is not None:
            assert st["declared_dead"] == [fired[2]]
        scrub = cl.scrub()
        assert scrub["under_replicated"] == [], \
            f"step {n}: re-replication left chunks under-replicated"
        for o in range(objs.n_objects):
            v = objs.read_version(cl, o)
            assert v != -1, f"step {n}: object {o} TORN"
            assert v >= acked[o], \
                (f"step {n}: object {o} lost acked v{acked[o]} "
                 f"(read v{v})")

    points = cluster_kill_sweep(make, schedule, check)
    # 4 objects x K=2 chains x (2 hops x 2 steps + ack) = 20 swept steps
    assert points == 21, points


# ------------------------------------------------------ sim acceptance
def test_sim_pipelined_chain_beats_serial_fanout():
    """ACCEPTANCE: 4-node K=2 pipelined chain writes sustain >= 1.5x the
    ops/s of serial per-replica (client-fanout) writes, and the
    replication tax stays bounded (>= 0.6x single-node — the CI
    floor)."""
    ten = [{"name": "t0", "n_ops": 1200}]
    kw = dict(n_lbas=1 << 14, chunk_blocks=64, n_blocks=8, qdepth=4,
              tenants=ten)
    pip = run_cluster_sim_workload(n_nodes=4, replication_k=2,
                                   mode="pipelined", **kw)
    ser = run_cluster_sim_workload(n_nodes=4, replication_k=2,
                                   mode="serial", **kw)
    one = run_cluster_sim_workload(n_nodes=1, replication_k=1,
                                   mode="pipelined", **kw)
    assert pip["ops_s"] / ser["ops_s"] >= 1.5, \
        (pip["ops_s"], ser["ops_s"])
    assert pip["ops_s"] / one["ops_s"] >= 0.6, \
        (pip["ops_s"], one["ops_s"])
    # replicated bytes really moved: K x payload over the wire
    assert pip["counts"]["net_bytes"] >= 2 * 1200 * 8 * 4096


def test_sim_kill_storm_restores_replication():
    ten = [{"name": "t0", "n_ops": 800}]
    r = run_cluster_sim_workload(n_nodes=5, replication_k=2,
                                 n_lbas=1 << 13, chunk_blocks=64,
                                 n_blocks=8, qdepth=4, tenants=ten,
                                 kill_node=1, kill_at_frac=0.5)
    c = r["counts"]
    assert c["nodes_killed"] == 1
    assert c["chunks_repaired"] > 0
    assert c["rereplicated_blocks"] > 0
    assert c["storm_span_us"] > 0
    # every op completed despite the mid-workload death
    assert r["per_tenant"]["t0"]["ops"] == 800


def test_sim_placement_policies_balance():
    ten = [{"name": "t0", "n_ops": 400}]
    for pol in ("ring", "spread", "balanced"):
        r = run_cluster_sim_workload(n_nodes=6, replication_k=3, racks=3,
                                     placement=pol, n_lbas=1 << 14,
                                     tenants=ten, n_blocks=8)
        assert r["rack_diversity"] == pytest.approx(3.0)
        assert r["balance"] < 1.5


# ----------------------------------------------------- integrations
def test_blockstore_over_cluster_survives_node_loss(tmp_path):
    from repro.ckpt.blockstore import make_blockstore

    bs = make_blockstore(capacity_bytes=4 << 20, cache_bytes=1 << 20,
                         cluster=3, replication_k=2)
    try:
        payload = np.arange(50_000, dtype=np.float32).tobytes()
        bs.put("step1", payload)
        data_lba = bs.directory["step1"][0]
        primary = bs.dev._chain_for(data_lba // bs.dev.cfg.chunk_blocks)[0]
        bs.dev.kill_node(primary)        # lose the data chunk's primary
        assert bs.get("step1") == payload
        assert bs.dev.metrics_snapshot()["read_failovers"] > 0
    finally:
        bs.close()


def test_async_request_log_over_cluster():
    from repro.serve.engine import AsyncRequestLog

    cl = small_cluster(policy="caiti", n_lbas=256, aio_workers=2)
    try:
        log = AsyncRequestLog(cl, base_lba=128, capacity_blocks=64)
        for i in range(6):
            log.append({"rid": i, "tokens": list(range(i))})
        assert log.drain() == 0 and log.logged == 6
        # records are chain-replicated: both members hold the first one
        chain = cl._chain_for(128 // cl.cfg.chunk_blocks)
        raws = [bytes(cl.nodes[ni].volume.read(128)) for ni in chain]
        assert raws[0] == raws[1]
        # records must stay whole-record atomic: the cluster's
        # chunk-bounded atomic envelope rejects an oversized append
        big = {"rid": 99, "pad": "x" * (cl.max_atomic_write_blocks()
                                        * cl.block_size)}
        with pytest.raises(AssertionError):
            log.append(big)
    finally:
        cl.close()
