"""Caiti transit cache + staging policies: functional semantics under the
real threaded implementation."""
import threading

import numpy as np
import pytest

from repro.core import CaitiConfig, make_device, POLICIES


def _blk(x: int) -> bytes:
    return bytes([x % 256]) * 4096


CACHED = ("caiti", "caiti-noee", "caiti-nobp", "pmbd", "pmbd70", "lru",
          "coactive")


@pytest.mark.parametrize("policy", CACHED)
def test_read_your_writes(policy):
    dev = make_device(policy, n_lbas=256, cache_bytes=64 * 4096)
    try:
        for lba in range(64):
            dev.write(lba, _blk(lba + 1))
        for lba in range(64):
            assert bytes(dev.read(lba)) == _blk(lba + 1), (policy, lba)
    finally:
        dev.close()


@pytest.mark.parametrize("policy", CACHED)
def test_overwrite_latest_visible(policy):
    dev = make_device(policy, n_lbas=64, cache_bytes=16 * 4096)
    try:
        for v in range(5):
            dev.write(7, _blk(v + 1))
        assert bytes(dev.read(7)) == _blk(5)
        dev.fsync()
        assert bytes(dev.read(7)) == _blk(5)
    finally:
        dev.close()


@pytest.mark.parametrize("policy", CACHED)
def test_fsync_persists_to_backend(policy):
    """After fsync every written block must be readable from the BTT
    directly (cache bypass)."""
    dev = make_device(policy, n_lbas=256, cache_bytes=16 * 4096)
    try:
        for lba in range(48):
            dev.write(lba, _blk(lba + 9))
        dev.fsync()
        btt = dev.impl.btt
        for lba in range(48):
            assert bytes(btt.read(lba)) == _blk(lba + 9), (policy, lba)
    finally:
        dev.close()


def test_caiti_write_more_than_cache_capacity():
    """Writes far beyond capacity must all land (transit or bypass)."""
    dev = make_device("caiti", n_lbas=1024, cache_bytes=8 * 4096,
                      n_workers=2)
    try:
        for lba in range(512):
            dev.write(lba, _blk(lba))
        dev.fsync()
        for lba in range(0, 512, 37):
            assert bytes(dev.read(lba)) == _blk(lba)
    finally:
        dev.close()


def test_caiti_eager_eviction_drains():
    """With eager eviction the cache empties without any flush call."""
    dev = make_device("caiti", n_lbas=256, cache_bytes=32 * 4096)
    try:
        for lba in range(32):
            dev.write(lba, _blk(lba))
        # wait for the background pool (bounded)
        import time
        for _ in range(200):
            if dev.occupancy() == 0.0:
                break
            time.sleep(0.01)
        assert dev.occupancy() == 0.0
        assert dev.impl.btt.writes >= 32
    finally:
        dev.close()


def test_caiti_noee_keeps_buffered_until_flush():
    dev = make_device("caiti-noee", n_lbas=256, cache_bytes=32 * 4096)
    try:
        for lba in range(16):
            dev.write(lba, _blk(lba))
        assert dev.occupancy() > 0.0
        assert dev.impl.btt.writes == 0        # nothing transited yet
        dev.fsync()
        assert dev.impl.btt.writes >= 16
    finally:
        dev.close()


def test_caiti_bypass_counted_on_full_cache():
    dev = make_device("caiti-noee", n_lbas=256, cache_bytes=4 * 4096)
    try:
        for lba in range(32):
            dev.write(lba, _blk(lba))
        assert dev.metrics.count.get("bypass_writes", 0) > 0
    finally:
        dev.close()


def test_caiti_concurrent_stress():
    dev = make_device("caiti", n_lbas=512, cache_bytes=16 * 4096,
                      n_workers=3)
    errs = []

    def w(base):
        try:
            for i in range(60):
                dev.write((base + i) % 512, _blk(base + i))
                if i % 20 == 19:
                    dev.fsync()
        except BaseException as e:
            errs.append(e)

    try:
        ts = [threading.Thread(target=w, args=(j * 97,)) for j in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errs
        dev.fsync()
        # every block must be whole (untorn) after the dust settles
        for lba in range(0, 512, 41):
            data = bytes(dev.read(lba))
            assert data == bytes([data[0]]) * 4096
    finally:
        dev.close()


def test_all_policies_construct():
    for policy in POLICIES:
        dev = make_device(policy, n_lbas=64, cache_bytes=8 * 4096)
        dev.write(1, _blk(1))
        assert bytes(dev.read(1)) == _blk(1)
        dev.close()


def test_bio_interface_flags():
    from repro.core import Bio, BioFlags, BioOp, fsync_bio
    dev = make_device("caiti", n_lbas=64, cache_bytes=8 * 4096)
    try:
        bio = Bio(op=BioOp.WRITE, lba=3, data=_blk(7),
                  flags=BioFlags.REQ_FUA)
        dev.submit_bio(bio)
        assert bio.wait(5.0) == 0
        fb = fsync_bio()
        dev.submit_bio(fb)
        assert fb.wait(5.0) == 0
        assert bytes(dev.impl.btt.read(3)) == _blk(7)
    finally:
        dev.close()
