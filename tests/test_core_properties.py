"""Property-based tests (hypothesis) for the system's core invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import BTT, PMemSpace, make_device
from repro.core.sim import run_sim_workload


def _blk(x: int) -> bytes:
    return bytes([x % 251]) * 4096


OPS = st.lists(
    st.one_of(
        st.tuples(st.just("write"), st.integers(0, 31), st.integers(1, 250)),
        st.tuples(st.just("read"), st.integers(0, 31), st.just(0)),
        st.tuples(st.just("fsync"), st.just(0), st.just(0)),
    ),
    min_size=1, max_size=60)


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=OPS, policy=st.sampled_from(
    ["caiti", "caiti-noee", "caiti-nobp", "pmbd", "lru", "coactive", "btt"]))
def test_policy_matches_dict_model(ops, policy):
    """Single-threaded linearizability: any op sequence behaves like a
    dict (read-your-writes + durability via fsync)."""
    dev = make_device(policy, n_lbas=32, cache_bytes=6 * 4096)
    model = {}
    try:
        for op, lba, val in ops:
            if op == "write":
                dev.write(lba, _blk(val))
                model[lba] = val
            elif op == "read":
                got = bytes(dev.read(lba))
                want = _blk(model[lba]) if lba in model else b"\x00" * 4096
                assert got == want
            else:
                dev.fsync()
        dev.fsync()
        for lba, val in model.items():
            assert bytes(dev.read(lba)) == _blk(val)
    finally:
        dev.close()


@settings(max_examples=20, deadline=None)
@given(writes=st.lists(st.tuples(st.integers(0, 15), st.integers(1, 250)),
                       min_size=1, max_size=40),
       crash_at=st.integers(0, 39))
def test_btt_crash_anywhere_leaves_committed_prefix(writes, crash_at):
    """Crash DURING any write: every previously completed write is intact
    and the in-flight lba shows either old or new data — never torn."""
    pmem = PMemSpace(64)
    btt = BTT(pmem, n_lbas=16, nfree=2)
    model = {}
    from repro.core import SimulatedCrash

    crashed = False
    for i, (lba, val) in enumerate(writes):
        if i == crash_at:
            state = {"arm": True}

            def hook(label):
                if label == "pmem_write_mid" and state["arm"]:
                    state["arm"] = False
                    raise SimulatedCrash(label)

            pmem.crash_hook = hook
            try:
                btt.write(lba, _blk(val))
                model[lba] = val       # survived (hook may not have fired)
            except SimulatedCrash:
                crashed = True
            pmem.crash_hook = None
            break
        btt.write(lba, _blk(val))
        model[lba] = val

    btt2 = BTT(pmem, n_lbas=16, fresh=False)
    btt2.recover()
    for lba, val in model.items():
        got = bytes(btt2.read(lba))
        assert got == _blk(val), f"lba {lba} corrupted after recovery"
    if crashed:
        # the in-flight block: old value (or zero) — must be untorn
        lba, val = writes[crash_at]
        got = bytes(btt2.read(lba))
        assert got == bytes([got[0]]) * 4096


@settings(max_examples=10, deadline=None)
@given(n_ops=st.integers(500, 3000), slots=st.integers(16, 512),
       depth=st.sampled_from([1, 8, 32]))
def test_sim_caiti_never_slower_than_staging(n_ops, slots, depth):
    """Virtual-time invariant: Caiti's makespan <= PMBD's and LRU's for
    any uniform write-only workload (the paper's headline claim)."""
    kw = dict(n_ops=n_ops, n_lbas=4096, cache_slots=slots, iodepth=depth)
    mk = {p: run_sim_workload(p, **kw).counts["makespan_us"]
          for p in ("caiti", "pmbd", "lru")}
    assert mk["caiti"] <= mk["pmbd"] * 1.02
    assert mk["caiti"] <= mk["lru"] * 1.02


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_sim_deterministic(seed):
    a = run_sim_workload("caiti", n_ops=2000, n_lbas=4096, cache_slots=64,
                         iodepth=16, seed=seed)
    b = run_sim_workload("caiti", n_ops=2000, n_lbas=4096, cache_slots=64,
                         iodepth=16, seed=seed)
    assert a.response_us == b.response_us


def test_transit_buffer_bypass_and_flush():
    from repro.core import TransitBuffer
    sunk = []
    tb = TransitBuffer(lambda x: sunk.append(x), capacity_bytes=100,
                       n_workers=2)
    for i in range(20):
        tb.put(i, nbytes=30)
    tb.flush()
    assert sorted(sunk) == list(range(20))
    tb.close()


def test_transit_buffer_error_surfaces_at_flush():
    import pytest
    from repro.core import TransitBuffer

    def sink(x):
        if x == 3:
            raise RuntimeError("disk on fire")

    tb = TransitBuffer(sink, capacity_bytes=1000, n_workers=1)
    for i in range(5):
        tb.put(i, nbytes=10)
    with pytest.raises(RuntimeError, match="disk on fire"):
        tb.flush()
