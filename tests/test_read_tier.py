"""ReadTier (CLOCK clean read cache) + per-socket eviction banks +
ReplicaResyncer unit tests; the volume-level integration lives in
tests/test_volume.py."""
import time

import numpy as np

from repro.volume import ReadTier, SharedEvictionPool


def _blk(x: int) -> bytes:
    return bytes([x % 256]) * 4096


# ------------------------------------------------------------- tier core
def test_tier_fill_hit_invalidate():
    tier = ReadTier(8 * 4096, 4096)
    assert tier.lookup(("a", 1)) is None
    tier.insert(("a", 1), _blk(7))
    assert bytes(tier.lookup(("a", 1))) == _blk(7)
    tier.invalidate(("a", 1))
    assert tier.lookup(("a", 1)) is None
    assert tier.stats()["invalidations"] == 1


def test_tier_lookup_into_out_buffer():
    tier = ReadTier(4 * 4096, 4096)
    tier.insert(0, _blk(3))
    out = np.zeros(4096, np.uint8)
    got = tier.lookup(0, out=out)
    assert got is out
    assert bytes(out) == _blk(3)


def test_tier_clock_second_chance_keeps_hot_key():
    tier = ReadTier(4 * 4096, 4096)          # 4 slots
    for k in range(4):
        tier.insert(k, _blk(k))
    tier.insert(4, _blk(4))                  # sweep clears all ref bits
    tier.lookup(1)                           # re-reference key 1 only
    tier.insert(5, _blk(5))                  # hand passes 1 (second chance)
    assert 1 in tier
    assert 2 not in tier                     # the unreferenced one went
    assert len(tier) == 4


def test_tier_capacity_bounded():
    tier = ReadTier(8 * 4096, 4096)
    for k in range(100):
        tier.insert(k, _blk(k))
    assert len(tier) == 8


def test_tier_fence_rejects_stale_fill():
    """The read-miss fill protocol: a write invalidation between
    prepare() and insert() must drop the (stale) fill."""
    tier = ReadTier(8 * 4096, 4096)
    token = tier.prepare(5)                  # reader starts a backend read
    tier.invalidate(5)                       # writer updates the block
    assert not tier.insert(5, _blk(1), token=token)
    assert tier.lookup(5) is None
    assert tier.stats()["rejected_fills"] == 1
    # a fresh fill (token taken after the invalidate) lands fine
    token = tier.prepare(5)
    assert tier.insert(5, _blk(2), token=token)
    assert bytes(tier.lookup(5)) == _blk(2)


def test_tier_object_mode_for_serving_pages():
    tier = ReadTier(block_size=None, n_slots=2)
    k = np.ones((16, 2, 4), np.float32)
    tier.insert(("page", 0, 1, 2), (k, k * 2))
    got = tier.lookup(("page", 0, 1, 2))
    assert got is not None and np.array_equal(got[0], k)
    tier.insert(("page", 0, 3, 4), (k, k))
    tier.insert(("page", 0, 5, 6), (k, k))   # evicts one of the others
    assert len(tier) == 2


# ------------------------------------------------- per-socket pool banks
class _FakeCache:
    """Minimal pool participant: records which items were drained."""

    def __init__(self):
        self.drained = []
        self.completed = 0

    def _evict_slot(self, item):
        self.drained.append(item)

    def _complete_eviction(self, n=1):
        self.completed += n


def _wait(pred, timeout=5.0):
    t0 = time.time()
    while time.time() - t0 < timeout:
        if pred():
            return True
        time.sleep(0.01)
    return False


def test_pool_socket_banks_drain_and_steal():
    pool = SharedEvictionPool(4, name="t", n_sockets=2)
    a, b = _FakeCache(), _FakeCache()
    pool.register(a, socket=0)
    pool.register(b, socket=1)
    try:
        for i in range(20):
            pool.submit(a, ("a", i))
            pool.submit(b, ("b", i))
        assert _wait(lambda: a.completed == 20 and b.completed == 20)
        assert sorted(a.drained) == [("a", i) for i in range(20)]
        # every pick is attributed to one of the two banks
        assert sum(pool.drained_by_socket) == 40
    finally:
        pool.close()


def test_pool_idle_bank_steals_cross_socket():
    """A one-participant pool with 2 sockets: the socket-1 bank has no
    home queues, so every item it drains is a steal — work conservation
    over locality, a lone backlog can never wedge."""
    pool = SharedEvictionPool(2, name="t", n_sockets=2)
    a = _FakeCache()
    pool.register(a, socket=0)
    try:
        for i in range(50):
            pool.submit(a, i)
        assert _wait(lambda: a.completed == 50)
        assert pool.backlog() == 0
    finally:
        pool.close()


def test_single_device_tier_and_read_path_summary():
    """make_device(read_tier_bytes=...) fronts a lone caiti device, and
    Metrics.read_path() summarizes where reads were served from."""
    from repro.core import make_device
    dev = make_device("caiti", n_lbas=256, cache_bytes=512 * 4096,
                      read_tier_bytes=64 * 4096)
    try:
        for lba in range(48):
            dev.write(lba, _blk(lba + 1))
        dev.fsync()                      # writebacks populate the tier
        for lba in range(48):
            assert bytes(dev.read(lba)) == _blk(lba + 1)
        rp = dev.metrics.read_path()
        assert rp["read_tier_hits"] + rp["read_hits"] == 48
        assert rp["read_misses"] == 0
        assert rp["dram_hit_rate"] == 1.0
        dev.impl.read_tier.clear()
        dev.read(0)                      # cold: full BTT round trip
        rp = dev.metrics.read_path()
        assert rp["read_misses"] == 1 and rp["read_tier_fills"] >= 1
        assert rp["dram_hit_rate"] < 1.0
    finally:
        dev.close()


def test_kvcache_host_pages_read_through_tier():
    """Serving layer: hybrid attention over host-resident pages caches
    the dequantized pages; page-in invalidates them."""
    import jax.numpy as jnp
    from repro.serve.kvcache import PagedCacheConfig, PagedKVCache
    cfg = PagedCacheConfig(n_layers=2, n_kv_heads=2, head_dim=8,
                           page_size=4, n_pages=4, read_tier_pages=16)
    kv = PagedKVCache(cfg)
    sid = kv.new_sequence()
    for t in range(8):
        tok = [np.full((2, 8), t, np.float32) for _ in range(2)]
        kv.append_token(sid, tok, tok)
    kv.deactivate(sid)                       # pages transit to the host tier
    kv.seqs[sid].active = True               # decode without paging in
    q = jnp.ones((1, 2, 8), jnp.float32)
    kv.attention(0, q, [sid])
    hits0 = kv.metrics.snapshot()["count"].get("read_tier_hits", 0)
    out1 = kv.attention(0, q, [sid])         # same pages: dequant cached
    hits1 = kv.metrics.snapshot()["count"].get("read_tier_hits", 0)
    assert hits1 > hits0
    out2 = kv.attention(0, q, [sid])
    assert np.allclose(np.asarray(out1), np.asarray(out2))
    kv.activate(sid)                         # page-in pops host handles
    assert len(kv.read_tier) == 0            # ...and invalidates the tier


def test_pool_assign_socket_repins():
    pool = SharedEvictionPool(2, name="t", n_sockets=2)
    a = _FakeCache()
    pool.register(a)                         # defaults to socket 0
    pool.assign_socket(a, 1)
    try:
        pool.submit(a, "x")
        assert _wait(lambda: a.completed == 1)
    finally:
        pool.close()
