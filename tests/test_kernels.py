"""Pallas kernel sweeps: every kernel, across shapes and dtypes, against
the pure-jnp oracle (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import (flash_attention, gather_quantize,
                           gather_quantize_crc, paged_attention,
                           scatter_dequantize, scatter_dequantize_crc)
from repro.kernels import ref
from repro.kernels.block_transit import (gather_quantize_crc_pallas,
                                         scatter_dequantize_crc_pallas)

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,S,H,Hkv,hd", [
    (1, 128, 128, 2, 2, 64),       # MHA square
    (2, 256, 256, 4, 2, 64),       # GQA
    (1, 128, 384, 8, 1, 128),      # MQA, rectangular, wide head
    (2, 384, 128, 4, 4, 64),       # more Q than KV
])
def test_flash_attention_sweep(B, T, S, H, Hkv, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=True, bq=128, bk=128)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window", [32, 128, 500])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, T, H, hd = 1, 256, 2, 64
    q = jax.random.normal(ks[0], (B, T, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, H, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_non_causal():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, T, S, H, hd = 2, 128, 256, 2, 64
    q = jax.random.normal(ks[0], (B, T, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=False)
    exp = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,hd,P,page,maxp", [
    (2, 4, 2, 64, 16, 16, 4),
    (3, 8, 1, 128, 12, 32, 3),     # MQA
    (1, 2, 2, 64, 4, 8, 2),
])
def test_paged_attention_sweep(B, H, Hkv, hd, P, page, maxp, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kp = jax.random.normal(ks[1], (P, page, Hkv, hd), dtype)
    vp = jax.random.normal(ks[2], (P, page, Hkv, hd), dtype)
    rng = np.random.default_rng(0)
    bt = jnp.asarray(rng.permutation(P)[:B * maxp].reshape(B, maxp),
                     jnp.int32)
    sl = jnp.asarray(rng.integers(1, page * maxp + 1, (B,)), jnp.int32)
    out = paged_attention(q, kp, vp, bt, sl)
    exp = ref.paged_attention_ref(q, kp, vp, bt, sl)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@settings(max_examples=15, deadline=None)
@given(seq_lens=st.lists(st.integers(1, 64), min_size=1, max_size=4))
def test_paged_attention_respects_lengths(seq_lens):
    """Property: tokens beyond seq_len never influence the output."""
    B = len(seq_lens)
    H, Hkv, hd, page = 2, 2, 64, 16
    maxp = 4
    P = B * maxp
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (P, page, Hkv, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, Hkv, hd), jnp.float32)
    bt = jnp.arange(P, dtype=jnp.int32).reshape(B, maxp)
    sl = jnp.asarray(seq_lens, jnp.int32)
    out1 = paged_attention(q, kp, vp, bt, sl)
    # poison everything beyond each sequence's length; output must not move
    kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
    for b, L in enumerate(seq_lens):
        for pi in range(maxp):
            lo = pi * page
            for off in range(page):
                if lo + off >= L:
                    kp2[bt[b, pi], off] = 99.0
                    vp2[bt[b, pi], off] = -99.0
    out2 = paged_attention(q, jnp.asarray(kp2), jnp.asarray(vp2), bt, sl)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("P,page,F", [(8, 16, 128), (4, 32, 256),
                                      (16, 8, 384)])
def test_transit_codec_roundtrip(P, page, F):
    pool = jax.random.normal(jax.random.PRNGKey(5), (P, page, F),
                             jnp.float32)
    ids = jnp.asarray(np.random.default_rng(1).permutation(P)[:3], jnp.int32)
    q, sc = gather_quantize(pool, ids)
    qr, sr = ref.gather_quantize_ref(pool, ids)
    assert np.array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sr), rtol=1e-6)
    # roundtrip error bounded by one quantization step
    restored = scatter_dequantize(jnp.zeros_like(pool), ids, q, sc)
    orig = np.asarray(pool)[np.asarray(ids)]
    got = np.asarray(restored)[np.asarray(ids)]
    step = np.abs(orig).max(axis=-1, keepdims=True) / 127.0
    assert (np.abs(got - orig) <= step * 0.75 + 1e-7).all()


@settings(max_examples=12, deadline=None)
@given(shape=st.sampled_from([(6, 8, 64), (8, 16, 128), (4, 32, 96),
                              (12, 8, 256)]),
       seed=st.integers(0, 2**31 - 1),
       n_ids=st.integers(1, 4))
def test_fused_transit_crc_matches_three_pass_property(shape, seed, n_ids):
    """Property (satellite): the FUSED crc+quantize+gather kernel is
    bit-identical (q, crc) and allclose (scales, dequant) to the
    three-pass composition gather_quantize_ref -> transit_crc_ref ->
    scatter_dequantize_ref — in direct interpret=True mode AND through
    the jit-compiled public wrappers.  The crc oracle itself is pinned
    to ``zlib.adler32`` of the packed page bytes."""
    import zlib
    P, page, F = shape
    rng = np.random.default_rng(seed)
    pool = jnp.asarray(rng.standard_normal((P, page, F)), jnp.float32)
    ids = jnp.asarray(rng.permutation(P)[:min(n_ids, P)], jnp.int32)

    qr, sr = ref.gather_quantize_ref(pool, ids)          # pass 1+2
    crc_r = ref.transit_crc_ref(qr)                      # pass 3 (walk)
    for pi, crc in zip(np.asarray(qr), crc_r):           # oracle's oracle
        assert int(crc) == zlib.adler32(pi.tobytes())

    for q, sc, crc in (
            gather_quantize_crc_pallas(pool, ids, interpret=True),
            gather_quantize_crc(pool, ids)):             # jit-compiled
        assert np.array_equal(np.asarray(q), np.asarray(qr))
        np.testing.assert_allclose(np.asarray(sc), np.asarray(sr),
                                   rtol=1e-6)
        assert np.array_equal(np.asarray(crc), crc_r)    # bit-identical

    exp_pool = ref.scatter_dequantize_ref(jnp.zeros_like(pool), ids, qr, sr)
    for new_pool, crc in (
            scatter_dequantize_crc_pallas(jnp.zeros_like(pool), ids,
                                          qr, sr, interpret=True),
            scatter_dequantize_crc(jnp.zeros_like(pool), ids, qr, sr)):
        assert np.array_equal(np.asarray(crc), crc_r)    # verify-on-land
        np.testing.assert_allclose(np.asarray(new_pool),
                                   np.asarray(exp_pool),
                                   atol=1e-6, rtol=1e-6)
    # end-to-end roundtrip error bounded by one quantization step
    got = np.asarray(new_pool)[np.asarray(ids)]
    orig = np.asarray(pool)[np.asarray(ids)]
    step = np.abs(orig).max(axis=-1, keepdims=True) / 127.0
    assert (np.abs(got - orig) <= step * 0.75 + 1e-7).all()


def test_fused_crc_detects_payload_corruption():
    """Flipping ONE byte of a quantized page moves its crc — the
    property the kvcache restore path relies on to detect torn transit."""
    pool = jax.random.normal(jax.random.PRNGKey(9), (4, 16, 64),
                             jnp.float32)
    ids = jnp.asarray([1, 3], jnp.int32)
    q, sc, crc = gather_quantize_crc(pool, ids)
    qc = np.asarray(q).copy()
    qc[0, 3, 7] = qc[0, 3, 7] ^ 1
    _, crc2 = scatter_dequantize_crc(jnp.zeros_like(pool), ids,
                                     jnp.asarray(qc), sc)
    assert int(crc2[0]) != int(crc[0])        # corrupted page flagged
    assert int(crc2[1]) == int(crc[1])        # untouched page unchanged


def test_scatter_preserves_other_pages():
    pool = jax.random.normal(jax.random.PRNGKey(6), (8, 16, 128),
                             jnp.float32)
    ids = jnp.asarray([2, 5], jnp.int32)
    q, sc = gather_quantize(pool, ids)
    out = scatter_dequantize(pool, ids, q, sc)
    for p in range(8):
        if p in (2, 5):
            continue
        np.testing.assert_array_equal(np.asarray(out[p]),
                                      np.asarray(pool[p]))


def test_flash_attention_grad_flows():
    """The kernel must be differentiable (used in training paths)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    B, T, H, hd = 1, 128, 2, 64
    q = jax.random.normal(ks[0], (B, T, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, H, hd), jnp.float32)

    def loss(q):
        return flash_attention(q, k, v, causal=True).sum()

    g = jax.grad(loss)(q)
    assert bool(jnp.isfinite(g).all())
