"""Trainer (fault tolerance, resume) and ServeEngine (paged decode)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointEngine, make_blockstore
from repro.configs import get_config
from repro.data import Prefetcher, SyntheticLM
from repro.models import build_model
from repro.optim import AdamW
from repro.serve import PagedCacheConfig, ServeEngine
from repro.train.loop import TrainConfig, Trainer


def _setup(steps=6, ckpt=None, ckpt_every=3):
    cfg = get_config("internlm2-1.8b", smoke=True)
    model = build_model(cfg)
    opt = AdamW(lr=1e-3, total_steps=100)
    src = SyntheticLM(cfg.vocab, seq=32, global_batch=4)
    tr = Trainer(model, opt, src, ckpt=ckpt,
                 cfg=TrainConfig(total_steps=steps, ckpt_every=ckpt_every,
                                 async_ckpt=True))
    return cfg, model, opt, src, tr


def test_trainer_runs_and_losses_finite():
    *_, tr = _setup(steps=5)
    out = tr.run(jax.random.PRNGKey(0))
    assert out["last_step"] == 4
    assert all(np.isfinite(l) for l in out["losses"])


def test_trainer_crash_restart_resumes_exact_schedule():
    """Run 0..5 with checkpoints; 'crash'; resume must continue from the
    next step and see the same data batches (deterministic pipeline)."""
    store = make_blockstore(capacity_bytes=256 << 20)
    eng = CheckpointEngine(store)
    cfg, model, opt, src, tr = _setup(steps=6, ckpt=eng, ckpt_every=2)
    out1 = tr.run(jax.random.PRNGKey(0))
    assert out1["last_step"] == 5

    # full reference run without interruption, same seeds
    cfg2, model2, opt2, src2, tr_ref = _setup(steps=9)
    ref = tr_ref.run(jax.random.PRNGKey(0))

    # resume the checkpointed trainer for 3 more steps
    tr2 = Trainer(model, opt, src, ckpt=eng,
                  cfg=TrainConfig(total_steps=9, ckpt_every=100))
    out2 = tr2.run(jax.random.PRNGKey(0))
    assert out2["last_step"] == 8
    # the resumed losses must match the uninterrupted run's steps 6..8
    np.testing.assert_allclose(out2["losses"], ref["losses"][6:9],
                               rtol=1e-4, atol=1e-5)
    eng.close()


def test_trainer_preemption_stop_saves():
    store = make_blockstore(capacity_bytes=128 << 20)
    eng = CheckpointEngine(store)
    cfg, model, opt, src, tr = _setup(steps=50, ckpt=eng, ckpt_every=100)
    orig_fn = tr.step_fn

    calls = {"n": 0}

    def wrapped(*a):
        calls["n"] += 1
        if calls["n"] == 3:
            tr.request_stop()          # SIGTERM arrives mid-run
        return orig_fn(*a)

    tr.step_fn = wrapped
    out = tr.run(jax.random.PRNGKey(0))
    assert out["last_step"] == 2
    assert eng.latest_step() == 2      # final sync save happened
    eng.close()


def test_data_pipeline_deterministic_and_prefetch():
    src = SyntheticLM(vocab=128, seq=16, global_batch=4, seed=7)
    a = src.batch_at(12)
    b = src.batch_at(12)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = src.batch_at(13)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # prefetcher yields consecutive steps from the start step
    pf = Prefetcher(src, start_step=5)
    s5, b5 = pf.next()
    s6, b6 = pf.next()
    pf.close()
    assert (s5, s6) == (5, 6)
    assert np.array_equal(b5["tokens"], src.batch_at(5)["tokens"])


def test_multihost_shards_disjoint_but_deterministic():
    full = SyntheticLM(vocab=128, seq=16, global_batch=8, seed=3)
    h0 = SyntheticLM(vocab=128, seq=16, global_batch=8, seed=3,
                     n_hosts=2, host_id=0)
    h1 = SyntheticLM(vocab=128, seq=16, global_batch=8, seed=3,
                     n_hosts=2, host_id=1)
    b0, b1 = h0.batch_at(0), h1.batch_at(0)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# ---------------------------------------------------------------- serving
def _serve_setup(pool_pages=64, page_size=8, use_kernel=False):
    cfg = get_config("qwen2.5-3b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_cfg = PagedCacheConfig(
        n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        page_size=page_size, n_pages=pool_pages, max_pages_per_seq=16)
    eng = ServeEngine(cfg, params, cache_cfg=cache_cfg, max_batch=2,
                      use_kernel=use_kernel)
    return cfg, model, params, eng


def test_paged_decode_matches_dense_reference():
    """Greedy tokens from the paged engine == tokens from the reference
    dense-cache decode path."""
    cfg, model, params, eng = _serve_setup()
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab, size=(12,)).tolist()
    req = eng.submit(prompt, max_new_tokens=6)
    eng.run()
    got = req.out_tokens

    # reference: model prefill + decode with the dense ring cache
    tok = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = model.prefill(params, {"tokens": tok},
                                  s_max=len(prompt) + 8)
    ref = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(5):
        t = jnp.asarray([ref[-1]], jnp.int32)
        logits, cache = model.decode_step(
            params, cache, t, jnp.asarray([pos], jnp.int32))
        ref.append(int(jnp.argmax(logits[0])))
        pos += 1
    assert got == ref, (got, ref)


def test_paged_engine_with_kernel_matches_ref_path():
    cfg, model, params, eng_ref = _serve_setup(use_kernel=False)
    _, _, _, eng_k = _serve_setup(use_kernel=True)
    prompt = list(range(2, 14))
    r1 = eng_ref.submit(prompt, max_new_tokens=5)
    eng_ref.run()
    r2 = eng_k.submit(prompt, max_new_tokens=5)
    eng_k.run()
    assert r1.out_tokens == r2.out_tokens


def test_eager_pageout_on_retire_and_release():
    cfg, model, params, eng = _serve_setup(pool_pages=32)
    for i in range(3):
        eng.submit(list(range(2, 10)), max_new_tokens=4)
    eng.run()
    assert len(eng.finished) == 3
    # all pages returned to the pool after release
    assert eng.cache.free_pages() == 32
    assert len(eng.cache.host) == 0


def test_conditional_bypass_under_pool_pressure():
    """A pool too small for the working set must trigger host-tier bypass
    pages, and decoding must still complete correctly."""
    cfg, model, params, eng = _serve_setup(pool_pages=2, page_size=4)
    req = eng.submit(list(range(2, 20)), max_new_tokens=4)
    eng.run()
    assert req.done
    assert eng.metrics.count.get("bypass_pages", 0) > 0


def test_transit_pageout_pagein_roundtrip():
    """deactivate (int8 page-out) then activate (page-in): decode still
    produces the same tokens as an uninterrupted run."""
    cfg, model, params, eng = _serve_setup(pool_pages=64)
    prompt = list(range(2, 18))
    # uninterrupted reference
    ref_req = eng.submit(prompt, max_new_tokens=6)
    eng.run()
    ref = ref_req.out_tokens

    _, _, _, eng2 = _serve_setup(pool_pages=64)
    req = eng2.submit(prompt, max_new_tokens=6)
    eng2.step()                      # prefill + 1 token
    sid = req.seq_id
    eng2.cache.deactivate(sid)       # transit out (int8)
    assert eng2.metrics.count.get("pages_out", 0) > 0
    eng2.cache.activate(sid)         # transit back in
    eng2.run()
    # int8 KV roundtrip may perturb logits; require the first tokens match
    assert req.out_tokens[:2] == ref[:2]
    assert len(req.out_tokens) == len(ref)
