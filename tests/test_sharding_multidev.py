"""Multi-device tests: run in a subprocess with 8 forced host devices
(XLA fixes the device count at first init, so the main test process — which
must see 1 device — cannot host these)."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow      # each test spawns an 8-device subprocess

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str) -> str:
    code = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n" +
            textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


def test_train_step_on_mesh_matches_single_device():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.parallel import make_ctx, param_spec_tree, named, \\
        batch_spec_tree
    from repro.train.step import make_train_step

    cfg = get_config('internlm2-1.8b', smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    r = np.random.default_rng(0)
    batch = {'tokens': jnp.asarray(r.integers(0, cfg.vocab, (8, 32)),
                                   jnp.int32),
             'targets': jnp.asarray(r.integers(0, cfg.vocab, (8, 32)),
                                    jnp.int32)}
    # single device reference
    step1 = jax.jit(make_train_step(model, opt))
    p1, o1, m1 = step1(params, opt_state, batch)

    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    ctx = make_ctx(mesh, 8)
    pspec = param_spec_tree(jax.eval_shape(lambda: params), mesh)
    pshard = named(pspec, mesh)
    bshard = named(batch_spec_tree(jax.eval_shape(lambda: batch), ctx), mesh)
    params_s = jax.device_put(params, pshard)
    opt_s = opt.init(params_s)
    step8 = jax.jit(make_train_step(model, opt, ctx),
                    in_shardings=(pshard, None, bshard))
    p8, o8, m8 = step8(params_s, opt_s, batch)
    d = abs(float(m1['loss']) - float(m8['loss']))
    assert d < 1e-2, (float(m1['loss']), float(m8['loss']))
    # params close after one step
    l1 = jax.tree.leaves(p1)[0]
    l8 = jax.tree.leaves(p8)[0]
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l8, np.float32), atol=3e-2)
    print('mesh-vs-single OK', float(m1['loss']), float(m8['loss']))
    """)


def test_int8_ring_allreduce_close_to_mean():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.collectives import compressed_allreduce_tree
    from repro.models.common import MeshCtx
    mesh = jax.make_mesh((8,), ('data',))
    ctx = MeshCtx(mesh=mesh, batch_axes=('data',), model_axis=None)
    r = np.random.default_rng(0)
    g = {'a': jnp.asarray(r.standard_normal((64, 64)), jnp.float32),
         'b': jnp.asarray(r.standard_normal((1000,)), jnp.float32)}
    out = jax.jit(lambda t: compressed_allreduce_tree(t, ctx))(g)
    # grads identical on all shards -> mean == input; int8 error bounded
    for k in g:
        err = np.abs(np.asarray(out[k]) - np.asarray(g[k])).max()
        amax = np.abs(np.asarray(g[k])).max()
        assert err <= amax / 127.0 * 8 + 1e-6, (k, err)
    print('ring int8 OK')
    """)


def test_decode_attention_seq_sharded_matches_ref():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.common import MeshCtx
    from repro.models.layers import decode_attention, chunked_attention
    mesh = jax.make_mesh((1, 8), ('data', 'model'))
    ctx = MeshCtx(mesh=mesh, batch_axes=('data',), model_axis='model')
    B, S, H, hd = 2, 64, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    k_pos = jnp.arange(S)[None].repeat(B, 0)
    pos = jnp.full((B,), S - 1)
    msk = jnp.ones((B, S), bool)
    out = decode_attention(q, k, v, k_pos=k_pos, pos=pos, window=0,
                           kv_mask=msk, ctx=ctx, chunk=32,
                           dtype=jnp.float32)
    ref = chunked_attention(q, k, v, q_pos=pos[:, None], k_pos=k_pos,
                            causal=True, kv_mask=msk, chunk=32,
                            dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-4)
    print('seq-sharded decode OK')
    """)


def test_zero1_specs_divide_shapes():
    _run("""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.models import build_model
    from repro.parallel import param_spec_tree, zero_spec_tree
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    for arch in ('internlm2-1.8b', 'qwen3-moe-235b-a22b', 'xlstm-1.3b'):
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        shapes = model.param_shape()
        specs = param_spec_tree(shapes, mesh)
        zspecs = zero_spec_tree(specs, shapes, mesh)
        def check(path, leaf, spec):
            for ax, name in enumerate(spec):
                if name is None:
                    continue
                assert leaf.shape[ax] % mesh.shape[name] == 0, \\
                    (arch, path, leaf.shape, spec)
        jax.tree_util.tree_map_with_path(
            check, shapes, zspecs,
            is_leaf=lambda x: isinstance(x, P))
    print('zero1 specs OK')
    """)


def test_moe_zero3_expert_gather_matches_single_device():
    """ZeRO-3 expert weights (stored sharded over 'data', gathered per
    layer) must produce the same loss as the unsharded single-device path."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import build_model
    from repro.parallel import make_ctx, named, param_spec_tree, \\
        batch_spec_tree

    cfg = get_config('qwen3-moe-235b-a22b', smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    r = np.random.default_rng(0)
    batch = {'tokens': jnp.asarray(r.integers(0, cfg.vocab, (8, 32)),
                                   jnp.int32),
             'targets': jnp.asarray(r.integers(0, cfg.vocab, (8, 32)),
                                    jnp.int32)}
    ref = float(model.loss(params, batch))

    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    ctx = make_ctx(mesh, 8)
    pspec = param_spec_tree(jax.eval_shape(lambda: params), mesh)
    # confirm the ZeRO-3 rule fired: expert F axis sharded over data
    wg_spec = pspec['blocks']['moe']['wg']
    assert 'data' in tuple(wg_spec), wg_spec
    pshard = named(pspec, mesh)
    p_s = jax.device_put(params, pshard)
    bshard = named(batch_spec_tree(jax.eval_shape(lambda: batch), ctx), mesh)
    b_s = jax.device_put(batch, bshard)
    got = float(jax.jit(lambda p, b: model.loss(p, b, ctx))(p_s, b_s))
    assert abs(got - ref) < 2e-2, (got, ref)
    print('moe zero3 OK', ref, got)
    """)


@pytest.mark.xfail(reason="psum accumulation-order noise marginally exceeds "
                   "the 3e-2 tol on CPU jax 0.4.37 (1/512 elements)",
                   strict=False)
def test_sharded_cache_decode_matches_single_device():
    """decode_update_and_attend with an S-sharded KV cache must emit the
    same logits as the unsharded decode."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import build_model
    from repro.parallel import cache_spec_tree, make_ctx, named, \\
        param_spec_tree

    cfg = get_config('internlm2-1.8b', smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    r = np.random.default_rng(0)
    B, T = 2, 32
    prompt = jnp.asarray(r.integers(0, cfg.vocab, (B, T)), jnp.int32)
    logits, cache = model.prefill(params, {'tokens': prompt}, s_max=T + 8)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((B,), T, jnp.int32)
    ref, _ = model.decode_step(params, cache, tok, pos)

    mesh = jax.make_mesh((1, 8), ('data', 'model'))
    ctx = make_ctx(mesh, B)
    pshard = named(param_spec_tree(jax.eval_shape(lambda: params), mesh),
                   mesh)
    cshard = named(cache_spec_tree(jax.eval_shape(lambda: cache), ctx, mesh),
                   mesh)
    p_s = jax.device_put(params, pshard)
    c_s = jax.device_put(cache, cshard)
    got, new_c = jax.jit(
        lambda p, c, t, q: model.decode_step(p, c, t, q, ctx))(
        p_s, c_s, tok, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-2, atol=3e-2)
    # the new token landed in exactly one shard's slot
    kpos = np.asarray(new_c['pos'])
    assert (kpos[:, :, T] == T).all()
    print('sharded-cache decode OK')
    """)
