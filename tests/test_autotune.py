"""Control-plane stability: the autotune Controller may adapt, but it
may NEVER escape its clamps, flap on one noisy window, or perturb a
volume that did not opt in.

Layers under test:
  * Knob      — AIMD step discipline, hard clamps, hysteresis, reversal
                damping, integer rounding, rail accounting
  * Controller— per-knob decision rules, SLO pressure veto, convergence
                under steady signals, noise robustness (hypothesis sweep
                when available, seeded-random sweep always)
  * wiring    — frozen passthrough (no autotuner => no knob ever moves),
                threaded StripedVolume apply path, ClusterVolume fan-out,
                and the virtual-time tuned-vs-frozen acceptance contrast
"""
import random
import threading

import pytest

from repro.core.sim import run_autotune_sim_workload
from repro.volume import make_volume
from repro.volume.autotune import (Controller, Knob, default_knobs,
                                   make_default_controller)


# ---------------------------------------------------------------- knobs
def test_knob_clamps_at_construction_and_set():
    k = Knob("w", 500.0, 0.0, 200.0, quantum=20.0)
    assert k.value == 200.0                      # seeded above hi: clamped
    assert k.set(-5.0) == 0.0                    # re-seed below lo: clamped
    assert k.in_range()


def test_knob_hysteresis_and_zero_vote_reset():
    k = Knob("w", 0.0, 0.0, 200.0, quantum=20.0, hysteresis=2)
    assert k.vote(+1) is None                    # 1 of 2 votes: hold
    assert k.vote(0) is None                     # neutral window: trend resets
    assert k.vote(+1) is None                    # back to 1 of 2
    assert k.vote(+1) == 20.0                    # second consecutive: move
    assert k.moves == 1 and k.raises == 1


def test_knob_reversal_needs_double_hysteresis():
    k = Knob("w", 0.0, 0.0, 200.0, quantum=20.0, hysteresis=2)
    assert k.vote(+1) is None and k.vote(+1) == 20.0
    # reversing an applied raise must clear 2x the bar (4 votes), so a
    # raise/lower tug-of-war damps instead of ringing
    assert k.vote(-1) is None and k.vote(-1) is None and k.vote(-1) is None
    assert k.vote(-1) is not None
    assert k.lowers == 1


def test_knob_aimd_decay_snaps_to_floor():
    k = Knob("w", 40.0, 0.0, 200.0, quantum=20.0, hysteresis=1)
    assert k.vote(-1) == 20.0                    # 40 * 0.5
    assert k.vote(-1) == 10.0                    # exactly half a quantum out
    # 10 * 0.5 = 5 lands strictly within half a quantum of lo: snap
    assert k.vote(-1) == 0.0
    assert k.value == 0.0                        # really zero, no asymptote


def test_knob_rail_votes_do_not_move_and_are_counted():
    k = Knob("w", 200.0, 0.0, 200.0, quantum=20.0, hysteresis=1)
    for _ in range(5):
        assert k.vote(+1) is None                # pinned at the hi rail
    assert k.value == 200.0 and k.moves == 0 and k.rail_hits == 5


def test_integer_knob_rounds_and_always_steps():
    k = Knob("scan", 8.0, 8.0, 512.0, quantum=0.4, integer=True,
             hysteresis=1)
    assert k.vote(+1) == 9.0                     # quantum < 1 still moves >= 1
    assert float(k.value).is_integer()
    k2 = Knob("scan", 64.0, 8.0, 512.0, quantum=32.0, integer=True,
              hysteresis=1)
    assert k2.vote(-1) == 32.0 and float(k2.value).is_integer()


# ----------------------------------------------------------- controller
def _steady(signals: dict, ctl: Controller, ticks: int) -> list[dict]:
    return [ctl.observe(signals) for _ in range(ticks)]


def test_controller_converges_under_steady_fsync_pressure():
    ctl = make_default_controller()
    moves = _steady({"fsync_rate": 0.25, "coalesce_rate": 0.0}, ctl, 50)
    lo, hi = ctl.clamp_range("commit_window_us")
    assert ctl.value("commit_window_us") == hi   # ratchets to the rail...
    assert all(lo <= v <= hi
               for m in moves for n, v in m.items()
               if n == "commit_window_us")       # ...never past it
    # once coalescing works, the steady state is HOLD, not oscillation
    before = ctl.total_moves
    _steady({"fsync_rate": 0.25, "coalesce_rate": 0.9}, ctl, 50)
    assert ctl.total_moves == before


def test_controller_decays_window_when_workload_turns_read_only():
    ctl = make_default_controller()
    _steady({"fsync_rate": 0.25, "coalesce_rate": 0.0}, ctl, 10)
    assert ctl.value("commit_window_us") > 0
    _steady({"fsync_rate": 0.0, "read_rate": 1.0,
             "tier_hit_rate": 0.8}, ctl, 40)
    assert ctl.value("commit_window_us") == 0.0  # back to zero, not 0.0001


def test_slo_pressure_vetoes_and_reverses_window_raises():
    ctl = make_default_controller(slos={"gold": {"p99_us": 100.0}})
    hot = {"fsync_rate": 0.25, "coalesce_rate": 0.0,
           "per_tenant_p99_us": {"gold": 500.0}}     # 5x over target
    _steady(hot, ctl, 30)
    assert ctl.last_pressure == pytest.approx(5.0)
    assert ctl.value("commit_window_us") == 0.0  # veto: never widened
    # wildcard SLO matches tenants with no explicit entry
    ctl2 = make_default_controller(slos={"*": {"p99_us": 100.0}})
    assert ctl2.slo_pressure(
        {"per_tenant_p99_us": {"t7": 250.0}}) == pytest.approx(2.5)


def test_hedge_delay_tracks_healthy_p99_only_while_limping():
    ctl = make_default_controller(hysteresis=1)
    v0 = ctl.value("hedge_delay_us")
    _steady({"limping": False, "healthy_p99_us": 9000.0}, ctl, 10)
    assert ctl.value("hedge_delay_us") == v0     # healthy fleet: hold
    _steady({"limping": True, "healthy_p99_us": 9000.0}, ctl, 10)
    assert ctl.value("hedge_delay_us") > v0      # trigger was too twitchy
    lo, hi = ctl.clamp_range("hedge_delay_us")
    assert lo <= ctl.value("hedge_delay_us") <= hi


def _assert_never_escaped(ctl: Controller):
    for name, knob in ctl.knobs.items():
        lo, hi = ctl.clamp_range(name)
        assert lo <= knob.value <= hi, (name, knob.value)
    for _tick, name, old, new in ctl.history:
        lo, hi = ctl.clamp_range(name)
        assert lo <= new <= hi, (name, old, new)


def _noise_signals(rng) -> dict:
    s = {"ops": rng.randint(0, 10_000)}
    for key in ("fsync_rate", "coalesce_rate", "log_rate",
                "log_coalesce_rate", "stall_rate", "bypass_rate",
                "staged_frac", "read_rate", "tier_hit_rate",
                "scan_denial_rate", "pin_rate", "wfq_debt_share"):
        s[key] = rng.uniform(0.0, 1.0)
    s["limping"] = rng.random() < 0.5
    s["healthy_p99_us"] = rng.uniform(0.0, 50_000.0)
    s["p99_us"] = rng.uniform(0.0, 50_000.0)
    s["per_tenant_p99_us"] = {f"t{j}": rng.uniform(1.0, 50_000.0)
                              for j in range(rng.randint(0, 3))}
    return s


def test_noise_never_escapes_clamps_seeded_random():
    """Always-on noise sweep: 2000 adversarial windows across 4 seeds;
    no knob value (current or historical) may leave its clamp range."""
    for seed in range(4):
        rng = random.Random(seed)
        ctl = make_default_controller(slos={"*": {"p99_us": 500.0}})
        for _ in range(500):
            ctl.observe(_noise_signals(rng))
        _assert_never_escaped(ctl)
        assert ctl.ticks == 500


def test_noise_never_escapes_clamps_hypothesis():
    """Property form of the same invariant when hypothesis is available
    (CI installs it; the container may not have it)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    rate = st.floats(min_value=0.0, max_value=1.0)
    sig = st.fixed_dictionaries({
        "fsync_rate": rate, "coalesce_rate": rate, "log_rate": rate,
        "log_coalesce_rate": rate, "stall_rate": rate,
        "bypass_rate": rate, "read_rate": rate, "tier_hit_rate": rate,
        "scan_denial_rate": rate, "limping": st.booleans(),
        "healthy_p99_us": st.floats(min_value=0.0, max_value=1e6),
        "p99_us": st.floats(min_value=0.0, max_value=1e6),
    })

    @hyp.settings(max_examples=60, deadline=None)
    @hyp.given(st.lists(sig, min_size=1, max_size=40))
    def run(windows):
        ctl = make_default_controller(slos={"*": {"p99_us": 500.0}})
        for s in windows:
            ctl.observe(s)
        _assert_never_escaped(ctl)

    run()


def test_bind_seeds_from_live_config_and_ignores_unknown():
    ctl = make_default_controller()
    ctl.bind({"commit_window_us": 120.0, "not_a_knob": 42.0,
              "scan_threshold": 9999.0})
    assert ctl.value("commit_window_us") == 120.0
    assert ctl.value("scan_threshold") == 512.0  # clamped into range
    assert "not_a_knob" not in ctl.knobs


def test_stats_shape():
    ctl = Controller(default_knobs())
    ctl.observe({"fsync_rate": 0.5})
    st = ctl.stats()
    assert st["ticks"] == 1
    assert set(st["knobs"]) == {k.name for k in default_knobs()}


# ----------------------------------------------- threaded volume wiring
def test_frozen_volume_is_pure_passthrough():
    vol = make_volume("caiti", n_lbas=1024, n_shards=2,
                      cache_bytes=1 << 20, shared_workers=2)
    try:
        assert vol.autotuner is None
        assert vol.autotune_step() == {}         # no-op, not an error
        vol.write(0, b"\x11" * vol.cfg.block_size)
        vol.fsync()
        assert vol.autotune_step() == {}
        assert vol._committer.window == 0.0      # knob untouched
        assert "autotune" not in vol.metrics_snapshot()
    finally:
        vol.close()


def test_threaded_volume_applies_commit_window_within_clamps():
    vol = make_volume("caiti", n_lbas=4096, n_shards=2,
                      cache_bytes=2 << 20, shared_workers=2,
                      autotune=True)
    try:
        assert vol.autotuner is not None
        blk = b"\x22" * vol.cfg.block_size

        def burst():
            for i in range(40):
                vol.write(i % 64, blk)
                if i % 2 == 0:
                    vol.fsync()

        for _ in range(3):                       # window -> observe -> move
            ts = [threading.Thread(target=burst) for _ in range(3)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            vol.autotune_step()
        lo, hi = vol.autotuner.clamp_range("commit_window_us")
        w_us = vol.autotuner.value("commit_window_us")
        assert lo <= w_us <= hi
        assert w_us > 0.0                        # fsync storm opened it
        # the applied plumbing agrees with the controller (us -> s)
        assert vol._committer.window == pytest.approx(w_us / 1e6)
        assert vol.cfg.commit_window == pytest.approx(w_us / 1e6)
        snap = vol.metrics_snapshot()
        assert snap["autotune"]["ticks"] == 3
        assert snap["autotune"]["autotune_ticks"] == 3
        assert snap["autotune"]["move_rate"] > 0.0
        assert "autotune" in vol.scrub(sample_every=64)
    finally:
        vol.close()


def test_cluster_attach_and_fanout_stay_in_clamps():
    from repro.cluster import make_cluster
    cl = make_cluster(policy="btt", n_lbas=256, n_nodes=3,
                      replication_k=2, chunk_blocks=16, node_shards=2,
                      stripe_blocks=4, journal_slots=8, journal_span=4,
                      autotune=True)
    try:
        assert cl.autotuner is not None
        blk = b"\x33" * 4096
        for rnd in range(3):
            for i in range(30):
                cl.write(i % 64, blk)
                if i % 2 == 0:
                    cl.fsync()
            cl.autotune_step()
        for name, knob in cl.autotuner.knobs.items():
            lo, hi = cl.autotuner.clamp_range(name)
            assert lo <= knob.value <= hi, (name, knob.value)
        # member volumes received the fanned-out window (us -> s)
        w_us = cl.autotuner.value("commit_window_us")
        for node in cl.nodes:
            assert node.volume.cfg.commit_window == \
                pytest.approx(w_us / 1e6)
        assert "autotune" in cl.metrics_snapshot()
    finally:
        cl.close()


# ------------------------------------------------- sim acceptance gate
PHASES = [
    {"name": "ycsb_a",
     "tenants": [{"name": f"t{j}", "n_ops": 400, "jobs": 2,
                  "read_frac": 0.5, "fsync_every": 4} for j in range(4)]},
    {"name": "ycsb_c", "lba_dist": "zipf",
     "tenants": [{"name": f"t{j}", "n_ops": 400, "jobs": 2,
                  "read_frac": 1.0} for j in range(4)]},
]


def test_sim_tuned_beats_frozen_and_knob_trace_stays_clamped():
    frozen = run_autotune_sim_workload("caiti", phases=PHASES,
                                       autotune=None, seed=1)
    ctl = make_default_controller()
    tuned = run_autotune_sim_workload("caiti", phases=PHASES,
                                      autotune=ctl, seed=1)
    assert frozen["ops"] == tuned["ops"]         # same trace both runs
    assert "knob_final" not in frozen            # frozen run is knob-silent
    assert tuned["ops_s"] >= frozen["ops_s"], \
        (tuned["ops_s"], frozen["ops_s"])        # the CI floor, in-tree
    # every applied move in the trace landed inside the declared clamps
    assert tuned["knob_trace"], "controller never engaged on a sync storm"
    for _t, changes in tuned["knob_trace"]:
        for name, v in changes.items():
            lo, hi = ctl.clamp_range(name)
            assert lo <= v <= hi, (name, v)
    for name, v in tuned["knob_final"].items():
        lo, hi = ctl.clamp_range(name)
        assert lo <= v <= hi
    assert tuned["autotune"]["total_moves"] == len(
        [1 for _t, ch in tuned["knob_trace"] for _ in ch])
