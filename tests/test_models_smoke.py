"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED config of the same family, runs one forward/train step on CPU with
shape + finiteness assertions, plus a prefill->decode consistency check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.optim import AdamW
from repro.train.step import make_train_step

pytestmark = pytest.mark.slow      # full-arch sweep: minutes of jit compiles

B, T = 2, 32


def _batch(cfg, rng=0):
    r = np.random.default_rng(rng)
    batch = {"tokens": jnp.asarray(r.integers(0, cfg.vocab, (B, T)), jnp.int32),
             "targets": jnp.asarray(r.integers(0, cfg.vocab, (B, T)),
                                    jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            r.standard_normal((B, cfg.enc_seq, cfg.d_model)), cfg.dtype)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            r.standard_normal((B, cfg.n_img_tokens, cfg.d_model)), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    logits = model.forward(params, _batch(cfg))
    assert logits.shape == (B, T, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_improves_loss(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-2, warmup_steps=1, total_steps=20, clip_norm=1.0)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    batch = _batch(cfg)
    losses = []
    for _ in range(8):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1]), arch
    # overfit one batch: loss must drop
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    """Greedy next-token from (prefill cache + decode_step) must equal the
    argmax from the full forward pass at the same position."""
    if arch == "xlstm-1.3b":
        pytest.xfail("mLSTM prefill-vs-decode bf16 drift marginally exceeds "
                     "the 5e-2 tol on CPU jax 0.4.37 (2/512 elements)")
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        # capacity routing drops differ between T and T+1 forwards; compare
        # under no-drop capacity so the equivalence is well-defined
        import dataclasses
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, rng=1)
    logits_full = model.forward(params, batch)          # (B, T, V)

    pre = {k: v for k, v in batch.items() if k != "targets"}
    logits_pre, cache = model.prefill(params, pre, s_max=T + 8)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-2, atol=2e-2)

    # decode one token and compare against forward on the extended seq
    nxt = jnp.argmax(logits_pre, axis=-1).astype(jnp.int32)
    pos = jnp.full((B,), T, jnp.int32)
    logits_dec, _ = model.decode_step(params, cache, nxt, pos)
    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], nxt[:, None]], axis=1)
    logits_full2 = model.forward(params, ext)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full2[:, -1]),
                               rtol=5e-2, atol=5e-2)


def test_moe_routing_mass_conservation():
    """Every token's selected experts' gates sum to ~1 after renorm."""
    cfg = get_config("qwen3-moe-235b-a22b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    logits = model.forward(params, _batch(cfg))
    assert bool(jnp.isfinite(logits).all())


def test_windowed_attention_ring_cache():
    """recurrentgemma's local attention ring buffer: decoding far past the
    window must still work and match full forward."""
    cfg = get_config("recurrentgemma-9b", smoke=True)
    assert cfg.attn_window > 0
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits_pre, cache = model.prefill(
        params, {"tokens": batch["tokens"]})
    assert bool(jnp.isfinite(logits_pre).all())


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "recurrentgemma-9b"])
def test_ssm_state_is_constant_size(arch):
    """Decode state must not grow with context (long_500k eligibility)."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    c_small = jax.eval_shape(lambda: model.make_cache(2, 64))
    c_large = jax.eval_shape(lambda: model.make_cache(2, 4096))
    s1 = sum(np.prod(l.shape) for l in jax.tree.leaves(c_small))
    s2 = sum(np.prod(l.shape) for l in jax.tree.leaves(c_large))
    if arch == "xlstm-1.3b":
        assert s1 == s2
    else:
        # hybrid: only the bounded attention window grows, capped at window
        assert s2 <= s1 * (cfg.attn_window / 16)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }
    for arch, (L, d, H, kv, ff, V) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == H, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab == V, arch
    moe = get_config("qwen3-moe-235b-a22b").moe
    assert moe.n_experts == 128 and moe.top_k == 8
    moe2 = get_config("moonshot-v1-16b-a3b").moe
    assert moe2.n_experts == 64 and moe2.top_k == 6
    assert get_config("qwen2.5-3b").qkv_bias
