"""Unified admission layer: sequential-scan tier bypass, tier-aware QoS
pricing, the shared bypass watermark, and the GroupCommitter primitive.
(Chained-tx crash atomicity lives in tests/test_volume.py.)"""
import threading
import time

import numpy as np

from repro.core.transit import TransitBuffer
from repro.volume import (AdmissionPolicy, GroupCommitter, ReadTier,
                          ScanDetector, make_volume)


def _blk(x: int) -> bytes:
    return bytes([x % 256]) * 4096


# ------------------------------------------------------------- detector
def test_scan_detector_tracks_interleaved_streams():
    d = ScanDetector(max_streams=4)
    # two interleaved sequential streams + random noise: each stream's
    # run keeps growing, noise stays at run length 1
    for i in range(10):
        assert d.observe("ns", 100 + i) == i + 1
        assert d.observe("ns", 500 + i) == i + 1
        assert d.observe("ns", 7919 * i) in (1, 2)
    assert d.current_run("ns", 109) == 10
    assert d.current_run("ns", 42) == 1


def test_scan_detector_noise_does_not_evict_active_streams():
    """REGRESSION: one-shot noise accesses (random reads from other
    tenants interleaved with the streams) used to push ESTABLISHED run
    counters out of the bounded table — each noise access inserts a new
    expectation and the coldest entry evicted was an active stream.
    Eviction now prefers run-length-1 entries, so interleaved sequential
    streams from different tenants keep their counters under noise."""
    d = ScanDetector(max_streams=4)
    for i in range(2):                       # streams establish (run >= 2)
        assert d.observe("ns", 1000 + i) == i + 1     # tenant A's stream
        assert d.observe("ns", 5000 + i) == i + 1     # tenant B's stream
    for i in range(2, 50):                   # then heavy noise interleaves
        assert d.observe("ns", 1000 + i) == i + 1
        assert d.observe("ns", 5000 + i) == i + 1
        for k in range(3):                            # 3 one-shot noise
            d.observe("ns", 1_000_000 + 7919 * i + 13 * k)
    assert d.current_run("ns", 1049) == 50
    assert d.current_run("ns", 5049) == 50


def test_scan_detector_eviction_bound_holds():
    """The multi-stream table stays bounded at max_streams even when
    more genuine streams than slots interleave — capacity is traded
    between them (counters churn), never exceeded."""
    d = ScanDetector(max_streams=4)
    for i in range(10):
        for s in range(6):                   # 6 streams > 4 slots
            d.observe("ns", 100 * s + i)
    assert len(d._streams["ns"]) <= 4
    # within capacity every stream keeps growing
    d2 = ScanDetector(max_streams=4)
    for i in range(10):
        for s in range(4):
            assert d2.observe("ns", 100 * s + i) == i + 1


def test_scan_detector_expectation_collision_keeps_longer_run():
    """REGRESSION: a one-shot access at (stream head - 1) writes the
    SAME expectation key the established run owns — it must not clobber
    the counter (the overwrite variant of noise killing a stream)."""
    d = ScanDetector(max_streams=8)
    for i in range(10):
        d.observe("ns", 100 + i)             # run: 100..109, expects 110
    assert d.observe("ns", 109) == 1         # noise re-read of the head
    assert d.current_run("ns", 109) == 10    # counter survived
    assert d.observe("ns", 110) == 11        # the scan continues


def test_scan_detector_new_stream_establishes_under_noise():
    """REGRESSION: with the table full of stale established counters, a
    NEW scan with one noise access interleaved per step must still
    establish — run-1 protection must not evict the scan's own first
    expectation while stale entries pin the table."""
    d = ScanDetector(max_streams=4)
    for s in range(4):                       # 4 scans run and finish
        for i in range(12):
            d.observe("ns", 1000 * s + i)
    for i in range(10):                      # new scan + 1 noise / step
        assert d.observe("ns", 9000 + i) == i + 1, i
        d.observe("ns", 500_000 + 7919 * i)
    assert d.current_run("ns", 9009) == 10


def test_scan_detector_stale_streams_age_out_for_new_scans():
    """REGRESSION (starvation): counters left behind by FINISHED scans
    must not pin the table forever — a new sequential scan arriving
    when every slot holds a stale established run must still be able to
    establish (the just-inserted expectation survives, the least
    recently extended stale entry is evicted)."""
    d = ScanDetector(max_streams=4)
    for s in range(4):                       # 4 scans run and finish
        for i in range(12):
            d.observe("ns", 1000 * s + i)
    # a 5th scan starts against a table full of stale run counters
    for i in range(10):
        assert d.observe("ns", 9000 + i) == i + 1, i
    assert d.current_run("ns", 9009) == 10


def test_volume_interleaved_tenant_scans_both_detected():
    """End to end: two tenants scanning concurrently (interleaved at the
    volume) must BOTH trip the scan-bypass once past the threshold —
    neither resets the other's run."""
    vol = make_volume("caiti", n_lbas=2048, n_shards=2, stripe_blocks=4,
                      cache_bytes=1024 * 4096, read_tier_bytes=64 * 4096,
                      scan_threshold=8)
    try:
        for lba in range(1024):
            vol.write(lba, _blk(lba + 1))
        vol.fsync()
        vol.read_tier.clear()
        # interleave two disjoint sequential scans + per-round noise
        for i in range(64):
            assert bytes(vol.read(256 + i)) == _blk(256 + i + 1)
            assert bytes(vol.read(768 + i)) == _blk(768 + i + 1)
            vol.read((37 * i + 11) % 256)    # random-reader tenant
        # each volume-level scan is 2 per-shard sequential streams (the
        # stripes interleave, per-shard locals stay consecutive): 4
        # streams x ~(32 - 8) denials — both tenants' scans tripped
        snap = vol.metrics_snapshot()
        assert snap["admission"]["scan_fill_denials"] >= 80
    finally:
        vol.close()


def test_admission_denies_fills_past_scan_threshold():
    adm = AdmissionPolicy(scan_threshold=4)
    denied = 0
    for i in range(10):
        adm.observe_read(0, i)
        if not adm.admit_tier_fill(0, i):
            denied += 1
    assert denied == 6                       # first 4 admitted
    assert adm.stats()["scan_fill_denials"] == 6
    # random access pattern is never denied
    for lba in (3, 999, 17, 512):
        adm.observe_read(1, lba)
        assert adm.admit_tier_fill(1, lba)


def test_admission_scan_threshold_zero_disables():
    adm = AdmissionPolicy(scan_threshold=0)
    for i in range(100):
        adm.observe_read(0, i)
        assert adm.admit_tier_fill(0, i)


def test_admission_watermark_bypass():
    staged = {"n": 0}
    adm = AdmissionPolicy(staged_slots_fn=lambda: staged["n"],
                          watermark_slots=10)
    assert not adm.should_bypass_write()
    staged["n"] = 10
    assert adm.should_bypass_write()


def test_read_charge_prices_dram_below_pmem():
    adm = AdmissionPolicy(tier_hit_cost_frac=0.125)
    assert adm.read_charge(4096, "backend") == 4096
    assert adm.read_charge(4096, "tier") == 512
    assert adm.read_charge(4096, "transit") == 512


# ----------------------------------------------------- tier integration
def test_tier_insert_respects_admission_on_fills_only():
    tier = ReadTier(16 * 4096, 4096)
    adm = AdmissionPolicy(scan_threshold=2)
    tier.admission = adm
    for i in range(6):
        adm.observe_read(0, i)
    # read-miss fill (token path) from a long run: denied
    token = tier.prepare((0, 5))
    assert not tier.insert((0, 5), _blk(5), token=token)
    # writeback insert (no token) is authoritative: always admitted
    assert tier.insert((0, 5), _blk(5))
    assert bytes(tier.lookup((0, 5))) == _blk(5)


def test_volume_scan_bypass_preserves_hot_set():
    """A giant sequential scan must not flush the tier's hot set: fills
    are denied past the threshold and the hot keys keep hitting."""
    vol = make_volume("caiti", n_lbas=2048, n_shards=2, stripe_blocks=4,
                      cache_bytes=1024 * 4096, read_tier_bytes=64 * 4096,
                      scan_threshold=8)
    try:
        hot = list(range(0, 64, 9))              # non-sequential stride
        for lba in range(512):
            vol.write(lba, _blk(lba + 1))
        vol.fsync()
        vol.read_tier.clear()                    # cold start
        for lba in hot:                          # build the hot set
            assert bytes(vol.read(lba)) == _blk(lba + 1)
        # giant scan: 256 sequential reads, only ~threshold may fill
        for lba in range(256, 512):
            assert bytes(vol.read(lba)) == _blk(lba + 1)
        snap = vol.metrics_snapshot()
        assert snap["admission"]["scan_fill_denials"] >= 200
        assert snap["tier_fill_bypassed"] >= 200
        # the hot set survived the scan: every hot read is a tier hit
        before = vol.metrics_snapshot()["read_tier_hits"]
        for lba in hot:
            assert bytes(vol.read(lba)) == _blk(lba + 1)
        assert vol.metrics_snapshot()["read_tier_hits"] - before \
            == len(hot)
    finally:
        vol.close()


def test_volume_without_scan_bypass_floods_tier():
    """Control for the test above: with scan detection off the same scan
    fills the tier block after block."""
    vol = make_volume("caiti", n_lbas=2048, n_shards=2, stripe_blocks=4,
                      cache_bytes=1024 * 4096, read_tier_bytes=64 * 4096,
                      scan_threshold=0)
    try:
        for lba in range(512):
            vol.write(lba, _blk(lba + 1))
        vol.fsync()
        vol.read_tier.clear()
        for lba in range(256, 512):
            vol.read(lba)
        assert vol.metrics_snapshot()["read_tier_fills"] >= 200
        assert vol.metrics_snapshot()["admission"]["scan_fill_denials"] == 0
    finally:
        vol.close()


# --------------------------------------------------- tier-aware QoS cost
def test_tier_hot_tenant_not_throttled_like_pmem_bound():
    """ROADMAP follow-on: a ReadTier hit must not debit the tenant token
    bucket at PMem-read cost.  The tier-hot tenant is charged the DRAM
    fraction (and never sleeps on the bucket); the PMem-bound tenant is
    charged full price and rate-limited."""
    vol = make_volume("caiti", n_lbas=512, n_shards=2,
                      cache_bytes=64 * 4096, read_tier_bytes=64 * 4096,
                      tier_hit_cost_frac=0.125)
    try:
        vol.add_tenant("hot", rate_mbps=1.0, burst_bytes=8 * 4096)
        vol.add_tenant("cold", rate_mbps=1.0, burst_bytes=8 * 4096)
        for lba in range(32):
            vol.write(lba, _blk(lba))
        vol.fsync()                      # writebacks populated the tier
        # 16 tier-served reads: 16 * 512B = 8KB of DRAM-priced debit —
        # under the burst, and charge() never sleeps: finishes instantly
        t0 = time.perf_counter()
        for k in range(16):
            assert bytes(vol.read(k % 8, tenant="hot")) == _blk(k % 8)
        hot_s = time.perf_counter() - t0
        assert vol.read_debits["hot"] == 16 * 512
        assert hot_s < 1.0
        # the same 16 reads PMem-bound: full 4K debit each (64KB against
        # a 32KB burst at 1 MB/s) — the bucket must make the tenant wait
        vol.read_tier.clear()
        t0 = time.perf_counter()
        for k in range(16):
            lba = 256 + k                # cold lbas: backend reads
            vol.write(lba, _blk(lba))
        vol.fsync()
        vol.read_tier.clear()
        t0 = time.perf_counter()
        for k in range(16):
            vol.read(256 + k, tenant="cold")
        cold_s = time.perf_counter() - t0
        assert vol.read_debits["cold"] == 16 * 4096
        assert cold_s > 0.01             # really throttled
        assert cold_s > hot_s
    finally:
        vol.close()


# -------------------------------------------------- transit-buffer hook
def test_transit_buffer_consults_admission():
    staged = {"over": False}

    class _Adm:
        def should_bypass_write(self):
            return staged["over"]

    sunk = []
    tb = TransitBuffer(sunk.append, capacity_bytes=1 << 20, n_workers=1,
                       admission=_Adm())
    try:
        assert tb.put(b"a", 100) == "staged"
        staged["over"] = True            # global watermark crossed
        assert tb.put(b"b", 100) == "bypass"
        staged["over"] = False
        assert tb.put(b"c", 100) == "staged"
        tb.flush()
        assert tb.metrics.snapshot()["count"]["bypass_writes"] == 1
    finally:
        tb.close()


# ------------------------------------------------------- GroupCommitter
def test_group_committer_single_caller_commits():
    n = {"commits": 0}

    def commit():
        n["commits"] += 1

    gc = GroupCommitter(commit)
    assert gc.sync() is True             # led its own commit
    assert gc.sync() is True
    assert n["commits"] == 2
    assert gc.stats() == {"calls": 2, "commits": 2, "coalesced": 0}


def test_group_committer_coalesces_and_covers_every_caller():
    order = []
    gate = threading.Event()

    def commit():
        gate.wait(5.0)                   # hold the leader mid-commit
        order.append("commit")

    gc = GroupCommitter(commit, window=0.05)
    results = []

    def caller():
        results.append(gc.sync())

    ts = [threading.Thread(target=caller) for _ in range(6)]
    ts[0].start()
    time.sleep(0.02)                     # leader inside its window
    for t in ts[1:]:
        t.start()
    time.sleep(0.05)
    gate.set()
    for t in ts:
        t.join(timeout=5)
    st = gc.stats()
    assert st["calls"] == 6
    assert st["commits"] + st["coalesced"] == 6
    assert st["commits"] <= 3            # a leader served the batch
    assert st["coalesced"] >= 3
    assert sum(results) == st["commits"]  # True == led


def test_group_committer_propagates_leader_error_to_batch():
    def commit():
        raise RuntimeError("media gone")

    gc = GroupCommitter(commit)
    try:
        gc.sync()
        raise AssertionError("expected RuntimeError")
    except RuntimeError:
        pass


# ------------------------------------------------ chained ckpt commits
def test_blockstore_uses_chained_commit_on_volumes(tmp_path):
    from repro.ckpt.blockstore import make_blockstore
    st = make_blockstore(str(tmp_path / "st"), policy="caiti",
                         capacity_bytes=8 << 20, cache_bytes=2 << 20,
                         n_shards=2)
    try:
        assert st._chained                       # volume: chained commit
        st.put("k", b"v" * 10_000)
        gen = st.commit()
        # the chained path journals root+manifest as ONE logical write
        assert st.dev.metrics_snapshot()["chains_logged"] >= 1
    finally:
        st.close()
    st2 = make_blockstore(str(tmp_path / "st"), policy="caiti",
                          capacity_bytes=8 << 20, cache_bytes=2 << 20,
                          n_shards=2)
    try:
        assert st2.generation == gen
        assert st2.get("k") == b"v" * 10_000
    finally:
        st2.close()


def test_blockstore_fallback_never_overwrites_active_manifest():
    """Mixed-mode regression: after a chained commit parks the root on
    region 0, a later commit whose manifest outgrows the journal ring
    falls back to ping-pong — and must pick the OTHER region, never the
    one the live root points at (else a crash mid-fallback destroys the
    previous generation)."""
    from repro.ckpt.blockstore import BlockStore
    vol = make_volume("caiti", n_lbas=4096, n_shards=2,
                      cache_bytes=2 << 20, journal_slots=4, journal_span=2)
    st = BlockStore(vol, 4096, manifest_blocks=16)
    try:
        assert vol.max_atomic_write_blocks() == 8
        st.put("a", b"x" * 100)
        st.commit()                              # chained: root on mlba 1
        assert st._active_mlba == 1
        for i in range(1500):                    # manifest > 7 blocks now
            st.directory[f"key-{i:04d}"] = (33, 1, 100)
        gen = st.commit()                        # falls back to ping-pong
        assert st._active_mlba == 1 + 16         # NOT the live region
        st2 = BlockStore(vol, 4096, manifest_blocks=16)
        assert st2.generation == gen
        assert len(st2.directory) == len(st.directory)
    finally:
        vol.close()


def test_blockstore_single_device_keeps_root_flip(tmp_path):
    from repro.ckpt.blockstore import make_blockstore
    st = make_blockstore(str(tmp_path / "st1"), policy="caiti",
                         capacity_bytes=8 << 20, cache_bytes=2 << 20)
    try:
        assert not st._chained                   # ping-pong + root flip
        st.put("k", b"x" * 5000)
        st.commit()
        assert st.get("k") == b"x" * 5000
    finally:
        st.close()
