"""Deterministic crash/fault-injection harness for the async I/O frontend.

The engine's deterministic mode (``n_workers=0``: nothing executes until
``poll``/``wait`` runs queued ops inline, in submission order) makes
every interleaving of submit / poll / crash a *replayable schedule*:

  * :class:`AsyncRun` — drives one volume through an explicit schedule of
    sync calls, async submissions, IO_LINK chained submissions and
    polls, recording completion order and per-ticket outcomes;
  * :func:`check_chain_invariants` — the linked-SQE contract as a swept
    property: dependents never complete before their parent, a failed
    link cancels (never silently drops) the rest of its chain, tickets
    before the failed link keep their own outcome;
  * :func:`crash_on_nth_btt_write` — global (cross-shard) crash injection
    at BTT-write granularity, the same counter the PR 3/4 sweeps align
    with the ``chain_commit_steps`` protocol model;
  * :func:`crash_sweep` — re-runs a schedule against a fresh file-backed
    volume with a crash injected at write point 1, 2, 3, ... until a run
    survives, reopening + recovering after each crash and handing every
    observation to an invariant checker.  This is how "a crash ANYWHERE
    never replays a partial member chain and never loses a completed
    ticket" becomes a swept property instead of a hand-picked example;
  * :func:`fail_shard_writes` — injected *device* errors (not crashes):
    BTT writes on one shard raise ``IOError``, which must surface as
    per-ticket failures, leaving the ring serving other tenants;
  * :func:`slow_shard_reads` — injected *fail-slow* behavior (the PR 8
    limplock mode): backend reads on one shard stall for a fixed delay,
    optionally dying after N slowed reads (slow-then-die) or returning
    to full speed (slow-then-recover) — the hedged-read sweeps drive
    every combination of slow/dead/racing legs through this;
  * :class:`VersionedObjects` + :func:`random_schedule` — seeded
    generator of interleaved multi-tenant schedules over versioned
    objects, with whole-object / monotone-version / completed-never-lost
    invariant checking after a clean run or a crash+recovery.

Durability contract the invariants rely on (matching the synchronous
sweeps in tests/test_volume.py): chained ``write_multi`` ops are durable
the moment they complete — the redo journal's tail header landed before
the call/ticket finished, so recovery rolls the whole chain forward.
Plain single-block writes are only crash-durable on ``btt``-policy
volumes (no staging), which is what the sweeps use.
"""
from __future__ import annotations

import numpy as np

from repro.core import SimulatedCrash
from repro.volume import make_volume


def blk(x: int) -> bytes:
    return bytes([x % 256]) * 4096


# ------------------------------------------------------- fault injection
def crash_on_nth_btt_write(vol, n: int) -> dict:
    """Arm a global (cross-shard) crash on BTT write number ``n``; the
    returned state dict's ``count`` says how many writes were attempted
    (``count - 1`` completed when the crash fired)."""
    state = {"count": 0}
    for d in vol.shards:
        btt = d.impl.btt
        orig = btt.write

        def wrapped(lba, data, _orig=orig):
            state["count"] += 1
            if state["count"] == n:
                raise SimulatedCrash("btt_write")
            return _orig(lba, data)

        btt.write = wrapped
    return state


def fail_shard_writes(vol, shard: int, local_lbas=None,
                      exc=IOError) -> dict:
    """Inject DEVICE errors (not crashes): BTT writes on ``shard`` —
    optionally only to ``local_lbas`` — raise ``exc``.  The media is
    untouched; the failure must surface on the one ticket whose op hit
    it."""
    state = {"failures": 0}
    btt = vol.shards[shard].impl.btt
    orig = btt.write

    def wrapped(lba, data, _orig=orig):
        if local_lbas is None or lba in local_lbas:
            state["failures"] += 1
            raise exc(f"injected device error: shard {shard} lba {lba}")
        return _orig(lba, data)

    btt.write = wrapped
    state["restore"] = lambda: setattr(btt, "write", orig)
    return state


def slow_shard_reads(vol, shard: int, delay_s: float, *,
                     die_after: int | None = None,
                     recover_after: int | None = None) -> dict:
    """Inject FAIL-SLOW read behavior on ``shard`` (the limplock mode
    hedged reads exist for): every cache/backend read first stalls
    ``delay_s`` wall seconds.  ``die_after=N`` turns the Nth-and-later
    slowed reads into ``IOError`` AFTER the stall (slow-then-die: the
    hedge must already be winning when the primary finally errors);
    ``recover_after=N`` restores full speed after N slowed reads
    (slow-then-recover: later reads must take the no-hedge fast path).
    Returns ``{"slowed": count, "restore": fn}``."""
    import time as _time
    impl = vol.shards[shard].impl
    attr = "read_ex" if hasattr(impl, "read_ex") else "read"
    orig = getattr(impl, attr)
    state = {"slowed": 0}

    def wrapped(local, out=None, **kw):
        if recover_after is not None and state["slowed"] >= recover_after:
            return orig(local, out=out, **kw)
        state["slowed"] += 1
        _time.sleep(delay_s)
        if die_after is not None and state["slowed"] >= die_after:
            raise IOError(f"injected fail-slow death: shard {shard}")
        return orig(local, out=out, **kw)

    setattr(impl, attr, wrapped)
    state["restore"] = lambda: setattr(impl, attr, orig)
    return state


def volume_lba_on_shard(vol, shard: int, start: int = 0) -> int:
    """Smallest volume lba >= ``start`` whose primary copy lives on
    ``shard`` (so error-injection tests can aim an op at the bad
    device)."""
    for lba in range(start, vol.n_lbas):
        if vol._map(lba, 0)[0] == shard:
            return lba
    raise AssertionError(f"no lba maps to shard {shard}")


# ---------------------------------------------------- schedule execution
class AsyncRun:
    """One deterministic run: an inline-mode engine driven through a
    schedule of steps, each a tuple:

      ("submit_multi", name, lba, blocks)   async chained write
      ("submit_write", name, lba, data)     async single-block write
      ("submit_read",  name, lba)           async read
      ("submit_read_out", name, lba, out)   async read landing into out=
      ("cancel", name)                      cancel a ticket (hedge-loser
                                            path: an out= landing target
                                            must never see partial data)
      ("submit_fsync", name)                async barrier + group commit
      ("link_write", name, parent, lba, data)   write linked behind parent
      ("link_multi", name, parent, lba, blocks) chained write, linked
      ("link_read",  name, parent, lba)         read linked behind parent
      ("link_fsync", name, parent)              fsync linked behind parent
      ("poll", max_ops | None)              execute queued ops inline
      ("sync_multi", lba, blocks)           blocking write_multi
      ("sync_write", lba, data)             blocking write
      ("fsync",)                            blocking fsync

    ``tickets`` maps names to tickets; ``executed_sync`` counts blocking
    steps that ran to completion; ``completion_order`` records ticket
    names in the order the completion ring surfaced them (the IO_LINK
    ordering invariants read this).  A ``SimulatedCrash`` aborts the run
    exactly where power was lost — tickets completed before that point
    keep ``ok == True``, everything queued is failed by the dying ring.

    The ``link_*`` steps build IO_LINK chains: ``parent`` names an
    earlier ticket; the engine holds the child until the parent
    completes OK and cancels it (ECANCELED) when the parent fails.
    """

    def __init__(self, vol) -> None:
        self.vol = vol
        self.eng = vol.aio_engine(n_workers=0)
        self.tickets: dict[str, object] = {}
        self.executed_sync: list[tuple] = []
        self.completion_order: list[str] = []
        self._names: dict[int, str] = {}       # id(ticket) -> name

    def _track(self, name: str, ticket) -> None:
        self.tickets[name] = ticket
        self._names[id(ticket)] = name

    def _drain(self, max_ops=None) -> None:
        for t in self.eng.poll(max_ops):
            self.completion_order.append(
                self._names.get(id(t), f"tid{t.tid}"))

    def step(self, s: tuple) -> None:
        kind = s[0]
        if kind == "submit_multi":
            _, name, lba, blocks = s
            self._track(name, self.eng.submit("write_multi", lba,
                                              blocks=blocks))
        elif kind == "submit_write":
            _, name, lba, data = s
            self._track(name, self.eng.submit("write", lba, data=data))
        elif kind == "submit_read":
            _, name, lba = s
            self._track(name, self.eng.submit("read", lba))
        elif kind == "submit_read_out":
            _, name, lba, out = s
            self._track(name, self.eng.submit("read", lba, out=out))
        elif kind == "cancel":
            self.eng.cancel(self.tickets[s[1]])
        elif kind == "submit_fsync":
            self._track(s[1], self.eng.submit("fsync"))
        elif kind == "link_write":
            _, name, parent, lba, data = s
            self._track(name, self.eng.submit(
                "write", lba, data=data, link_to=self.tickets[parent]))
        elif kind == "link_multi":
            _, name, parent, lba, blocks = s
            self._track(name, self.eng.submit(
                "write_multi", lba, blocks=blocks,
                link_to=self.tickets[parent]))
        elif kind == "link_read":
            _, name, parent, lba = s
            self._track(name, self.eng.submit(
                "read", lba, link_to=self.tickets[parent]))
        elif kind == "link_fsync":
            _, name, parent = s
            self._track(name, self.eng.submit(
                "fsync", link_to=self.tickets[parent]))
        elif kind == "poll":
            self._drain(s[1])
        elif kind == "sync_multi":
            _, lba, blocks = s
            self.vol.write_multi(lba, blocks)
            self.executed_sync.append(s)
        elif kind == "sync_write":
            _, lba, data = s
            self.vol.write(lba, data)
            self.executed_sync.append(s)
        elif kind == "fsync":
            self.vol.fsync()
            self.executed_sync.append(s)
        else:
            raise ValueError(s)

    def run(self, schedule) -> "AsyncRun":
        for s in schedule:
            self.step(s)
        self._drain(None)            # settle any stragglers
        return self

    def ok_tickets(self) -> set[str]:
        """Names of tickets that completed successfully (before a crash,
        if one fired)."""
        return {name for name, t in self.tickets.items() if t.ok}


def check_chain_invariants(run: AsyncRun, chains) -> None:
    """IO_LINK invariants over named ticket chains (each chain a list of
    ticket names in link order), valid after a clean run, an injected
    device error, or a crash:

      * **in-order completion**: a dependent never surfaces on the
        completion ring before its parent — ``completion_order``
        respects chain order for every pair that was recorded;
      * **fail-stop cascade, never a silent drop**: once a link fails,
        every LATER submitted ticket in the chain resolves with an
        error (ECANCELED from the cascade, or the dying ring's
        SubmitError after a crash) — it never completes ok, and it
        never ends in limbo with neither a success nor an error;
      * **isolation**: tickets BEFORE the failed link keep their own
        outcome (a dependent's cancellation never reaches back up).

    Only tickets the schedule actually submitted are checked — a crash
    that aborts the run mid-chain leaves the tail unsubmitted, which is
    the caller's power-loss semantics, not a harness failure.
    """
    pos = {name: i for i, name in enumerate(run.completion_order)}
    for chain in chains:
        live = [n for n in chain if n in run.tickets]
        for parent, child in zip(live, live[1:]):
            if parent in pos and child in pos:
                assert pos[parent] < pos[child], \
                    (f"dependent {child!r} completed before its link "
                     f"parent {parent!r}: {run.completion_order}")
        failed_at = next((i for i, n in enumerate(live)
                          if run.tickets[n].error is not None), None)
        if failed_at is None:
            continue
        for n in live[:failed_at]:
            assert run.tickets[n].ok, \
                f"{n!r} precedes the failed link but is not ok"
        for n in live[failed_at + 1:]:
            t = run.tickets[n]
            assert not t.ok, \
                f"{n!r} completed OK after its link parent failed"
            assert t.error is not None, \
                (f"{n!r} was silently dropped: chain parent failed but "
                 f"the dependent has neither a result nor an error")


# ----------------------------------------------------------- crash sweep
def run_crash_point(path: str, n: int, schedule_fn, *, vol_kw,
                    prep_fn=None):
    """One crash point: build a fresh file-backed volume at ``path``,
    run ``prep_fn(vol)`` un-instrumented (base state + fsync), arm
    :func:`crash_on_nth_btt_write` at write ``n``, run ``schedule_fn()``
    through an :class:`AsyncRun`, simulate power loss (persist mmaps,
    abandon the object) and reopen + recover.  Returns
    ``(writes_done, crashed, run, reopened_vol)`` — the caller checks
    invariants and closes the volume."""
    vol = make_volume(path=path, **vol_kw)
    if prep_fn is not None:
        prep_fn(vol)
    state = crash_on_nth_btt_write(vol, n)
    run = AsyncRun(vol)
    crashed = True
    try:
        run.run(schedule_fn())
        crashed = False
    except SimulatedCrash:
        pass
    for d in vol.shards:             # power loss keeps media state
        d.impl.btt.pmem.persist()
    del vol
    vol2 = make_volume(path=path, **vol_kw)
    done = state["count"] - (1 if crashed else 0)
    return done, crashed, run, vol2


def crash_sweep(tmp_path, schedule_fn, check_fn, *, vol_kw,
                prep_fn=None, max_points: int = 2000) -> int:
    """Property-sweep a schedule over EVERY BTT write point: run
    :func:`run_crash_point` for n = 1, 2, ... and hand every observation
    to ``check_fn(n, writes_done, crashed, run, reopened_vol)``.  Stops
    after the first run that survives (every write point swept) and
    returns how many points that took."""
    n = 1
    while n <= max_points:
        done, crashed, run, vol2 = run_crash_point(
            str(tmp_path / f"sweep{n}"), n, schedule_fn,
            vol_kw=vol_kw, prep_fn=prep_fn)
        try:
            check_fn(n, done, crashed, run, vol2)
        finally:
            vol2.close()
        if not crashed:
            return n
        n += 1
    raise AssertionError(f"sweep did not terminate in {max_points} points")


# ------------------------------------------- seeded interleaved schedules
class VersionedObjects:
    """O disjoint multi-block objects, each carrying a version counter.
    Block i of object o at version v is a distinct constant pattern, so
    a read-back either matches exactly one whole version or is torn."""

    def __init__(self, n_objects: int = 4, n_blocks: int = 4,
                 stride: int = 16, base_lba: int = 8) -> None:
        self.n_objects = n_objects
        self.n_blocks = n_blocks
        self.lbas = [base_lba + o * stride for o in range(n_objects)]
        self.issued: list[int] = [0] * n_objects     # highest version issued

    def pattern(self, o: int, v: int) -> list[bytes]:
        return [blk(17 + o * 31 + v * 7 + i) for i in range(self.n_blocks)]

    def write_base(self, vol) -> None:
        for o in range(self.n_objects):
            vol.write_multi(self.lbas[o], self.pattern(o, 0))
        vol.fsync()

    def next_version(self, o: int) -> tuple[int, int, list[bytes]]:
        self.issued[o] += 1
        return self.lbas[o], self.issued[o], self.pattern(o, self.issued[o])

    def read_version(self, vol, o: int) -> int:
        """The whole version object ``o`` holds on ``vol``, or -1 if the
        blocks do not match any single issued version (TORN — the
        atomicity violation the sweeps exist to catch)."""
        got = [bytes(vol.read(self.lbas[o] + i))
               for i in range(self.n_blocks)]
        for v in range(self.issued[o] + 1):
            if got == self.pattern(o, v):
                return v
        return -1


def random_schedule(rng: np.random.Generator, objs: VersionedObjects,
                    n_steps: int = 24) -> list[tuple]:
    """Seeded interleaving of async submissions, polls, sync writes and
    fsync barriers over the versioned objects.  Ticket names encode the
    (object, version) they wrote so invariants can be checked later.

    Writes to ONE object are serialized against its queued-but-not-yet-
    executed async write (the generator mirrors the inline engine's
    FIFO to know what is still pending): version order == execution
    order per object, so "surviving version >= highest completed
    version" is exactly the completed-tickets-are-never-lost claim.
    Cross-object interleaving stays fully random."""
    sched: list[tuple] = []
    pending: list[object] = []       # queued, unexecuted: object id | "F"
    for k in range(n_steps):
        r = rng.random()
        busy = {p for p in pending if p != "F"}
        free = [o for o in range(objs.n_objects) if o not in busy]
        if r < 0.40 and free:
            o = free[int(rng.integers(len(free)))]
            lba, v, blocks = objs.next_version(o)
            sched.append(("submit_multi", f"o{o}v{v}", lba, blocks))
            pending.append(o)
        elif r < 0.55 and free:
            o = free[int(rng.integers(len(free)))]
            lba, v, blocks = objs.next_version(o)
            sched.append(("sync_multi", lba, blocks))
        elif r < 0.70:
            sched.append(("poll", 1))
            if pending:
                pending.pop(0)
        elif r < 0.85:
            sched.append(("poll", None))
            pending.clear()
        elif r < 0.95:
            sched.append(("submit_fsync", f"fsync{k}"))
            pending.append("F")
        else:
            sched.append(("fsync",))
    sched.append(("poll", None))
    return sched


def check_versioned_invariants(objs: VersionedObjects, run: AsyncRun,
                               vol, crashed: bool) -> None:
    """Post-run (and post-recovery, if crashed) invariants of a
    versioned-object schedule:

      * **whole-object**: every object reads back exactly one version —
        never a torn mix of two (``read_version != -1``);
      * **completed tickets are never lost**: an async chained write
        whose ticket completed OK is durable, so the surviving version
        is >= it; likewise every blocking ``sync_multi`` that returned;
      * **no invented data**: the surviving version never exceeds the
        highest version issued (vacuously true via read_version).

    Versions are monotone per object (each writer bumps the counter),
    so "v >= floor" is exactly "nothing committed was rolled back".
    """
    floors = [0] * objs.n_objects
    for s in run.executed_sync:
        if s[0] == "sync_multi":
            o = objs.lbas.index(s[1])
            floors[o] = max(floors[o], _version_of(objs, o, s[2]))
    for name in run.ok_tickets():
        if name.startswith("o") and "v" in name:
            o, v = name[1:].split("v")
            floors[int(o)] = max(floors[int(o)], int(v))
    for o in range(objs.n_objects):
        v = objs.read_version(vol, o)
        assert v != -1, f"object {o} is TORN after " \
                        f"{'crash+recovery' if crashed else 'clean run'}"
        assert v >= floors[o], \
            (f"object {o} lost committed version: read v{v}, but "
             f"v{floors[o]} had completed before the crash")


def _version_of(objs: VersionedObjects, o: int, blocks) -> int:
    first = bytes(blocks[0])
    for v in range(objs.issued[o] + 1):
        if first == objs.pattern(o, v)[0]:
            return v
    raise AssertionError("unknown version payload")


# ---------------------------------------------------- cluster kill sweep
def kill_node_on_nth_step(cluster, n: int) -> dict:
    """Arm the cluster's ``step_hook`` to fail-stop the node involved in
    pipeline step ``n`` — the cluster fires the hook immediately BEFORE
    each transfer ("xfer"), durable member write ("write") and
    acknowledgement ("ack") step, so sweeping n covers power loss at
    every point of the replication pipeline.  The returned state's
    ``fired`` records ``(step_no, phase, node_idx)`` once the kill
    lands, or stays None when the schedule finished under step ``n``
    (the sweep's termination signal)."""
    state = {"fired": None}

    def hook(step_no: int, phase: str, node_idx: int) -> None:
        if step_no == n and state["fired"] is None:
            state["fired"] = (step_no, phase, node_idx)
            cluster.kill_node(node_idx)

    cluster.step_hook = hook
    return state


def cluster_kill_sweep(make_cluster_fn, schedule_fn, check_fn, *,
                       max_points: int = 2000) -> int:
    """Property-sweep a cluster schedule over EVERY pipeline step: for
    n = 1, 2, ... build a fresh cluster via ``make_cluster_fn()``, arm
    :func:`kill_node_on_nth_step` at step ``n``, drive
    ``schedule_fn(cluster)`` (which must absorb per-op ``ClusterError``
    failures itself and remember what was acknowledged), then hand
    ``check_fn(n, fired, cluster)`` the observation — fired is None on
    the terminating kill-free run.  This is the distributed sibling of
    :func:`crash_sweep`: "a node death ANYWHERE in the write pipeline
    never loses an acknowledged write and never tears an object"
    becomes a swept property."""
    n = 1
    while n <= max_points:
        cl = make_cluster_fn()
        state = kill_node_on_nth_step(cl, n)
        try:
            schedule_fn(cl)
        finally:
            cl.step_hook = None
        try:
            check_fn(n, state["fired"], cl)
        finally:
            cl.close()
        if state["fired"] is None:
            return n
        n += 1
    raise AssertionError(f"sweep did not terminate in {max_points} points")
