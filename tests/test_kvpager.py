"""Volume-backed KV paging (serve/kvpager.py + the kvcache spill tier)
and the PR-10 bugfix sweep of the cache's concurrency/capacity edges.

The three regression tests (concurrent deactivate, max_pages_per_seq,
drain_evictions timeout) fail on the pre-fix cache: unlocked table/free
-list mutation double-frees pool pages, an over-long sequence either
got an HBM page the dense table cannot index or died deep in table_for,
and an expired eviction barrier silently proceeded mid-mutation."""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metrics import KV_PAGING_COUNTERS, Metrics
from repro.serve import KVPager, PagedCacheConfig, PagedKVCache
from repro.volume.volume import make_volume


def _vol(n_lbas=1024):
    return make_volume(n_lbas=n_lbas, n_shards=2, aio_workers=2,
                       cache_bytes=1 << 22)


def _cfg(**kw):
    base = dict(n_layers=2, n_kv_heads=2, head_dim=8, page_size=4,
                n_pages=8, host_pages=64, max_pages_per_seq=8,
                read_tier_pages=8)
    base.update(kw)
    return PagedCacheConfig(**base)


def _fill(cache, sid, n_tokens, rng):
    L = cache.cfg.n_layers
    H, hd = cache.cfg.n_kv_heads, cache.cfg.head_dim
    for _ in range(n_tokens):
        k = jnp.asarray(rng.normal(size=(H, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(H, hd)), jnp.float32)
        cache.append_token(sid, [k] * L, [v] * L)


# ------------------------------------------------------------------ pager
def test_pager_roundtrip_dedup_and_slot_reuse():
    m = Metrics()
    pager = KVPager(_vol(), capacity_blocks=64, metrics=m)
    payload = bytes(range(256)) * 20               # 5120 B -> 2 blocks
    h1 = pager.spill(payload)
    h2 = pager.spill(payload)                      # content-hash dedup
    assert h1 == h2
    assert m.count["kv_dedup_hits"] == 1
    assert m.count["kv_spills"] == 1
    assert pager.fetch(h1) == payload
    other = pager.spill(b"different" * 600)
    assert other != h1
    free0 = pager.free_slots()
    pager.release(h1)
    assert pager.free_slots() == free0             # one ref still live
    pager.release(h1)
    assert pager.free_slots() == free0 + 1         # slot freed
    assert m.count["kv_spill_frees"] == 1
    # freed slots are reusable; handles are NOT recycled
    h3 = pager.spill(payload)
    assert h3 != h1
    assert pager.fetch(h3) == payload
    path = m.kv_paging_path()
    assert path["kv_restore_crc_errors"] == 0
    assert path["dedup_rate"] == pytest.approx(0.25)   # 3 spills, 1 dedup


def test_pager_wire_crc_detects_torn_record():
    m = Metrics()
    vol = _vol()
    pager = KVPager(vol, capacity_blocks=64, metrics=m)
    payload = b"kvpage" * 900                      # 2 blocks
    h = pager.spill(payload)
    rec = pager._records[h]
    for t in rec.spill_tickets:
        vol.wait(t)
    # tear the record's second block behind the pager's back
    vol.write(rec.lba + 1, np.frombuffer(b"\xff" * vol.block_size,
                                         np.uint8))
    with pytest.raises(IOError):
        pager.fetch(h)
    assert m.count["kv_restore_crc_errors"] == 1
    assert m.count["kv_restores"] == 0


def test_pager_prefetch_hit_and_wasted_counters():
    m = Metrics()
    pager = KVPager(_vol(), capacity_blocks=64, metrics=m)
    h1 = pager.spill(b"a" * 5000)
    h2 = pager.spill(b"b" * 5000)
    assert pager.prefetch([h1, h2]) == 2
    assert pager.prefetch([h1]) == 0               # already in flight
    assert pager.fetch(h1) == b"a" * 5000
    pager.release(h2)                              # unconsumed prefetch
    assert m.count["kv_prefetch_issued"] == 2
    assert m.count["kv_prefetch_hits"] == 1
    assert m.count["kv_prefetch_wasted"] == 1


def test_pager_capacity_exhaustion_is_loud():
    pager = KVPager(_vol(), capacity_blocks=2, metrics=Metrics())
    pager.spill(b"a" * 100)                        # 1 block -> 2 slots
    pager.spill(b"b" * 100)
    with pytest.raises(MemoryError, match="spill tier exhausted"):
        pager.spill(b"c" * 100)


# ------------------------------------------------- cache <-> volume tier
def test_spill_restore_preserves_kv_exactly():
    """The volume roundtrip must carry the int8 payload bit-exactly:
    attention after restore-through-the-volume == attention after a
    plain host-tier roundtrip of the SAME tokens."""
    rng_tokens = np.random.default_rng(3).normal(
        size=(12, 2, 2, 8)).astype(np.float32)

    def build(pager, host_pages):
        m = Metrics()
        c = PagedKVCache(_cfg(host_pages=host_pages), metrics=m,
                         pager=pager)
        sid = c.new_sequence()
        for t in range(12):
            k = jnp.asarray(rng_tokens[t, 0])
            v = jnp.asarray(rng_tokens[t, 1])
            c.append_token(sid, [k] * 2, [v] * 2)
        c.deactivate(sid)
        c.activate(sid)
        q = jnp.ones((1, 2, 8), jnp.float32)
        return c, m, np.asarray(c.attention(0, q, [sid], use_kernel=False))

    _c1, _m1, ref = build(None, host_pages=64)      # host-only roundtrip
    pager = KVPager(_vol(), capacity_blocks=256)
    c2, m2, got = build(pager, host_pages=0)        # everything spills
    assert m2.count["kv_spills"] > 0
    assert m2.count["kv_restores"] > 0
    assert m2.count["kv_restore_crc_errors"] == 0
    assert m2.count["transit_crc_errors"] == 0
    np.testing.assert_allclose(got, ref, atol=1e-6)


def test_hybrid_attention_reads_spilled_pages_without_promotion():
    """A cold sequence's attention must serve straight off the volume
    (the bypass discipline): no page-in, values matching the host-tier
    dequantization."""
    rng = np.random.default_rng(4)
    toks = rng.normal(size=(8, 2, 2, 8)).astype(np.float32)

    def build(pager, host_pages):
        m = Metrics()
        c = PagedKVCache(_cfg(host_pages=host_pages, n_pages=4),
                         metrics=m, pager=pager)
        sid = c.new_sequence()
        for t in range(8):
            c.append_token(sid, [jnp.asarray(toks[t, 0])] * 2,
                           [jnp.asarray(toks[t, 1])] * 2)
        c.deactivate(sid)
        return c, m, sid

    c1, _m1, s1 = build(None, host_pages=64)
    pager = KVPager(_vol(), capacity_blocks=256)
    c2, m2, s2 = build(pager, host_pages=0)
    assert any(e[0] == "vol" for e in c2.seqs[s2].table)
    q = jnp.ones((1, 2, 8), jnp.float32)
    ref = np.asarray(c1.attention(1, q, [s1], use_kernel=False))
    got = np.asarray(c2.attention(1, q, [s2], use_kernel=False))
    np.testing.assert_allclose(got, ref, atol=1e-6)
    assert m2.count["hybrid_attention"] == 1
    assert all(e[0] == "vol" for e in c2.seqs[s2].table)   # still cold
    assert m2.count["pages_in"] == 0


def test_prefetch_then_activate_hits():
    m = Metrics()
    pager = KVPager(_vol(), capacity_blocks=256, metrics=m)
    c = PagedKVCache(_cfg(host_pages=0, read_tier_pages=0), metrics=m,
                     pager=pager)
    rng = np.random.default_rng(5)
    sid = c.new_sequence()
    _fill(c, sid, 8, rng)
    c.deactivate(sid)
    n_vol = sum(1 for e in c.seqs[sid].table if e[0] == "vol")
    assert n_vol == 2
    assert c.prefetch(sid) == n_vol
    c.activate(sid)
    path = m.kv_paging_path()
    assert path["kv_prefetch_hits"] == n_vol
    assert path["prefetch_hit_rate"] == 1.0
    assert all(e[0] == "hbm" for e in c.seqs[sid].table)
    c.release(sid)
    assert pager.stats()["records"] == 0


# --------------------------------------------- satellite 1: lock discipline
def test_concurrent_deactivate_never_double_frees():
    """Racing sync deactivates of the same sequences: pre-fix, two
    threads both saw an "hbm" entry and both paged it out — the pool
    page entered the free list twice and the host tier leaked a packed
    copy.  All table/free-list mutations now serialize on _tlock."""
    m = Metrics()
    c = PagedKVCache(_cfg(n_pages=32, read_tier_pages=0), metrics=m)
    rng = np.random.default_rng(0)
    sids = []
    for _ in range(6):
        sid = c.new_sequence()
        _fill(c, sid, 8, rng)                      # 2 pages each
        sids.append(sid)
    barrier = threading.Barrier(4)

    def deactivate_all():
        barrier.wait()
        for sid in sids:
            c.deactivate(sid)

    threads = [threading.Thread(target=deactivate_all) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(c._free) == len(set(c._free)), "pool page double-freed"
    resident = sum(1 for s in c.seqs.values()
                   for e in s.table if e[0] == "hbm")
    assert len(c._free) + resident == c.cfg.n_pages
    # each of the 12 pages packed to the host tier exactly once
    # (one k-handle + one v-handle per layer)
    assert len(c.host) == 12 * 2 * c.cfg.n_layers
    assert m.count["pages_out"] == 12


# ------------------------------------------ satellite 2: max_pages_per_seq
def test_max_pages_per_seq_enforced_without_bypass():
    c = PagedKVCache(_cfg(max_pages_per_seq=2, conditional_bypass=False,
                          n_pages=16), metrics=Metrics())
    sid = c.new_sequence()
    _fill(c, sid, 8, np.random.default_rng(0))     # exactly at the bound
    with pytest.raises(MemoryError, match="max_pages_per_seq"):
        _fill(c, sid, 1, np.random.default_rng(1))


def test_long_sequence_bypasses_and_decodes_via_hybrid_path():
    m = Metrics()
    c = PagedKVCache(_cfg(max_pages_per_seq=2, n_pages=16), metrics=m)
    sid = c.new_sequence()
    _fill(c, sid, 11, np.random.default_rng(0))    # 3 pages: 1 past bound
    assert m.count["long_seq_bypass"] > 0
    assert len(c.seqs[sid].table) == 3
    assert c.seqs[sid].table[2][0] == "host-fresh"  # never an HBM page
    # the dense table refuses loudly instead of writing out of bounds
    with pytest.raises(ValueError, match="max_pages_per_seq"):
        c.table_for([sid])
    # attention routes to the hybrid slow path and still works
    q = jnp.ones((1, 2, 8), jnp.float32)
    out = np.asarray(c.attention(0, q, [sid], use_kernel=False))
    assert np.all(np.isfinite(out))
    assert m.count["hybrid_attention"] == 1


# ------------------------------------- satellite 3: drain_evictions expiry
def test_drain_evictions_timeout_is_loud():
    c = PagedKVCache(_cfg(), metrics=Metrics())
    with c._evict_cv:
        c._inflight_evictions += 1                 # a stuck page-out
    with pytest.raises(TimeoutError, match="still in flight"):
        c.drain_evictions(timeout=0.05)
    assert c.drain_evictions(timeout=0.05, raise_on_timeout=False) is False
    with c._evict_cv:
        c._inflight_evictions -= 1
        c._evict_cv.notify_all()
    assert c.drain_evictions(timeout=1.0) is True


# --------------------------------- satellite 4: crc + release accounting
def test_page_in_crc_mismatch_returns_pool_page():
    """A corrupted host payload must surface as IOError + a counter bump
    WITHOUT leaking the pool page allocated for the restore, and without
    popping any host handle (the sequence stays consistently cold)."""
    m = Metrics()
    c = PagedKVCache(_cfg(read_tier_pages=0), metrics=m)
    sid = c.new_sequence()
    _fill(c, sid, 4, np.random.default_rng(0))
    c.deactivate(sid)
    assert c.seqs[sid].table[0][0] == "host"
    hk, _hv = c.seqs[sid].table[0][1][0]
    q, s, crc = c.host.get(0, hk)
    q = q.copy()
    q[0, 0] ^= 0x5A                                # tear one byte
    c.host.pages[(0, hk)] = (q, s, crc)
    free_before = c.free_pages()
    host_before = len(c.host)
    with pytest.raises(IOError, match="tore in transit"):
        c.activate(sid)
    assert m.count["transit_crc_errors"] == 1
    assert c.free_pages() == free_before, "restore leaked a pool page"
    assert len(c.host) == host_before, "partial page-in popped handles"
    assert c.seqs[sid].table[0][0] == "host"


def test_release_accounts_mixed_hbm_host_fresh_pages():
    m = Metrics()
    c = PagedKVCache(_cfg(n_pages=4, host_pages=64), metrics=m)
    rng = np.random.default_rng(1)
    a = c.new_sequence()
    _fill(c, a, 8, rng)                            # 2 hbm pages
    b = c.new_sequence()
    _fill(c, b, 8, rng)                            # pool now full
    _fill(c, b, 4, rng)                            # bypass -> host-fresh
    c.deactivate(a)                                # a's pages -> host
    assert [e[0] for e in c.seqs[a].table] == ["host", "host"]
    assert c.free_pages() == 2                     # a's pool pages freed
    kinds_b = [e[0] for e in c.seqs[b].table]
    assert kinds_b == ["hbm", "hbm", "host-fresh"]
    c.release(b)                                   # hbm + host-fresh mix
    assert c.free_pages() == 4
    c.release(a)                                   # packed host pages
    assert c.free_pages() == 4
    assert len(c.host) == 0
    assert c.seqs == {}


# ------------------------------------------------------- engine + metrics
def test_kv_paging_path_metrics_shape():
    m = Metrics()
    path = m.kv_paging_path()
    for key in KV_PAGING_COUNTERS:
        assert path[key] == 0
    assert path["dedup_rate"] == 0.0
    assert path["prefetch_hit_rate"] == 0.0
    m.bump("kv_spills", 3)
    m.bump("kv_dedup_hits", 1)
    m.bump("kv_restores", 2)
    m.bump("kv_prefetch_hits", 1)
    path = m.kv_paging_path()
    assert path["dedup_rate"] == pytest.approx(0.25)
    assert path["prefetch_hit_rate"] == pytest.approx(0.5)


def test_engine_suspend_resume_through_the_pager():
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import ServeEngine

    cfg = get_config("qwen2.5-3b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    vol = _vol(n_lbas=4096)
    pager = KVPager(vol, capacity_blocks=2048)
    cache_cfg = PagedCacheConfig(
        n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        page_size=4, n_pages=16, host_pages=0, max_pages_per_seq=16)
    eng = ServeEngine(cfg, params, cache_cfg=cache_cfg, max_batch=2,
                      pager=pager, prefetch_depth=2)
    r1 = eng.submit(list(range(2, 14)), max_new_tokens=6)
    r2 = eng.submit(list(range(3, 15)), max_new_tokens=6)
    eng.step()                                     # both admitted
    eng.suspend(eng.running[0])                    # preempt: spill to vol
    assert eng.metrics.count["kv_spills"] > 0
    assert eng.suspended
    eng.run(max_ticks=200)                         # resumes + finishes
    assert r1.done and r2.done
    assert len(r1.out_tokens) == 6 and len(r2.out_tokens) == 6
    assert eng.metrics.count["resumes"] >= 1
    assert eng.metrics.count["kv_restores"] > 0
    assert eng.metrics.count["kv_restore_crc_errors"] == 0
    assert eng.metrics.count["transit_crc_errors"] == 0


# ------------------------------------------------------------------- sim
def test_kv_paging_sim_sweep_invariants():
    from repro.core.sim import run_kv_paging_sim_workload as run

    common = dict(hbm_pages=16, host_pages=16, pages_per_session=4,
                  page_blocks=8, shared_pages=1, rounds=3, decode_us=20.0)
    base = run(n_sessions=4, **common)
    assert base["spills"] == 0 and base["restores_vol"] == 0
    x4 = run(n_sessions=32, **common)              # 4x HBM+host capacity
    x4_sync = run(n_sessions=32, prefetch_depth=0, **common)
    assert x4["tokens_s"] / base["tokens_s"] >= 0.5       # CI floor
    assert x4["tokens_s"] >= x4_sync["tokens_s"]          # prefetch wins
    assert x4["dedup_hits"] > 0                           # shared prefix
    assert x4["prefetch_hits"] > 0 and x4_sync["prefetch_hits"] == 0
    assert x4["restores_vol"] <= x4["spills"] + x4["dedup_hits"]
    assert x4 == run(n_sessions=32, **common)             # deterministic
