"""Striped volume manager: striping, shared eviction pool, global bypass,
QoS, and — the acceptance core — cross-shard write atomicity after a
simulated crash (torn multi-shard writes never surface on read)."""
import threading
import time

import numpy as np
import pytest

from repro.core import SimulatedCrash
from repro.core.sim import (chain_commit_steps, chain_crash_outcome,
                            run_volume_sim_workload)
from repro.volume import (SharedEvictionPool, TenantSpec, TokenBucket,
                          WFQGate, make_volume)


def _blk(x: int) -> bytes:
    return bytes([x % 256]) * 4096


# ------------------------------------------------------------ functional
def test_striping_read_your_writes():
    vol = make_volume("caiti", n_lbas=2048, n_shards=4, stripe_blocks=4,
                      cache_bytes=64 * 4096)
    try:
        for lba in range(0, 2048, 11):
            vol.write(lba, _blk(lba + 1))
        for lba in range(0, 2048, 11):
            assert bytes(vol.read(lba)) == _blk(lba + 1), lba
        vol.fsync()
        # every shard's BTT must have taken real writes (striping spreads)
        for d in vol.shards:
            assert d.impl.btt.writes > 0
        for lba in range(0, 2048, 11):
            assert bytes(vol.read(lba)) == _blk(lba + 1), lba
    finally:
        vol.close()


def test_write_multi_roundtrip_spans_shards():
    vol = make_volume("caiti", n_lbas=1024, n_shards=4, stripe_blocks=1,
                      cache_bytes=64 * 4096)
    try:
        blocks = [_blk(40 + i) for i in range(8)]
        vol.write_multi(100, blocks)          # stripe_blocks=1: 8 shard hops
        for i in range(8):
            assert bytes(vol.read(100 + i)) == _blk(40 + i)
        assert vol.journal.last_txid() >= 1
    finally:
        vol.close()


def test_shared_pool_drains_all_shards():
    vol = make_volume("caiti", n_lbas=1024, n_shards=4, stripe_blocks=2,
                      cache_bytes=1024 * 4096, shared_workers=2)
    try:
        # shards must NOT own private eviction threads
        for d in vol.shards:
            assert d.impl._workers == []
        assert isinstance(vol.pool, SharedEvictionPool)
        for lba in range(256):
            vol.write(lba, _blk(lba))
        for _ in range(300):
            if vol.occupancy() == 0.0:
                break
            time.sleep(0.01)
        assert vol.occupancy() == 0.0        # eager eviction drained
        snap = vol.metrics_snapshot()
        assert snap["bg_evictions"] + snap["bypass_writes"] >= 256
        assert snap["bg_evictions"] > 0
    finally:
        vol.close()


def test_global_bypass_watermark_trips_before_local_full():
    # no eager eviction -> staged bytes only grow, so the volume watermark
    # (25%) trips long before any single shard's cache is full
    vol = make_volume("caiti-noee", n_lbas=4096, n_shards=4,
                      stripe_blocks=2, cache_bytes=256 * 4096,
                      bypass_watermark=0.25)
    try:
        for lba in range(128):
            vol.write(lba, _blk(lba))
        snap = vol.metrics_snapshot()
        assert snap["bypass_writes"] > 0
        # and no shard ever filled locally
        for d in vol.shards:
            assert d.impl.staged_slots() < len(d.impl._slots)
    finally:
        vol.close()


# ------------------------------------------------------ layered read path
def test_read_tier_layered_path():
    """tier -> transit -> BTT: after fsync (writebacks populated the
    tier) reads are served from DRAM; writes invalidate tier entries.
    The transit cache (512 slots) exceeds the 171 writes so no write can
    take the bypass path — every block writebacks through the tier and
    ``read_misses == 0`` is deterministic."""
    vol = make_volume("caiti", n_lbas=1024, n_shards=4, stripe_blocks=4,
                      cache_bytes=512 * 4096, read_tier_bytes=512 * 4096)
    try:
        for lba in range(0, 512, 3):
            vol.write(lba, _blk(lba + 1))
        vol.fsync()
        for lba in range(0, 512, 3):
            assert bytes(vol.read(lba)) == _blk(lba + 1), lba
        snap = vol.metrics_snapshot()
        assert snap["read_tier_hits"] > 0
        assert snap["read_misses"] == 0        # everything came from DRAM
        # overwrite must invalidate: the tier never serves stale data
        vol.write(3, _blk(99))
        assert bytes(vol.read(3)) == _blk(99)
        vol.fsync()
        assert bytes(vol.read(3)) == _blk(99)
    finally:
        vol.close()


def test_read_tier_populates_on_read_miss():
    vol = make_volume("caiti", n_lbas=256, n_shards=2,
                      cache_bytes=32 * 4096, read_tier_bytes=64 * 4096)
    try:
        for lba in range(32):
            vol.write(lba, _blk(lba))
        vol.fsync()
        vol.read_tier.clear()                  # cold tier
        assert bytes(vol.read(5)) == _blk(5)   # miss fills the tier
        before = vol.metrics_snapshot()["read_tier_hits"]
        assert bytes(vol.read(5)) == _blk(5)   # now a tier hit
        assert vol.metrics_snapshot()["read_tier_hits"] == before + 1
    finally:
        vol.close()


def test_replication_scrub_clean():
    vol = make_volume("caiti", n_lbas=512, n_shards=4, replicas=2,
                      cache_bytes=64 * 4096)
    try:
        for lba in range(0, 512, 5):
            vol.write(lba, _blk(lba + 7))
        vol.fsync()
        assert vol.scrub_replicas(5) == 0
        # replica really lives on a different shard
        s0, _ = vol._map(0, 0)
        s1, _ = vol._map(0, 1)
        assert s0 != s1
    finally:
        vol.close()


# -------------------------------------------- degraded reads + resync
def _corrupt_primary(vol, lba):
    shard, local = vol._map(lba, 0)
    vol.shards[shard].impl.btt.write(
        local, np.frombuffer(b"\xde" * 4096, np.uint8))


def test_degraded_read_and_background_resync():
    """ACCEPTANCE: with one replica and injected primary-shard
    corruption, every read returns correct data (replica fallback), and
    the ReplicaResyncer restores scrub divergence to zero while
    foreground I/O keeps flowing."""
    vol = make_volume("caiti", n_lbas=512, n_shards=4, replicas=2,
                      cache_bytes=64 * 4096, read_tier_bytes=64 * 4096)
    try:
        for lba in range(0, 128, 2):
            vol.write(lba, _blk(lba + 7))
        vol.fsync()
        bad = [0, 10, 20, 30, 40]
        for lba in bad:
            _corrupt_primary(vol, lba)
        vol.read_tier.clear()                  # force cold (BTT) reads
        assert vol.scrub_replicas() == len(bad)
        detail = vol.scrub_replicas_detail()
        assert {d[0] for d in detail} == set(bad)
        assert all(d[1] == 0 for d in detail)  # the PRIMARY copy is bad
        # every read returns the correct data via the replica
        for lba in bad:
            assert bytes(vol.read(lba)) == _blk(lba + 7), lba
        snap = vol.metrics_snapshot()
        assert snap["degraded_reads"] == len(bad)
        # the degraded read read-repaired the tier: a second pass serves
        # good data from DRAM without degrading again
        for lba in bad:
            assert bytes(vol.read(lba)) == _blk(lba + 7), lba
        assert vol.metrics_snapshot()["degraded_reads"] == len(bad)
        # degraded reads auto-queued repairs; foreground I/O proceeds
        # while the background pool drains them
        for lba in range(1, 64, 2):
            vol.write(lba, _blk(lba))
            assert bytes(vol.read(lba)) == _blk(lba)
        assert vol.resyncer.wait_idle(20.0)
        vol.fsync()       # drain staged foreground copies: scrub reads
        # below the caches, and a half-evicted write is not divergence
        assert vol.scrub_replicas() == 0       # divergence fully repaired
        assert vol.resyncer.repaired_blocks >= len(bad)
        assert vol.metrics_snapshot()["resync_repairs"] >= len(bad)
    finally:
        vol.close()


def test_resync_sweep_repairs_unread_blocks():
    """A scrub-driven resync() repairs divergence nobody has read yet."""
    vol = make_volume("caiti", n_lbas=256, n_shards=3, replicas=2,
                      cache_bytes=32 * 4096)
    try:
        for lba in range(64):
            vol.write(lba, _blk(lba + 1))
        vol.fsync()
        for lba in (3, 9, 27):
            _corrupt_primary(vol, lba)
        assert vol.scrub_replicas() == 3
        assert vol.resyncer.resync() == 3      # queued straight from scrub
        assert vol.resyncer.wait_idle(20.0)
        assert vol.scrub_replicas() == 0
        for lba in (3, 9, 27):
            assert bytes(vol.read(lba)) == _blk(lba + 1)
    finally:
        vol.close()


def test_corrupt_replica_repaired_from_primary():
    """Divergence on the REPLICA side: reads never degrade (primary is
    fine) but scrub finds it and resync repairs from the primary."""
    vol = make_volume("caiti", n_lbas=256, n_shards=3, replicas=2,
                      cache_bytes=32 * 4096)
    try:
        vol.write(7, _blk(70))
        vol.fsync()
        s1, l1 = vol._map(7, 1)
        vol.shards[s1].impl.btt.write(
            l1, np.frombuffer(b"\xab" * 4096, np.uint8))
        detail = vol.scrub_replicas_detail()
        assert [(d[0], d[1]) for d in detail] == [(7, 1)]
        assert bytes(vol.read(7)) == _blk(70)
        assert vol.metrics_snapshot()["degraded_reads"] == 0
        vol.resyncer.resync()
        assert vol.resyncer.wait_idle(20.0)
        assert vol.scrub_replicas() == 0
    finally:
        vol.close()


def test_reopen_tie_divergence_never_destroys_good_copy(tmp_path):
    """Without the persisted crc ledger (``persist_ledger=False``) the
    ledger is empty after reopen, so a 1-vs-1 primary/replica tie is
    undecidable: resync must flag it and REFUSE to repair — overwriting
    the replica with the corrupt primary would turn recoverable
    divergence into data loss.  With >= 3 copies a strict majority still
    repairs."""
    path = str(tmp_path / "vol")
    kw = dict(n_lbas=256, n_shards=3, replicas=2, cache_bytes=32 * 4096,
              backend="file", path=path, persist_ledger=False)
    vol = make_volume("caiti", **kw)
    vol.write(5, _blk(55))
    vol.fsync()
    vol.close()
    vol = make_volume("caiti", **kw)
    _corrupt_primary(vol, 5)
    try:
        assert vol.scrub_replicas() == 1
        vol.resyncer.resync()
        assert vol.resyncer.wait_idle(10.0)
        assert vol.scrub_replicas() == 1       # still flagged, NOT "fixed"
        s1, l1 = vol._map(5, 1)
        assert bytes(vol.shards[s1].impl.btt.read(l1)) == _blk(55)
    finally:
        vol.close()
    # three copies: majority decides even with an empty ledger
    path3 = str(tmp_path / "vol3")
    kw3 = dict(n_lbas=256, n_shards=3, replicas=3, cache_bytes=32 * 4096,
               backend="file", path=path3, persist_ledger=False)
    vol = make_volume("caiti", **kw3)
    vol.write(5, _blk(66))
    vol.fsync()
    vol.close()
    vol = make_volume("caiti", **kw3)
    _corrupt_primary(vol, 5)
    try:
        assert vol.scrub_replicas() >= 1
        vol.resyncer.resync()
        assert vol.resyncer.wait_idle(10.0)
        assert vol.scrub_replicas() == 0
        assert bytes(vol.read(5)) == _blk(66)
    finally:
        vol.close()


# ------------------------------------------------------- crash atomicity
def _crash_on_nth_write(pmem, n):
    state = {"count": 0}

    def hook(label):
        if label == "pmem_write_begin":
            state["count"] += 1
            if state["count"] == n:
                raise SimulatedCrash(label)

    pmem.crash_hook = hook
    return state


def _reopen(path, **kw):
    return make_volume("btt", n_lbas=256, n_shards=4, stripe_blocks=1,
                       backend="file", path=path, **kw)


def test_torn_multishard_write_rolls_forward(tmp_path):
    """Crash mid in-place phase, AFTER the journal header committed: the
    write must be fully visible after recovery (roll forward)."""
    path = str(tmp_path / "vol")
    vol = _reopen(path)
    base = [_blk(1 + i) for i in range(4)]
    vol.write_multi(8, base)                       # lbas 8..11, shards 0..3
    vol.fsync()
    # in-place writes start after journal commit; lba 9's home shard sees
    # exactly one write for this tx — crash there, leaving lba 8 new and
    # lbas 9..11 old (a torn multi-shard write)
    new = [_blk(101 + i) for i in range(4)]
    shard2, _ = vol._map(9, 0)                     # 2nd block's home shard
    _crash_on_nth_write(vol.shards[shard2].impl.btt.pmem, 1)
    with pytest.raises(SimulatedCrash):
        vol.write_multi(8, new)
    # "power loss": abandon the torn volume, reopen from the files
    for d in vol.shards:
        d.impl.btt.pmem.crash_hook = None
    vol2 = _reopen(path)
    assert vol2.recovery_stats["replayed_txs"] >= 1
    got = [bytes(vol2.read(8 + i)) for i in range(4)]
    assert got == new, "journaled write must be rolled forward whole"
    vol2.close()


def test_torn_journal_write_is_invisible(tmp_path):
    """Crash BEFORE the journal header lands: the old data must remain
    fully intact on every shard (the write never happened)."""
    path = str(tmp_path / "vol")
    vol = _reopen(path)
    base = [_blk(21 + i) for i in range(4)]
    vol.write_multi(16, base)
    vol.fsync()
    # next tx journals on slot (txid % 64); its payload writes hit the
    # journal shard's BTT first — crash on the first of them
    txid = vol.journal.next_txid
    jshard, _ = vol.journal._slot_home(txid % vol.journal.n_slots)
    _crash_on_nth_write(vol.shards[jshard].impl.btt.pmem, 1)
    with pytest.raises(SimulatedCrash):
        vol.write_multi(16, [_blk(201 + i) for i in range(4)])
    for d in vol.shards:
        d.impl.btt.pmem.crash_hook = None
    vol2 = _reopen(path)
    got = [bytes(vol2.read(16 + i)) for i in range(4)]
    assert got == base, "uncommitted tx must be invisible (old data whole)"
    vol2.close()


def test_ring_wrap_checkpoint_still_replays_current_tx(tmp_path):
    """Regression: the wrap-time checkpoint must mark applied STRICTLY
    below the wrapping txid — a crash mid in-place of that tx must still
    roll forward (not be skipped as 'already applied')."""
    path = str(tmp_path / "vol")
    vol = make_volume("btt", n_lbas=256, n_shards=4, stripe_blocks=1,
                      backend="file", path=path, journal_slots=4)
    for k in range(4):                             # fill the 4-slot ring
        vol.write_multi(8, [_blk(k)] * 4)
    # tx 5 wraps onto tx 1's slot -> checkpoint fires (one superblock
    # write on every shard), then journal (slot home = shard 1), then
    # in-place: lba 10's shard sees superblock (1st) + in-place (2nd)
    shard2, _ = vol._map(10, 0)
    assert vol.journal._slot_home(5 % 4)[0] != shard2
    _crash_on_nth_write(vol.shards[shard2].impl.btt.pmem, 2)
    with pytest.raises(SimulatedCrash):
        vol.write_multi(8, [_blk(50 + i) for i in range(4)])
    for d in vol.shards:
        d.impl.btt.pmem.crash_hook = None
    vol2 = make_volume("btt", n_lbas=256, n_shards=4, stripe_blocks=1,
                       backend="file", path=path, journal_slots=4)
    assert vol2.recovery_stats["replayed_txs"] >= 1
    got = [bytes(vol2.read(8 + i)) for i in range(4)]
    assert got == [_blk(50 + i) for i in range(4)]
    vol2.close()


def test_fsync_checkpoint_skips_replay(tmp_path):
    """After fsync, journal records are checkpointed: recovery must not
    clobber a later (also fsynced) single-block overwrite."""
    path = str(tmp_path / "vol")
    vol = _reopen(path)
    vol.write_multi(8, [_blk(1 + i) for i in range(4)])
    vol.fsync()                                    # checkpoint: applied_txid
    vol.write(9, _blk(99))                         # later overwrite
    vol.fsync()
    vol2 = _reopen(path)
    assert vol2.recovery_stats["replayed_txs"] == 0
    assert bytes(vol2.read(9)) == _blk(99)
    vol2.close()


def test_reopen_geometry_mismatch_rejected(tmp_path):
    path = str(tmp_path / "vol")
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1,
                      backend="file", path=path)
    vol.close()
    with pytest.raises(AssertionError, match="stripe_blocks"):
        make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=4,
                    backend="file", path=path)
    # journal geometry shifts the data region too — must also be rejected
    with pytest.raises(AssertionError, match="journal_span"):
        make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1,
                    journal_span=2, backend="file", path=path)


def test_reopen_missing_member_rejected(tmp_path):
    """A shard file without a superblock is a damaged volume, never a
    fresh one — re-formatting would orphan the surviving shards."""
    import os
    path = str(tmp_path / "vol")
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1,
                      backend="file", path=path)
    vol.write(0, _blk(5))
    vol.close()
    os.remove(path + ".shard1")
    with pytest.raises(AssertionError, match="member missing"):
        make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1,
                    backend="file", path=path)


def test_caiti_volume_crash_recovery(tmp_path):
    """Caiti shards (staged writes) + abrupt abandonment: journal replay
    restores every journaled write after reopen.  The read tier is
    enabled: clean slots are never journaled, so write atomicity must be
    byte-for-byte identical with the tier in the stack."""
    path = str(tmp_path / "vol")
    vol = make_volume("caiti", n_lbas=512, n_shards=3, stripe_blocks=2,
                      cache_bytes=64 * 4096, backend="file", path=path,
                      read_tier_bytes=32 * 4096)
    vol.write_multi(10, [_blk(31 + i) for i in range(6)])
    # crash BEFORE fsync: staged copies may not have reached BTT, but the
    # journal committed first — flush mmaps (power loss keeps media state)
    for d in vol.shards:
        d.impl.btt.pmem.persist()
    del vol                                        # no close(): no drain
    vol2 = make_volume("caiti", n_lbas=512, n_shards=3, stripe_blocks=2,
                       cache_bytes=64 * 4096, backend="file", path=path,
                       read_tier_bytes=32 * 4096)
    got = [bytes(vol2.read(10 + i)) for i in range(6)]
    assert got == [_blk(31 + i) for i in range(6)]
    vol2.close()


# -------------------------------------------------- chained-tx atomicity
def _crash_on_nth_btt_write(vol, n):
    """Global (cross-shard) crash injection at BTT-write granularity —
    one counter over every shard, so crash points line up with the
    protocol steps of ``repro.core.sim.chain_commit_steps``."""
    state = {"count": 0}
    for d in vol.shards:
        btt = d.impl.btt
        orig = btt.write

        def wrapped(lba, data, _orig=orig):
            state["count"] += 1
            if state["count"] == n:
                raise SimulatedCrash("btt_write")
            return _orig(lba, data)

        btt.write = wrapped
    return state


_CHAIN_KW = dict(n_lbas=128, n_shards=2, stripe_blocks=1,
                 journal_slots=16, journal_span=2, backend="file")


def _chain_crash_run(tmp_path, crash_write: int):
    """Write an 8-block (4x-span) object, fsync, then overwrite it with
    a crash injected on BTT write ``crash_write`` of the chained tx.
    Returns (outcome, steps_executed): outcome 'old' | 'new' | 'torn'
    read back after reopen+recovery."""
    path = str(tmp_path / f"chain{crash_write}")
    old = [_blk(10 + i) for i in range(8)]
    new = [_blk(110 + i) for i in range(8)]
    vol = make_volume("btt", path=path, **_CHAIN_KW)
    vol.write_multi(8, old)
    vol.fsync()
    state = _crash_on_nth_btt_write(vol, crash_write)
    crashed = True
    try:
        vol.write_multi(8, new)
        crashed = False
    except SimulatedCrash:
        pass
    # "power loss": abandon the torn volume, reopen from the files
    for d in vol.shards:
        d.impl.btt.pmem.persist()
    del vol
    vol2 = make_volume("btt", path=path, **_CHAIN_KW)
    got = [bytes(vol2.read(8 + i)) for i in range(8)]
    vol2.close()
    outcome = "old" if got == old else "new" if got == new else "torn"
    return outcome, state["count"] - (1 if crashed else 0), crashed


def test_chain_crash_between_links_leaves_old_object(tmp_path):
    """Kill between chain links (inside the journal phase, before the
    tail header): the OLD object must be fully intact — the chain never
    committed, no in-place write happened."""
    steps = chain_commit_steps(8, 2)
    tail = steps.index(("tail_header",))          # step 11 of 20
    # crash on the 6th BTT write: mid payload of link 2 (between links)
    outcome, done, crashed = _chain_crash_run(tmp_path, 6)
    assert crashed and outcome == "old"
    assert done < tail                            # really pre-commit
    # crash on the LAST non-tail header (the write before the commit pt)
    outcome, done, crashed = _chain_crash_run(tmp_path, tail + 1)
    assert crashed and outcome == "old"


def test_chain_crash_between_tail_header_and_inplace_rolls_forward(tmp_path):
    """Kill between the tail header and the in-place writes: the tail
    landed, so recovery must roll the WHOLE new object forward."""
    steps = chain_commit_steps(8, 2)
    tail = steps.index(("tail_header",))
    # tail header is BTT write tail+1; crash on the first in-place write
    outcome, done, crashed = _chain_crash_run(tmp_path, tail + 2)
    assert crashed and outcome == "new"
    assert done == tail + 1                       # exactly post-commit


@pytest.mark.slow
def test_chain_crash_property_every_point_whole_object(tmp_path):
    """ACCEPTANCE: a crash ANYWHERE inside a 4x-span logical write
    surfaces either the complete new object or the complete old one —
    property-tested over every injected BTT-write crash point, and
    cross-validated against the simulator's chain-crash model."""
    steps = chain_commit_steps(8, 2)              # 8 payload, 3 hdr, 1
    n = 1                                         # tail, 8 in-place = 20
    while True:
        outcome, done, crashed = _chain_crash_run(tmp_path, n)
        if not crashed:                           # past the last write
            assert outcome == "new"
            assert done == len(steps)             # model counts the
            break                                 # protocol exactly
        assert outcome in ("old", "new"), f"torn object at write {n}"
        assert outcome == chain_crash_outcome(8, 2, done), \
            f"real volume disagrees with sim model at crash point {n}"
        n += 1
    assert n == len(steps) + 1                    # swept every step


def test_chain_crash_smoke_key_points(tmp_path):
    """Fast (not slow-marked) subset of the property sweep: one point in
    each protocol phase, still model-checked."""
    steps = chain_commit_steps(8, 2)
    tail = steps.index(("tail_header",))
    for n in (1, tail, tail + 1, tail + 2, len(steps)):
        outcome, done, crashed = _chain_crash_run(tmp_path, n)
        assert crashed and outcome == chain_crash_outcome(8, 2, done), n


def test_write_multi_exceeding_ring_rejected(tmp_path):
    vol = make_volume("btt", n_lbas=128, n_shards=2, stripe_blocks=1,
                      journal_slots=4, journal_span=2)
    try:
        assert vol.max_atomic_write_blocks() == 8
        with pytest.raises(AssertionError, match="exceeds"):
            vol.write_multi(0, [_blk(i) for i in range(10)])
    finally:
        vol.close()


# ------------------------------------------------------- group commit
def test_group_commit_coalesces_concurrent_fsyncs():
    """>= 4 concurrent fsync callers share a leader's drain+checkpoint:
    far fewer commits than calls, and every caller's writes are covered
    (applied mark reaches the last txid)."""
    vol = make_volume("caiti", n_lbas=1024, n_shards=2,
                      cache_bytes=64 * 4096, commit_window=0.1)
    try:
        start = threading.Barrier(8)

        def worker(j):
            start.wait()
            vol.write_multi(j * 16, [_blk(j + i) for i in range(4)])
            vol.fsync()

        ts = [threading.Thread(target=worker, args=(j,)) for j in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=30)
        st = vol._committer.stats()
        assert st["calls"] == 8
        # generous bounds (loaded CI schedulers stagger threads): the
        # essential claim is that coalescing HAPPENED and accounting adds
        # up, not an exact batch shape
        assert st["commits"] + st["coalesced"] == 8
        assert st["commits"] <= 5, st           # leaders gathered others
        assert st["coalesced"] >= 3, st
        assert vol.journal.applied_txid == vol.journal.last_txid()
        for j in range(8):
            for i in range(4):
                assert bytes(vol.read(j * 16 + i)) == _blk(j + i)
        snap = vol.metrics_snapshot()
        assert snap["group_commit"]["coalesced"] >= 3
    finally:
        vol.close()


def test_reopen_verifies_reads_from_persisted_ledger(tmp_path):
    """A reopened volume must verify reads BEFORE the first overwrite:
    the crc ledger summary persisted at fsync makes post-reopen
    corruption detectable, and the read degrades to the replica."""
    path = str(tmp_path / "vol")
    kw = dict(n_lbas=256, n_shards=3, replicas=2, cache_bytes=32 * 4096,
              backend="file", path=path)
    vol = make_volume("caiti", **kw)
    for lba in range(0, 64, 2):
        vol.write(lba, _blk(lba + 9))
    vol.fsync()
    vol.close()
    vol = make_volume("caiti", **kw)
    try:
        assert len(vol._crcs) >= 32              # ledger survived reopen
        _corrupt_primary(vol, 10)
        assert bytes(vol.read(10)) == _blk(19)   # degraded, not garbage
        snap = vol.metrics_snapshot()
        assert snap["degraded_reads"] == 1
        assert snap["verify_failures"] == 1
        # and the divergence is now decidable: resync repairs it
        vol.resyncer.resync()
        assert vol.resyncer.wait_idle(10.0)
        assert vol.scrub_replicas() == 0
    finally:
        vol.close()


# ---------------------------------------------------------------- QoS
def test_token_bucket_caps_rate():
    tb = TokenBucket(rate_bytes_s=1e6, burst_bytes=4096)
    assert tb.acquire(4096) == 0.0                 # burst covers the first
    t0 = time.perf_counter()
    tb.acquire(4096)                               # must wait ~4.1ms refill
    assert time.perf_counter() - t0 > 0.002
    assert not tb.try_acquire(4096)


def test_wfq_gate_admits_by_start_tag():
    gate = WFQGate(max_inflight=1)
    gate.set_tenant("a", weight=2.0)
    gate.set_tenant("b", weight=1.0)
    first = gate.admit("a", 100)        # occupies the slot; F_a = 50
    order = []

    def waiter(name):
        t = gate.admit(name, 100)
        order.append(name)
        gate.done(t)

    # a's next tag is 50, b's is 0 -> b must win the freed slot
    ta = threading.Thread(target=waiter, args=("a",))
    ta.start()
    time.sleep(0.05)
    tb_ = threading.Thread(target=waiter, args=("b",))
    tb_.start()
    time.sleep(0.05)
    gate.done(first)
    ta.join(timeout=5)
    tb_.join(timeout=5)
    assert order == ["b", "a"]


def test_volume_qos_threaded_smoke():
    vol = make_volume("caiti", n_lbas=1024, n_shards=2,
                      cache_bytes=32 * 4096,
                      tenants=[TenantSpec("a", weight=2.0),
                               TenantSpec("b", rate_mbps=200.0)])
    try:
        for i in range(64):
            vol.write(i, _blk(i), tenant="a")
            vol.write(512 + i, _blk(i), tenant="b")
        assert vol._gate.admitted_bytes["a"] == 64 * 4096
    finally:
        vol.close()


# ------------------------------------------------------- simulator claims
def _tenants(n, ops):
    return [{"name": f"t{j}", "n_ops": ops} for j in range(n)]


def test_sim_4shard_caiti_2x_single_device():
    """ACCEPTANCE: 4-shard Caiti volume sustains >= 2x the aggregate write
    throughput of single-device Caiti under a 4-tenant fio-like load."""
    kw = dict(n_lbas=262144, cache_slots=8192, n_workers=16,
              tenants=_tenants(4, 4000))
    r1 = run_volume_sim_workload("caiti", n_shards=1, **kw)
    r4 = run_volume_sim_workload("caiti", n_shards=4, **kw)
    assert r4["agg_mb_s"] >= 2.0 * r1["agg_mb_s"], \
        (r1["agg_mb_s"], r4["agg_mb_s"])


def test_sim_volume_caiti_beats_staging_baselines():
    kw = dict(n_shards=4, n_lbas=262144, cache_slots=4096, n_workers=16,
              tenants=_tenants(4, 3000))
    caiti = run_volume_sim_workload("caiti", **kw)["makespan_us"]
    for p in ("pmbd", "lru", "coactive"):
        assert caiti < run_volume_sim_workload(p, **kw)["makespan_us"], p


def test_sim_wfq_weights_divide_contended_throughput():
    tw = [{"name": "hi", "n_ops": 6000, "weight": 2.0, "jobs": 8},
          {"name": "lo", "n_ops": 6000, "weight": 1.0, "jobs": 8}]
    r = run_volume_sim_workload("caiti", n_shards=2, n_lbas=262144,
                                cache_slots=1024, tenants=tw,
                                qdepth=4, n_workers=4)
    hi = r["per_tenant"]["hi"]["contended_mb_s"]
    lo = r["per_tenant"]["lo"]["contended_mb_s"]
    assert 1.6 < hi / lo < 2.4, hi / lo


def test_sim_token_bucket_caps_tenant():
    ts = [{"name": "capped", "n_ops": 3000, "rate_mbps": 50.0},
          {"name": "free", "n_ops": 6000}]
    r = run_volume_sim_workload("caiti", n_shards=2, n_lbas=262144,
                                cache_slots=2048, tenants=ts)
    assert r["per_tenant"]["capped"]["mb_s"] <= 50.0 * 1.15
    assert r["per_tenant"]["free"]["mb_s"] > 500.0


def test_sim_read_tier_speedup_on_read_heavy_mix():
    """ACCEPTANCE: a >=90%-read zipfian volume workload with the read
    tier sustains >= 1.5x the throughput of the identical workload with
    the tier disabled (misses pay the contended PMem banks; tier hits
    are a DRAM copy)."""
    kw = dict(n_shards=2, n_lbas=16384, cache_slots=2048, n_workers=8,
              read_frac=0.90, lba_dist="zipf", zipf_theta=1.1,
              tenants=_tenants(4, 6000))
    off = run_volume_sim_workload("caiti", tier_slots=0, **kw)
    on = run_volume_sim_workload("caiti", tier_slots=8192, **kw)
    assert on["tier_hit_rate"] > 0.5, on["tier_hit_rate"]
    assert on["agg_mb_s"] >= 1.5 * off["agg_mb_s"], \
        (off["agg_mb_s"], on["agg_mb_s"], on["tier_hit_rate"])


def test_sim_degraded_reads_modeled():
    """Injected primary-verification failures cost a replica round trip
    (throughput drops) and are counted."""
    kw = dict(n_shards=2, n_lbas=16384, cache_slots=1024, n_workers=8,
              read_frac=0.95, lba_dist="zipf", tier_slots=2048,
              tenants=_tenants(2, 3000))
    ok = run_volume_sim_workload("caiti", **kw)
    dg = run_volume_sim_workload("caiti", degraded_every=10, **kw)
    assert ok["degraded_reads"] == 0
    assert dg["degraded_reads"] > 0
    assert dg["agg_mb_s"] < ok["agg_mb_s"]


def test_sim_watermark_increases_bypass():
    kw = dict(n_shards=4, n_lbas=262144, cache_slots=1024, n_workers=8,
              tenants=_tenants(4, 4000))
    low = run_volume_sim_workload("caiti", watermark=0.5, **kw)
    off = run_volume_sim_workload("caiti", watermark=1.0, **kw)
    assert low["bypass_rate"] > off["bypass_rate"]


# ------------------------------------------------------- ckpt integration
def test_sharded_blockstore_roundtrip(tmp_path):
    from repro.ckpt.blockstore import make_blockstore
    path = str(tmp_path / "store")
    st = make_blockstore(path, policy="caiti", capacity_bytes=16 << 20,
                         cache_bytes=4 << 20, n_shards=3)
    payload = np.random.default_rng(0).integers(
        0, 256, size=100_000, dtype=np.uint8).tobytes()
    st.put("x", payload)
    st.put("y", b"tiny")
    gen = st.commit()
    st.close()
    st2 = make_blockstore(path, policy="caiti", capacity_bytes=16 << 20,
                          cache_bytes=4 << 20, n_shards=3)
    assert st2.generation == gen
    assert st2.get("x") == payload
    assert st2.get("y") == b"tiny"
    st2.close()
