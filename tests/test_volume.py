"""Striped volume manager: striping, shared eviction pool, global bypass,
QoS, and — the acceptance core — cross-shard write atomicity after a
simulated crash (torn multi-shard writes never surface on read)."""
import threading
import time

import numpy as np
import pytest

from repro.core import SimulatedCrash
from repro.core.sim import (chain_commit_steps, chain_crash_outcome,
                            run_volume_sim_workload)
from repro.volume import (AdmissionPolicy, LogEntry, SharedEvictionPool,
                          TenantSpec, TokenBucket, WFQGate, make_volume)


def _blk(x: int) -> bytes:
    return bytes([x % 256]) * 4096


# ------------------------------------------------------------ functional
def test_striping_read_your_writes():
    vol = make_volume("caiti", n_lbas=2048, n_shards=4, stripe_blocks=4,
                      cache_bytes=64 * 4096)
    try:
        for lba in range(0, 2048, 11):
            vol.write(lba, _blk(lba + 1))
        for lba in range(0, 2048, 11):
            assert bytes(vol.read(lba)) == _blk(lba + 1), lba
        vol.fsync()
        # every shard's BTT must have taken real writes (striping spreads)
        for d in vol.shards:
            assert d.impl.btt.writes > 0
        for lba in range(0, 2048, 11):
            assert bytes(vol.read(lba)) == _blk(lba + 1), lba
    finally:
        vol.close()


def test_write_multi_roundtrip_spans_shards():
    vol = make_volume("caiti", n_lbas=1024, n_shards=4, stripe_blocks=1,
                      cache_bytes=64 * 4096)
    try:
        blocks = [_blk(40 + i) for i in range(8)]
        vol.write_multi(100, blocks)          # stripe_blocks=1: 8 shard hops
        for i in range(8):
            assert bytes(vol.read(100 + i)) == _blk(40 + i)
        assert vol.journal.last_txid() >= 1
    finally:
        vol.close()


def test_shared_pool_drains_all_shards():
    vol = make_volume("caiti", n_lbas=1024, n_shards=4, stripe_blocks=2,
                      cache_bytes=1024 * 4096, shared_workers=2)
    try:
        # shards must NOT own private eviction threads
        for d in vol.shards:
            assert d.impl._workers == []
        assert isinstance(vol.pool, SharedEvictionPool)
        for lba in range(256):
            vol.write(lba, _blk(lba))
        for _ in range(300):
            if vol.occupancy() == 0.0:
                break
            time.sleep(0.01)
        assert vol.occupancy() == 0.0        # eager eviction drained
        snap = vol.metrics_snapshot()
        assert snap["bg_evictions"] + snap["bypass_writes"] >= 256
        assert snap["bg_evictions"] > 0
    finally:
        vol.close()


def test_global_bypass_watermark_trips_before_local_full():
    # no eager eviction -> staged bytes only grow, so the volume watermark
    # (25%) trips long before any single shard's cache is full
    vol = make_volume("caiti-noee", n_lbas=4096, n_shards=4,
                      stripe_blocks=2, cache_bytes=256 * 4096,
                      bypass_watermark=0.25)
    try:
        for lba in range(128):
            vol.write(lba, _blk(lba))
        snap = vol.metrics_snapshot()
        assert snap["bypass_writes"] > 0
        # and no shard ever filled locally
        for d in vol.shards:
            assert d.impl.staged_slots() < len(d.impl._slots)
    finally:
        vol.close()


# ------------------------------------------------------ layered read path
def test_read_tier_layered_path():
    """tier -> transit -> BTT: after fsync (writebacks populated the
    tier) reads are served from DRAM; writes invalidate tier entries.
    The transit cache (512 slots) exceeds the 171 writes so no write can
    take the bypass path — every block writebacks through the tier and
    ``read_misses == 0`` is deterministic."""
    vol = make_volume("caiti", n_lbas=1024, n_shards=4, stripe_blocks=4,
                      cache_bytes=512 * 4096, read_tier_bytes=512 * 4096)
    try:
        for lba in range(0, 512, 3):
            vol.write(lba, _blk(lba + 1))
        vol.fsync()
        for lba in range(0, 512, 3):
            assert bytes(vol.read(lba)) == _blk(lba + 1), lba
        snap = vol.metrics_snapshot()
        assert snap["read_tier_hits"] > 0
        assert snap["read_misses"] == 0        # everything came from DRAM
        # overwrite must invalidate: the tier never serves stale data
        vol.write(3, _blk(99))
        assert bytes(vol.read(3)) == _blk(99)
        vol.fsync()
        assert bytes(vol.read(3)) == _blk(99)
    finally:
        vol.close()


def test_read_tier_populates_on_read_miss():
    vol = make_volume("caiti", n_lbas=256, n_shards=2,
                      cache_bytes=32 * 4096, read_tier_bytes=64 * 4096)
    try:
        for lba in range(32):
            vol.write(lba, _blk(lba))
        vol.fsync()
        vol.read_tier.clear()                  # cold tier
        assert bytes(vol.read(5)) == _blk(5)   # miss fills the tier
        before = vol.metrics_snapshot()["read_tier_hits"]
        assert bytes(vol.read(5)) == _blk(5)   # now a tier hit
        assert vol.metrics_snapshot()["read_tier_hits"] == before + 1
    finally:
        vol.close()


def test_replication_scrub_clean():
    vol = make_volume("caiti", n_lbas=512, n_shards=4, replicas=2,
                      cache_bytes=64 * 4096)
    try:
        for lba in range(0, 512, 5):
            vol.write(lba, _blk(lba + 7))
        vol.fsync()
        assert vol.scrub_replicas(5) == 0
        # replica really lives on a different shard
        s0, _ = vol._map(0, 0)
        s1, _ = vol._map(0, 1)
        assert s0 != s1
    finally:
        vol.close()


# -------------------------------------------- degraded reads + resync
def _corrupt_primary(vol, lba):
    shard, local = vol._map(lba, 0)
    vol.shards[shard].impl.btt.write(
        local, np.frombuffer(b"\xde" * 4096, np.uint8))


def test_degraded_read_and_background_resync():
    """ACCEPTANCE: with one replica and injected primary-shard
    corruption, every read returns correct data (replica fallback), and
    the ReplicaResyncer restores scrub divergence to zero while
    foreground I/O keeps flowing."""
    vol = make_volume("caiti", n_lbas=512, n_shards=4, replicas=2,
                      cache_bytes=64 * 4096, read_tier_bytes=64 * 4096)
    try:
        for lba in range(0, 128, 2):
            vol.write(lba, _blk(lba + 7))
        vol.fsync()
        bad = [0, 10, 20, 30, 40]
        for lba in bad:
            _corrupt_primary(vol, lba)
        vol.read_tier.clear()                  # force cold (BTT) reads
        assert vol.scrub_replicas() == len(bad)
        detail = vol.scrub_replicas_detail()
        assert {d[0] for d in detail} == set(bad)
        assert all(d[1] == 0 for d in detail)  # the PRIMARY copy is bad
        # every read returns the correct data via the replica
        for lba in bad:
            assert bytes(vol.read(lba)) == _blk(lba + 7), lba
        snap = vol.metrics_snapshot()
        assert snap["degraded_reads"] == len(bad)
        # the degraded read read-repaired the tier: a second pass serves
        # good data from DRAM without degrading again
        for lba in bad:
            assert bytes(vol.read(lba)) == _blk(lba + 7), lba
        assert vol.metrics_snapshot()["degraded_reads"] == len(bad)
        # degraded reads auto-queued repairs; foreground I/O proceeds
        # while the background pool drains them
        for lba in range(1, 64, 2):
            vol.write(lba, _blk(lba))
            assert bytes(vol.read(lba)) == _blk(lba)
        assert vol.resyncer.wait_idle(20.0)
        vol.fsync()       # drain staged foreground copies: scrub reads
        # below the caches, and a half-evicted write is not divergence
        assert vol.scrub_replicas() == 0       # divergence fully repaired
        assert vol.resyncer.repaired_blocks >= len(bad)
        assert vol.metrics_snapshot()["resync_repairs"] >= len(bad)
    finally:
        vol.close()


def test_resync_sweep_repairs_unread_blocks():
    """A scrub-driven resync() repairs divergence nobody has read yet."""
    vol = make_volume("caiti", n_lbas=256, n_shards=3, replicas=2,
                      cache_bytes=32 * 4096)
    try:
        for lba in range(64):
            vol.write(lba, _blk(lba + 1))
        vol.fsync()
        for lba in (3, 9, 27):
            _corrupt_primary(vol, lba)
        assert vol.scrub_replicas() == 3
        assert vol.resyncer.resync() == 3      # queued straight from scrub
        assert vol.resyncer.wait_idle(20.0)
        assert vol.scrub_replicas() == 0
        for lba in (3, 9, 27):
            assert bytes(vol.read(lba)) == _blk(lba + 1)
    finally:
        vol.close()


def test_corrupt_replica_repaired_from_primary():
    """Divergence on the REPLICA side: reads never degrade (primary is
    fine) but scrub finds it and resync repairs from the primary."""
    vol = make_volume("caiti", n_lbas=256, n_shards=3, replicas=2,
                      cache_bytes=32 * 4096)
    try:
        vol.write(7, _blk(70))
        vol.fsync()
        s1, l1 = vol._map(7, 1)
        vol.shards[s1].impl.btt.write(
            l1, np.frombuffer(b"\xab" * 4096, np.uint8))
        detail = vol.scrub_replicas_detail()
        assert [(d[0], d[1]) for d in detail] == [(7, 1)]
        assert bytes(vol.read(7)) == _blk(70)
        assert vol.metrics_snapshot()["degraded_reads"] == 0
        vol.resyncer.resync()
        assert vol.resyncer.wait_idle(20.0)
        assert vol.scrub_replicas() == 0
    finally:
        vol.close()


def test_reopen_tie_divergence_never_destroys_good_copy(tmp_path):
    """Without the persisted crc ledger (``persist_ledger=False``) the
    ledger is empty after reopen, so a 1-vs-1 primary/replica tie is
    undecidable: resync must flag it and REFUSE to repair — overwriting
    the replica with the corrupt primary would turn recoverable
    divergence into data loss.  With >= 3 copies a strict majority still
    repairs."""
    path = str(tmp_path / "vol")
    kw = dict(n_lbas=256, n_shards=3, replicas=2, cache_bytes=32 * 4096,
              backend="file", path=path, persist_ledger=False)
    vol = make_volume("caiti", **kw)
    vol.write(5, _blk(55))
    vol.fsync()
    vol.close()
    vol = make_volume("caiti", **kw)
    _corrupt_primary(vol, 5)
    try:
        assert vol.scrub_replicas() == 1
        vol.resyncer.resync()
        assert vol.resyncer.wait_idle(10.0)
        assert vol.scrub_replicas() == 1       # still flagged, NOT "fixed"
        s1, l1 = vol._map(5, 1)
        assert bytes(vol.shards[s1].impl.btt.read(l1)) == _blk(55)
    finally:
        vol.close()
    # three copies: majority decides even with an empty ledger
    path3 = str(tmp_path / "vol3")
    kw3 = dict(n_lbas=256, n_shards=3, replicas=3, cache_bytes=32 * 4096,
               backend="file", path=path3, persist_ledger=False)
    vol = make_volume("caiti", **kw3)
    vol.write(5, _blk(66))
    vol.fsync()
    vol.close()
    vol = make_volume("caiti", **kw3)
    _corrupt_primary(vol, 5)
    try:
        assert vol.scrub_replicas() >= 1
        vol.resyncer.resync()
        assert vol.resyncer.wait_idle(10.0)
        assert vol.scrub_replicas() == 0
        assert bytes(vol.read(5)) == _blk(66)
    finally:
        vol.close()


# ------------------------------------------------------- crash atomicity
def _crash_on_nth_write(pmem, n):
    state = {"count": 0}

    def hook(label):
        if label == "pmem_write_begin":
            state["count"] += 1
            if state["count"] == n:
                raise SimulatedCrash(label)

    pmem.crash_hook = hook
    return state


def _reopen(path, **kw):
    return make_volume("btt", n_lbas=256, n_shards=4, stripe_blocks=1,
                       backend="file", path=path, **kw)


def test_torn_multishard_write_rolls_forward(tmp_path):
    """Crash mid in-place phase, AFTER the journal header committed: the
    write must be fully visible after recovery (roll forward)."""
    path = str(tmp_path / "vol")
    vol = _reopen(path)
    base = [_blk(1 + i) for i in range(4)]
    vol.write_multi(8, base)                       # lbas 8..11, shards 0..3
    vol.fsync()
    # in-place writes start after journal commit; lba 9's home shard sees
    # exactly one write for this tx — crash there, leaving lba 8 new and
    # lbas 9..11 old (a torn multi-shard write)
    new = [_blk(101 + i) for i in range(4)]
    shard2, _ = vol._map(9, 0)                     # 2nd block's home shard
    _crash_on_nth_write(vol.shards[shard2].impl.btt.pmem, 1)
    with pytest.raises(SimulatedCrash):
        vol.write_multi(8, new)
    # "power loss": abandon the torn volume, reopen from the files
    for d in vol.shards:
        d.impl.btt.pmem.crash_hook = None
    vol2 = _reopen(path)
    assert vol2.recovery_stats["replayed_txs"] >= 1
    got = [bytes(vol2.read(8 + i)) for i in range(4)]
    assert got == new, "journaled write must be rolled forward whole"
    vol2.close()


def test_torn_journal_write_is_invisible(tmp_path):
    """Crash BEFORE the journal header lands: the old data must remain
    fully intact on every shard (the write never happened)."""
    path = str(tmp_path / "vol")
    vol = _reopen(path)
    base = [_blk(21 + i) for i in range(4)]
    vol.write_multi(16, base)
    vol.fsync()
    # next tx journals on slot (txid % 64); its payload writes hit the
    # journal shard's BTT first — crash on the first of them
    txid = vol.journal.next_txid
    jshard, _ = vol.journal._slot_home(txid % vol.journal.n_slots)
    _crash_on_nth_write(vol.shards[jshard].impl.btt.pmem, 1)
    with pytest.raises(SimulatedCrash):
        vol.write_multi(16, [_blk(201 + i) for i in range(4)])
    for d in vol.shards:
        d.impl.btt.pmem.crash_hook = None
    vol2 = _reopen(path)
    got = [bytes(vol2.read(16 + i)) for i in range(4)]
    assert got == base, "uncommitted tx must be invisible (old data whole)"
    vol2.close()


def test_ring_wrap_checkpoint_still_replays_current_tx(tmp_path):
    """Regression: the wrap-time checkpoint must mark applied STRICTLY
    below the wrapping txid — a crash mid in-place of that tx must still
    roll forward (not be skipped as 'already applied')."""
    path = str(tmp_path / "vol")
    vol = make_volume("btt", n_lbas=256, n_shards=4, stripe_blocks=1,
                      backend="file", path=path, journal_slots=4)
    for k in range(4):                             # fill the 4-slot ring
        vol.write_multi(8, [_blk(k)] * 4)
    # tx 5 wraps onto tx 1's slot -> checkpoint fires (one superblock
    # write on every shard), then journal (slot home = shard 1), then
    # in-place: lba 10's shard sees superblock (1st) + in-place (2nd)
    shard2, _ = vol._map(10, 0)
    assert vol.journal._slot_home(5 % 4)[0] != shard2
    _crash_on_nth_write(vol.shards[shard2].impl.btt.pmem, 2)
    with pytest.raises(SimulatedCrash):
        vol.write_multi(8, [_blk(50 + i) for i in range(4)])
    for d in vol.shards:
        d.impl.btt.pmem.crash_hook = None
    vol2 = make_volume("btt", n_lbas=256, n_shards=4, stripe_blocks=1,
                       backend="file", path=path, journal_slots=4)
    assert vol2.recovery_stats["replayed_txs"] >= 1
    got = [bytes(vol2.read(8 + i)) for i in range(4)]
    assert got == [_blk(50 + i) for i in range(4)]
    vol2.close()


def test_fsync_checkpoint_skips_replay(tmp_path):
    """After fsync, journal records are checkpointed: recovery must not
    clobber a later (also fsynced) single-block overwrite."""
    path = str(tmp_path / "vol")
    vol = _reopen(path)
    vol.write_multi(8, [_blk(1 + i) for i in range(4)])
    vol.fsync()                                    # checkpoint: applied_txid
    vol.write(9, _blk(99))                         # later overwrite
    vol.fsync()
    vol2 = _reopen(path)
    assert vol2.recovery_stats["replayed_txs"] == 0
    assert bytes(vol2.read(9)) == _blk(99)
    vol2.close()


def test_reopen_geometry_mismatch_rejected(tmp_path):
    path = str(tmp_path / "vol")
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1,
                      backend="file", path=path)
    vol.close()
    with pytest.raises(AssertionError, match="stripe_blocks"):
        make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=4,
                    backend="file", path=path)
    # journal geometry shifts the data region too — must also be rejected
    with pytest.raises(AssertionError, match="journal_span"):
        make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1,
                    journal_span=2, backend="file", path=path)


def test_reopen_missing_member_rejected(tmp_path):
    """A shard file without a superblock is a damaged volume, never a
    fresh one — re-formatting would orphan the surviving shards."""
    import os
    path = str(tmp_path / "vol")
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1,
                      backend="file", path=path)
    vol.write(0, _blk(5))
    vol.close()
    os.remove(path + ".shard1")
    with pytest.raises(AssertionError, match="member missing"):
        make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1,
                    backend="file", path=path)


def test_caiti_volume_crash_recovery(tmp_path):
    """Caiti shards (staged writes) + abrupt abandonment: journal replay
    restores every journaled write after reopen.  The read tier is
    enabled: clean slots are never journaled, so write atomicity must be
    byte-for-byte identical with the tier in the stack."""
    path = str(tmp_path / "vol")
    vol = make_volume("caiti", n_lbas=512, n_shards=3, stripe_blocks=2,
                      cache_bytes=64 * 4096, backend="file", path=path,
                      read_tier_bytes=32 * 4096)
    vol.write_multi(10, [_blk(31 + i) for i in range(6)])
    # crash BEFORE fsync: staged copies may not have reached BTT, but the
    # journal committed first — flush mmaps (power loss keeps media state)
    for d in vol.shards:
        d.impl.btt.pmem.persist()
    del vol                                        # no close(): no drain
    vol2 = make_volume("caiti", n_lbas=512, n_shards=3, stripe_blocks=2,
                       cache_bytes=64 * 4096, backend="file", path=path,
                       read_tier_bytes=32 * 4096)
    got = [bytes(vol2.read(10 + i)) for i in range(6)]
    assert got == [_blk(31 + i) for i in range(6)]
    vol2.close()


# -------------------------------------------------- chained-tx atomicity
def _crash_on_nth_btt_write(vol, n):
    """Global (cross-shard) crash injection at BTT-write granularity —
    one counter over every shard, so crash points line up with the
    protocol steps of ``repro.core.sim.chain_commit_steps``."""
    state = {"count": 0}
    for d in vol.shards:
        btt = d.impl.btt
        orig = btt.write

        def wrapped(lba, data, _orig=orig):
            state["count"] += 1
            if state["count"] == n:
                raise SimulatedCrash("btt_write")
            return _orig(lba, data)

        btt.write = wrapped
    return state


_CHAIN_KW = dict(n_lbas=128, n_shards=2, stripe_blocks=1,
                 journal_slots=16, journal_span=2, backend="file")


def _chain_crash_run(tmp_path, crash_write: int):
    """Write an 8-block (4x-span) object, fsync, then overwrite it with
    a crash injected on BTT write ``crash_write`` of the chained tx.
    Returns (outcome, steps_executed): outcome 'old' | 'new' | 'torn'
    read back after reopen+recovery."""
    path = str(tmp_path / f"chain{crash_write}")
    old = [_blk(10 + i) for i in range(8)]
    new = [_blk(110 + i) for i in range(8)]
    vol = make_volume("btt", path=path, **_CHAIN_KW)
    vol.write_multi(8, old)
    vol.fsync()
    state = _crash_on_nth_btt_write(vol, crash_write)
    crashed = True
    try:
        vol.write_multi(8, new)
        crashed = False
    except SimulatedCrash:
        pass
    # "power loss": abandon the torn volume, reopen from the files
    for d in vol.shards:
        d.impl.btt.pmem.persist()
    del vol
    vol2 = make_volume("btt", path=path, **_CHAIN_KW)
    got = [bytes(vol2.read(8 + i)) for i in range(8)]
    vol2.close()
    outcome = "old" if got == old else "new" if got == new else "torn"
    return outcome, state["count"] - (1 if crashed else 0), crashed


def test_chain_crash_between_links_leaves_old_object(tmp_path):
    """Kill between chain links (inside the journal phase, before the
    tail header): the OLD object must be fully intact — the chain never
    committed, no in-place write happened."""
    steps = chain_commit_steps(8, 2)
    tail = steps.index(("tail_header",))          # step 11 of 20
    # crash on the 6th BTT write: mid payload of link 2 (between links)
    outcome, done, crashed = _chain_crash_run(tmp_path, 6)
    assert crashed and outcome == "old"
    assert done < tail                            # really pre-commit
    # crash on the LAST non-tail header (the write before the commit pt)
    outcome, done, crashed = _chain_crash_run(tmp_path, tail + 1)
    assert crashed and outcome == "old"


def test_chain_crash_between_tail_header_and_inplace_rolls_forward(tmp_path):
    """Kill between the tail header and the in-place writes: the tail
    landed, so recovery must roll the WHOLE new object forward."""
    steps = chain_commit_steps(8, 2)
    tail = steps.index(("tail_header",))
    # tail header is BTT write tail+1; crash on the first in-place write
    outcome, done, crashed = _chain_crash_run(tmp_path, tail + 2)
    assert crashed and outcome == "new"
    assert done == tail + 1                       # exactly post-commit


@pytest.mark.slow
def test_chain_crash_property_every_point_whole_object(tmp_path):
    """ACCEPTANCE: a crash ANYWHERE inside a 4x-span logical write
    surfaces either the complete new object or the complete old one —
    property-tested over every injected BTT-write crash point, and
    cross-validated against the simulator's chain-crash model."""
    steps = chain_commit_steps(8, 2)              # 8 payload, 3 hdr, 1
    n = 1                                         # tail, 8 in-place = 20
    while True:
        outcome, done, crashed = _chain_crash_run(tmp_path, n)
        if not crashed:                           # past the last write
            assert outcome == "new"
            assert done == len(steps)             # model counts the
            break                                 # protocol exactly
        assert outcome in ("old", "new"), f"torn object at write {n}"
        assert outcome == chain_crash_outcome(8, 2, done), \
            f"real volume disagrees with sim model at crash point {n}"
        n += 1
    assert n == len(steps) + 1                    # swept every step


def test_chain_crash_smoke_key_points(tmp_path):
    """Fast (not slow-marked) subset of the property sweep: one point in
    each protocol phase, still model-checked."""
    steps = chain_commit_steps(8, 2)
    tail = steps.index(("tail_header",))
    for n in (1, tail, tail + 1, tail + 2, len(steps)):
        outcome, done, crashed = _chain_crash_run(tmp_path, n)
        assert crashed and outcome == chain_crash_outcome(8, 2, done), n


def test_write_multi_exceeding_ring_rejected(tmp_path):
    vol = make_volume("btt", n_lbas=128, n_shards=2, stripe_blocks=1,
                      journal_slots=4, journal_span=2)
    try:
        assert vol.max_atomic_write_blocks() == 8
        with pytest.raises(AssertionError, match="exceeds"):
            vol.write_multi(0, [_blk(i) for i in range(10)])
    finally:
        vol.close()


# ------------------------------------------------- batched log pipeline
def test_log_batcher_coalesces_concurrent_chains():
    """>= 4 concurrent write_multi chains share a leader's slot-shard
    pass: far fewer journal batches than calls, every chain committed
    and readable, metrics account for the coalescing."""
    vol = make_volume("caiti", n_lbas=2048, n_shards=2,
                      cache_bytes=64 * 4096, log_window=0.1)
    try:
        start = threading.Barrier(8)

        def worker(j):
            start.wait()
            vol.write_multi(j * 32, [_blk(j + i) for i in range(4)])

        ts = [threading.Thread(target=worker, args=(j,)) for j in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=30)
        st = vol._log_batcher.stats()
        assert st["calls"] == 8
        assert st["batches"] + st["coalesced"] == 8
        # generous bounds (loaded CI schedulers stagger threads): the
        # essential claim is that coalescing HAPPENED
        assert st["batches"] <= 5, st
        assert st["coalesced"] >= 3, st
        for j in range(8):
            for i in range(4):
                assert bytes(vol.read(j * 32 + i)) == _blk(j + i)
        snap = vol.metrics_snapshot()
        assert snap["log_batches"] == st["batches"]
        assert snap["log_batch_links"] == 8          # 4 blocks = 1 link each
        assert snap["log_batch_coalesced"] >= 3
        assert snap["chain_txs"] == 8
    finally:
        vol.close()


def test_journal_log_batch_multi_entry_pass_and_scan():
    """A multi-entry log_batch reserves contiguous txids per entry, each
    entry its own chain, and scan() replays every member whole."""
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1,
                      journal_slots=16, journal_span=2)
    try:
        jl = vol.journal
        e1 = [_blk(10 + i) for i in range(5)]        # 3 links (span 2)
        e2 = [_blk(40 + i) for i in range(2)]        # 1 link
        e3 = [_blk(70 + i) for i in range(4)]        # 2 links
        res = jl.log_batch([(8, e1), (32, e2), (64, e3)])
        assert res[0] == [1, 2, 3]
        assert res[1] == [4]
        assert res[2] == [5, 6]
        assert jl.chains_logged == 3
        recs = jl.scan()
        assert [t for t, _, _ in recs] == [1, 2, 3, 4, 5, 6]
        replay = {}
        for _txid, lba, blocks in recs:
            for i, b in enumerate(blocks):
                replay[lba + i] = b
        for base, blks in ((8, e1), (32, e2), (64, e3)):
            for i, b in enumerate(blks):
                assert replay[base + i] == b, (base, i)
    finally:
        vol.close()


def test_log_batch_oversized_group_splits_and_single_chain_rejected():
    vol = make_volume("btt", n_lbas=256, n_shards=2, stripe_blocks=1,
                      journal_slots=4, journal_span=2)
    try:
        jl = vol.journal
        # 3 entries x 2 links = 6 links > 4 slots: must split into
        # sub-groups that fit, all entries still committed
        entries = [(k * 8, [_blk(k * 10 + i) for i in range(4)])
                   for k in range(3)]
        res = jl.log_batch([(lba, blks) for lba, blks in entries])
        assert [len(r) for r in res] == [2, 2, 2]
        # a SINGLE oversized chain still asserts, as log_chain always did
        with pytest.raises(AssertionError, match="exceeds"):
            jl.log_batch([(0, [_blk(i) for i in range(10)])])
    finally:
        vol.close()


_BATCH_KW = dict(n_lbas=128, n_shards=2, stripe_blocks=1,
                 journal_slots=16, journal_span=2, backend="file")


def _batch_crash_run(tmp_path, crash_write: int):
    """Two 8-block objects overwritten through ONE LogBatcher flush with
    a crash injected on global BTT write ``crash_write``.  Returns the
    post-recovery outcomes (['old'|'new'|'torn'] per member, crashed).

    Deterministic write schedule of the batched flush (16 payloads, 6
    non-tail headers, 2 tails, 16 in-place): write 23 is member 1's tail,
    write 24 member 2's — so a crash anywhere must surface each member
    whole, never a partially replayed member chain."""
    path = str(tmp_path / f"batch{crash_write}")
    old1 = [_blk(10 + i) for i in range(8)]
    old2 = [_blk(30 + i) for i in range(8)]
    new1 = [_blk(110 + i) for i in range(8)]
    new2 = [_blk(130 + i) for i in range(8)]
    vol = make_volume("btt", path=path, **_BATCH_KW)
    vol.write_multi(8, old1)
    vol.write_multi(32, old2)
    vol.fsync()
    state = _crash_on_nth_btt_write(vol, crash_write)
    crashed = True
    try:
        # both members in ONE batch flush (the deterministic equivalent
        # of two concurrent write_multi calls coalescing)
        vol._flush_log_batch([LogEntry(8, new1), LogEntry(32, new2)])
        crashed = False
    except SimulatedCrash:
        pass
    for d in vol.shards:
        d.impl.btt.pmem.persist()
    del vol
    vol2 = make_volume("btt", path=path, **_BATCH_KW)
    outs = []
    for base, old, new in ((8, old1, new1), (32, old2, new2)):
        got = [bytes(vol2.read(base + i)) for i in range(8)]
        outs.append("old" if got == old else "new" if got == new
                    else "torn")
    vol2.close()
    return outs, state["count"] - (1 if crashed else 0), crashed


# batched-flush protocol geometry (see _batch_crash_run docstring)
_BATCH_TAIL1, _BATCH_TAIL2, _BATCH_WRITES = 23, 24, 40


def _assert_batch_crash_point(n, outs, done, crashed):
    assert crashed, n
    assert all(o in ("old", "new") for o in outs), \
        f"partial member chain replayed at crash write {n}: {outs}"
    if done < _BATCH_TAIL1:                  # no tail landed
        assert outs == ["old", "old"], (n, outs)
    elif done < _BATCH_TAIL2:                # member 1's tail only
        assert outs == ["new", "old"], (n, outs)
    else:                                    # both tails on media
        assert outs == ["new", "new"], (n, outs)


def test_batched_log_crash_key_points(tmp_path):
    """Fast subset of the batched-flush crash sweep: one point per
    protocol phase (payloads, headers, first/second tail, in-place)."""
    for n in (1, 9, 20, _BATCH_TAIL1, _BATCH_TAIL2, _BATCH_TAIL2 + 1,
              _BATCH_WRITES):
        outs, done, crashed = _batch_crash_run(tmp_path, n)
        _assert_batch_crash_point(n, outs, done, crashed)


@pytest.mark.slow
def test_batched_log_crash_property_every_point(tmp_path):
    """ACCEPTANCE (PR 4 satellite): a crash ANYWHERE inside a LogBatcher
    flush never replays a partial batch member chain — each member is
    wholly old or wholly new, and members commit in tail order."""
    n = 1
    while True:
        outs, done, crashed = _batch_crash_run(tmp_path, n)
        if not crashed:
            assert outs == ["new", "new"]
            assert done == _BATCH_WRITES     # schedule counted exactly
            break
        _assert_batch_crash_point(n, outs, done, crashed)
        n += 1
    assert n == _BATCH_WRITES + 1            # swept every write point


def test_log_batch_multigroup_crash_never_loses_applied_members(tmp_path):
    """REGRESSION: when a batch splits into ring-bounded sub-groups, an
    earlier group's members must be applied in place BEFORE a later
    group journals — the later group's ring-wrap checkpoint marks them
    applied and its slots reuse theirs, so deferring their in-place
    writes to the end of the batch would let a crash silently LOSE
    fully-committed chains.  Swept over every BTT write point of a
    two-group flush: members only ever commit in order, whole.

    Deterministic schedule (journal_slots=4, span=2; three 4-block
    members -> group 1 = {m0, m1} [4 links, txids 7-10], group 2 = {m2}
    [txids 11-12, wraps onto m0's slots]): writes 1-10 group-1
    payloads+headers, 11-12 its tails (m0's then m1's), 13-20 its
    in-place phase, 21-22 the wrap checkpoint's superblocks, 23-28
    group-2 payloads+headers+tail, 29-32 its in-place phase."""
    kw = dict(n_lbas=128, n_shards=2, stripe_blocks=1,
              journal_slots=4, journal_span=2, backend="file")
    bases = (8, 24, 40)
    olds = [[_blk(20 * k + i) for i in range(4)] for k in range(3)]
    news = [[_blk(100 + 20 * k + i) for i in range(4)] for k in range(3)]
    n = 1
    while True:
        path = str(tmp_path / f"mg{n}")
        vol = make_volume("btt", path=path, **kw)
        for base, old in zip(bases, olds):
            vol.write_multi(base, old)
        vol.fsync()
        state = _crash_on_nth_btt_write(vol, n)
        crashed = True
        try:
            vol._flush_log_batch([LogEntry(b, nw)
                                  for b, nw in zip(bases, news)])
            crashed = False
        except SimulatedCrash:
            pass
        for d in vol.shards:
            d.impl.btt.pmem.persist()
        del vol
        vol2 = make_volume("btt", path=path, **kw)
        outs = []
        for base, old, new in zip(bases, olds, news):
            got = [bytes(vol2.read(base + i)) for i in range(4)]
            outs.append("old" if got == old else "new" if got == new
                        else "torn")
        vol2.close()
        done = state["count"] - (1 if crashed else 0)
        if not crashed:
            assert outs == ["new", "new", "new"]
            assert done == 32                    # schedule counted exactly
            break
        assert all(o in ("old", "new") for o in outs), (n, outs)
        if done < 11:                            # before m0's tail
            assert outs == ["old", "old", "old"], (n, outs)
        elif done < 12:                          # m0's tail only
            assert outs == ["new", "old", "old"], (n, outs)
        elif done < 28:
            # group 1 committed; THE regression window is done in
            # [20, 27]: group 2 checkpointed + overwrote group 1's
            # slots — its members must still read back new
            assert outs == ["new", "new", "old"], (n, outs)
        else:                                    # m2's tail on media
            assert outs == ["new", "new", "new"], (n, outs)
        n += 1
    assert n == 33                               # swept every write point


# ------------------------------------- async frontend x chained-tx crashes
_ASYNC_KW = dict(policy="btt", n_lbas=256, n_shards=2, stripe_blocks=1,
                 journal_slots=16, journal_span=2, backend="file")


def _async_mixed_fixture():
    """Three 8-block versioned objects overwritten by a deterministic
    mixed schedule: an ASYNC chain (queued), a SYNC write_multi (runs
    first — chains submitted via AsyncIOEngine mix with blocking
    callers), a poll executing the async chain, then a second async
    chain.  Per object: 8 payloads + 3 headers + 1 tail + 8 in-place =
    20 BTT writes, execution order o1(sync), o0, o2 — 60 write points."""
    from aio_harness import VersionedObjects
    cell = {}

    def prep(vol):
        cell["objs"] = VersionedObjects(n_objects=3, n_blocks=8, stride=16)
        cell["objs"].write_base(vol)

    def sched():
        objs = cell["objs"]
        s = []
        lba, v, blocks = objs.next_version(0)
        s.append(("submit_multi", f"o0v{v}", lba, blocks))   # queued
        lba, v, blocks = objs.next_version(1)
        s.append(("sync_multi", lba, blocks))                # runs first
        s.append(("poll", None))                             # runs o0
        lba, v, blocks = objs.next_version(2)
        s.append(("submit_multi", f"o2v{v}", lba, blocks))
        s.append(("poll", None))
        return s

    def check(n, done, crashed, run, vol2):
        from aio_harness import check_versioned_invariants
        check_versioned_invariants(cell["objs"], run, vol2, crashed)
        if crashed:
            # execution order o1, o0, o2 (20 writes each, tail = write
            # 12 of its own chain): commit points at global writes 12,
            # 32, 52 — each member commits whole, in order
            objs = cell["objs"]
            got = [objs.read_version(vol2, o) for o in (1, 0, 2)]
            want = [1 if done >= tail else 0 for tail in (12, 32, 52)]
            assert got == want, (n, done, got, want)

    return prep, sched, check


def test_async_mixed_chain_crash_key_points(tmp_path):
    """Fast subset of the async crash sweep: one point per protocol
    phase of each member (payloads / pre-tail / tail / in-place)."""
    from aio_harness import run_crash_point
    prep, sched, check = _async_mixed_fixture()
    for n in (1, 11, 12, 13, 31, 32, 33, 52, 60):
        done, crashed, run, vol2 = run_crash_point(
            str(tmp_path / f"akey{n}"), n, sched, vol_kw=_ASYNC_KW,
            prep_fn=prep)
        try:
            assert crashed, n
            check(n, done, crashed, run, vol2)
        finally:
            vol2.close()


@pytest.mark.slow
def test_async_mixed_chain_crash_property_every_point(tmp_path):
    """ACCEPTANCE (async frontend): chains submitted via AsyncIOEngine,
    mixed with sync write_multi, crashed at EVERY BTT write point —
    recovery never surfaces a torn member, members commit in execution
    order, and a ticket that completed before the crash is never lost."""
    from aio_harness import crash_sweep
    prep, sched, check = _async_mixed_fixture()
    points = crash_sweep(tmp_path, sched, check, vol_kw=_ASYNC_KW,
                         prep_fn=prep)
    assert points == 61                      # 3 x 20 writes, swept exactly


# ------------------------------------------------------- group commit
def test_group_commit_coalesces_concurrent_fsyncs():
    """>= 4 concurrent fsync callers share a leader's drain+checkpoint:
    far fewer commits than calls, and every caller's writes are covered
    (applied mark reaches the last txid)."""
    vol = make_volume("caiti", n_lbas=1024, n_shards=2,
                      cache_bytes=64 * 4096, commit_window=0.1)
    try:
        start = threading.Barrier(8)

        def worker(j):
            start.wait()
            vol.write_multi(j * 16, [_blk(j + i) for i in range(4)])
            vol.fsync()

        ts = [threading.Thread(target=worker, args=(j,)) for j in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=30)
        st = vol._committer.stats()
        assert st["calls"] == 8
        # generous bounds (loaded CI schedulers stagger threads): the
        # essential claim is that coalescing HAPPENED and accounting adds
        # up, not an exact batch shape
        assert st["commits"] + st["coalesced"] == 8
        assert st["commits"] <= 5, st           # leaders gathered others
        assert st["coalesced"] >= 3, st
        assert vol.journal.applied_txid == vol.journal.last_txid()
        for j in range(8):
            for i in range(4):
                assert bytes(vol.read(j * 16 + i)) == _blk(j + i)
        snap = vol.metrics_snapshot()
        assert snap["group_commit"]["coalesced"] >= 3
    finally:
        vol.close()


def test_reopen_verifies_reads_from_persisted_ledger(tmp_path):
    """A reopened volume must verify reads BEFORE the first overwrite:
    the crc ledger summary persisted at fsync makes post-reopen
    corruption detectable, and the read degrades to the replica."""
    path = str(tmp_path / "vol")
    kw = dict(n_lbas=256, n_shards=3, replicas=2, cache_bytes=32 * 4096,
              backend="file", path=path)
    vol = make_volume("caiti", **kw)
    for lba in range(0, 64, 2):
        vol.write(lba, _blk(lba + 9))
    vol.fsync()
    vol.close()
    vol = make_volume("caiti", **kw)
    try:
        assert len(vol._crcs) >= 32              # ledger survived reopen
        _corrupt_primary(vol, 10)
        assert bytes(vol.read(10)) == _blk(19)   # degraded, not garbage
        snap = vol.metrics_snapshot()
        assert snap["degraded_reads"] == 1
        assert snap["verify_failures"] == 1
        # and the divergence is now decidable: resync repairs it
        vol.resyncer.resync()
        assert vol.resyncer.wait_idle(10.0)
        assert vol.scrub_replicas() == 0
    finally:
        vol.close()


# ---------------------------------------------------------------- QoS
def test_token_bucket_caps_rate():
    tb = TokenBucket(rate_bytes_s=1e6, burst_bytes=4096)
    assert tb.acquire(4096) == 0.0                 # burst covers the first
    t0 = time.perf_counter()
    tb.acquire(4096)                               # must wait ~4.1ms refill
    assert time.perf_counter() - t0 > 0.002
    assert not tb.try_acquire(4096)


def test_wfq_gate_admits_by_start_tag():
    gate = WFQGate(max_inflight=1)
    gate.set_tenant("a", weight=2.0)
    gate.set_tenant("b", weight=1.0)
    first = gate.admit("a", 100)        # occupies the slot; F_a = 50
    order = []

    def waiter(name):
        t = gate.admit(name, 100)
        order.append(name)
        gate.done(t)

    # a's next tag is 50, b's is 0 -> b must win the freed slot
    ta = threading.Thread(target=waiter, args=("a",))
    ta.start()
    time.sleep(0.05)
    tb_ = threading.Thread(target=waiter, args=("b",))
    tb_.start()
    time.sleep(0.05)
    gate.done(first)
    ta.join(timeout=5)
    tb_.join(timeout=5)
    assert order == ["b", "a"]


def test_wfq_zero_byte_admit_advances_virtual_time():
    """Regression: a zero-byte admit used to advance NO virtual time, so
    the tenant's next request kept an identical start tag and could
    leapfrog earlier waiters in the (S, seq) heap.  Clamped to >= 1
    byte, every admit moves the finish tag."""
    gate = WFQGate(max_inflight=4)
    gate.set_tenant("a")
    gate.set_tenant("b")
    for _ in range(3):
        gate.done(gate.admit("a", 0))
    assert gate.zero_byte_admits == 3
    assert gate._finish["a"] >= 3.0          # 1 clamped byte per admit
    # ordering must respect the accumulated (clamped) virtual time: with
    # one slot held, "a" (3 burned vbytes + the holder's tag) queues
    # behind a fresh "b" whose start tag is the smaller
    gate2 = WFQGate(max_inflight=1)
    gate2.set_tenant("a")
    gate2.set_tenant("b")
    hold = gate2.admit("a", 0)               # zero-byte: still >= 1 vbyte
    order = []

    def waiter(name):
        t = gate2.admit(name, 8)
        order.append(name)
        gate2.done(t)

    ta = threading.Thread(target=waiter, args=("a",))
    ta.start()
    time.sleep(0.05)
    tb_ = threading.Thread(target=waiter, args=("b",))
    tb_.start()
    time.sleep(0.05)
    gate2.done(hold)
    ta.join(timeout=5)
    tb_.join(timeout=5)
    # a's tag inherits the clamped finish (> 0); b starts at 0 and wins
    assert order == ["b", "a"]


def test_wfq_tier_aware_pricing_and_batch_charge():
    """admit/charge/charge_batch price virtual time through the
    AdmissionPolicy: DRAM-served reads cost tier_hit_cost_frac, writes
    and batched log flushes full bytes."""
    pol = AdmissionPolicy(tier_hit_cost_frac=0.25, scan_threshold=0)
    gate = WFQGate(max_inflight=8, policy=pol)
    gate.set_tenant("a")
    gate.done(gate.admit("a", 4096, op="read", tier="tier"))
    assert gate._finish["a"] == pytest.approx(1024.0)       # 1/4 price
    assert gate.vtime_charged["a"] == pytest.approx(1024.0)
    gate.done(gate.admit("a", 4096, op="write"))
    assert gate.vtime_charged["a"] == pytest.approx(1024.0 + 4096.0)
    # an untagged read (probe found nothing DRAM-resident) pre-pays the
    # full PMem price up front — no settle owed
    gate.done(gate.admit("a", 4096, op="read"))
    assert gate.vtime_charged["a"] == pytest.approx(1024.0 + 2 * 4096.0)
    # a probed-DRAM read that raced and served from the backend settles
    # the remainder post-service via charge()
    gate.charge("a", 3072, op="read", tier="backend")
    assert gate.vtime_charged["a"] == pytest.approx(4096.0 + 2 * 4096.0)
    # an op='log' slot admit is intentionally ~free (1 clamped vbyte)
    # and not flagged as a zero-byte bug
    gate.done(gate.admit("a", 0, op="log"))
    assert gate.zero_byte_admits == 0
    assert gate.vtime_charged["a"] == pytest.approx(3 * 4096.0 + 1.0)
    # batched log charge: one pass, both tenants' tags advance
    gate.set_tenant("b", weight=2.0)
    charged = gate.charge_batch({"a": 8192, "b": 8192}, op="log")
    assert charged == {"a": 8192.0, "b": 8192.0}
    assert gate.vtime_charged["b"] == pytest.approx(8192.0)
    # weight 2 halves the finish-tag advance for the same priced bytes
    assert gate._finish["b"] - gate._vtime <= 4096.0 + 1e-9
    stats = gate.stats()
    assert stats["post_charges"] == 2
    assert stats["vtime_charged"]["a"] == int(3 * 4096.0 + 1.0 + 8192.0)


def test_threaded_volume_reads_priced_tier_aware():
    """ROADMAP close-out: gate tags no longer charge reads nothing — a
    tenant's DRAM-served reads debit tier_hit_cost_frac of the PMem
    price, and the per-tenant wfq counters expose it."""
    vol = make_volume("caiti", n_lbas=1024, n_shards=2,
                      cache_bytes=512 * 4096, read_tier_bytes=512 * 4096,
                      tier_hit_cost_frac=0.125,
                      tenants=[TenantSpec("hot"), TenantSpec("cold")])
    try:
        n = 32
        for i in range(n):
            vol.write(i, _blk(i), tenant="hot")
        vol.fsync()                  # writebacks populate the read tier
        for i in range(n):
            assert bytes(vol.read(i, tenant="hot")) == _blk(i)
        snap = vol.metrics_snapshot()
        assert snap["read_misses"] == 0          # all DRAM-served
        charged = snap["wfq"]["vtime_charged"]
        # hot's reads cost 1/8 of PMem price: total = writes (full) +
        # n reads at 512 bytes each — far below double-full-price
        assert charged["hot"] == n * 4096 + n * 512
        assert snap["wfq_vbytes"]["hot"] == charged["hot"]
        assert vol.read_debits["hot"] == n * 512
        # chained writes occupy a gate slot (op='log', 1 clamped vbyte)
        # and charge their real bytes once per batch at flush
        before = vol.metrics_snapshot()["wfq"]["vtime_charged"]["hot"]
        vol.write_multi(512, [_blk(9 + i) for i in range(4)], tenant="hot")
        after = vol.metrics_snapshot()["wfq"]["vtime_charged"]["hot"]
        assert after == before + 1 + 4 * 4096
        # a cold (probe=None) read pre-pays the full PMem price at admit
        # — backend service owes no settle
        lba = 700
        vol.write(lba, _blk(7), tenant="cold")
        vol.fsync()
        vol.read_tier.clear()
        assert bytes(vol.read(lba, tenant="cold")) == _blk(7)
        charged = vol.metrics_snapshot()["wfq"]["vtime_charged"]
        assert charged["cold"] == 4096 + 4096
    finally:
        vol.close()


def test_volume_qos_threaded_smoke():
    vol = make_volume("caiti", n_lbas=1024, n_shards=2,
                      cache_bytes=32 * 4096,
                      tenants=[TenantSpec("a", weight=2.0),
                               TenantSpec("b", rate_mbps=200.0)])
    try:
        for i in range(64):
            vol.write(i, _blk(i), tenant="a")
            vol.write(512 + i, _blk(i), tenant="b")
        assert vol._gate.admitted_bytes["a"] == 64 * 4096
    finally:
        vol.close()


# ------------------------------------------------------- simulator claims
def _tenants(n, ops):
    return [{"name": f"t{j}", "n_ops": ops} for j in range(n)]


def test_sim_4shard_caiti_2x_single_device():
    """ACCEPTANCE: 4-shard Caiti volume sustains >= 2x the aggregate write
    throughput of single-device Caiti under a 4-tenant fio-like load."""
    kw = dict(n_lbas=262144, cache_slots=8192, n_workers=16,
              tenants=_tenants(4, 4000))
    r1 = run_volume_sim_workload("caiti", n_shards=1, **kw)
    r4 = run_volume_sim_workload("caiti", n_shards=4, **kw)
    assert r4["agg_mb_s"] >= 2.0 * r1["agg_mb_s"], \
        (r1["agg_mb_s"], r4["agg_mb_s"])


def test_sim_volume_caiti_beats_staging_baselines():
    kw = dict(n_shards=4, n_lbas=262144, cache_slots=4096, n_workers=16,
              tenants=_tenants(4, 3000))
    caiti = run_volume_sim_workload("caiti", **kw)["makespan_us"]
    for p in ("pmbd", "lru", "coactive"):
        assert caiti < run_volume_sim_workload(p, **kw)["makespan_us"], p


def test_sim_wfq_weights_divide_contended_throughput():
    tw = [{"name": "hi", "n_ops": 6000, "weight": 2.0, "jobs": 8},
          {"name": "lo", "n_ops": 6000, "weight": 1.0, "jobs": 8}]
    r = run_volume_sim_workload("caiti", n_shards=2, n_lbas=262144,
                                cache_slots=1024, tenants=tw,
                                qdepth=4, n_workers=4)
    hi = r["per_tenant"]["hi"]["contended_mb_s"]
    lo = r["per_tenant"]["lo"]["contended_mb_s"]
    assert 1.6 < hi / lo < 2.4, hi / lo


def test_sim_token_bucket_caps_tenant():
    ts = [{"name": "capped", "n_ops": 3000, "rate_mbps": 50.0},
          {"name": "free", "n_ops": 6000}]
    r = run_volume_sim_workload("caiti", n_shards=2, n_lbas=262144,
                                cache_slots=2048, tenants=ts)
    assert r["per_tenant"]["capped"]["mb_s"] <= 50.0 * 1.15
    assert r["per_tenant"]["free"]["mb_s"] > 500.0


def test_sim_read_tier_speedup_on_read_heavy_mix():
    """ACCEPTANCE: a >=90%-read zipfian volume workload with the read
    tier sustains >= 1.5x the throughput of the identical workload with
    the tier disabled (misses pay the contended PMem banks; tier hits
    are a DRAM copy)."""
    kw = dict(n_shards=2, n_lbas=16384, cache_slots=2048, n_workers=8,
              read_frac=0.90, lba_dist="zipf", zipf_theta=1.1,
              tenants=_tenants(4, 6000))
    off = run_volume_sim_workload("caiti", tier_slots=0, **kw)
    on = run_volume_sim_workload("caiti", tier_slots=8192, **kw)
    assert on["tier_hit_rate"] > 0.5, on["tier_hit_rate"]
    assert on["agg_mb_s"] >= 1.5 * off["agg_mb_s"], \
        (off["agg_mb_s"], on["agg_mb_s"], on["tier_hit_rate"])


def test_sim_degraded_reads_modeled():
    """Injected primary-verification failures cost a replica round trip
    (throughput drops) and are counted."""
    kw = dict(n_shards=2, n_lbas=16384, cache_slots=1024, n_workers=8,
              read_frac=0.95, lba_dist="zipf", tier_slots=2048,
              tenants=_tenants(2, 3000))
    ok = run_volume_sim_workload("caiti", **kw)
    dg = run_volume_sim_workload("caiti", degraded_every=10, **kw)
    assert ok["degraded_reads"] == 0
    assert dg["degraded_reads"] > 0
    assert dg["agg_mb_s"] < ok["agg_mb_s"]


def test_sim_logbatch_speedup_acceptance():
    """ACCEPTANCE: with >= 4 tenants issuing 4-block chained-tx logged
    writes, the batched log pipeline sustains >= 1.3x the
    logged-writes/s of per-call log() (each chain paying its own
    serialized slot-shard pass)."""
    kw = dict(n_shards=4, n_lbas=262144, cache_slots=4096, n_workers=16,
              log_blocks=4, tenants=_tenants(4, 1200))
    per = run_volume_sim_workload("caiti", log_window_us=0.0, **kw)
    bat = run_volume_sim_workload("caiti", log_window_us=50.0, **kw)

    def logged_s(r):
        return r["counts"]["log_calls"] / max(r["makespan_us"] / 1e6, 1e-9)

    assert bat["counts"]["log_coalesced"] > 0
    assert bat["counts"]["log_batches"] < per["counts"]["log_batches"]
    assert logged_s(bat) >= 1.3 * logged_s(per), \
        (logged_s(per), logged_s(bat))


def test_sim_fairness_mixed_tenants_within_20pct_of_weight_share():
    """ACCEPTANCE: under tier-aware WFQ, read-heavy (90% reads, DRAM-hot)
    and write-heavy tenants each receive a charged-service share within
    20% of their weight share in the contended window — and the
    read-heavy tenant moves MORE raw bytes for the same charged share
    (DRAM hits priced at tier_hit_cost_frac)."""
    ts = [{"name": "rheavy", "n_ops": 4000, "weight": 2.0, "jobs": 8,
           "read_frac": 0.90},
          {"name": "wheavy", "n_ops": 4000, "weight": 1.0, "jobs": 8,
           "read_frac": 0.0},
          {"name": "mixed", "n_ops": 4000, "weight": 1.0, "jobs": 8,
           "read_frac": 0.50}]
    r = run_volume_sim_workload("caiti", n_shards=2, n_lbas=16384,
                                cache_slots=1024, n_workers=4, qdepth=4,
                                tier_slots=8192, lba_dist="zipf",
                                zipf_theta=1.1, tenants=ts)
    for name, d in r["per_tenant"].items():
        err = abs(d["contended_charged_share"] / d["weight_share"] - 1.0)
        assert err <= 0.20, (name, d["contended_charged_share"],
                             d["weight_share"])
    # same weight, but DRAM-priced reads buy the mixed tenant more raw
    # throughput than the all-PMem writer
    pt = r["per_tenant"]
    assert pt["mixed"]["contended_mb_s"] > pt["wheavy"]["contended_mb_s"]
    assert r["tier_hit_rate"] > 0.3


def test_sim_watermark_increases_bypass():
    kw = dict(n_shards=4, n_lbas=262144, cache_slots=1024, n_workers=8,
              tenants=_tenants(4, 4000))
    low = run_volume_sim_workload("caiti", watermark=0.5, **kw)
    off = run_volume_sim_workload("caiti", watermark=1.0, **kw)
    assert low["bypass_rate"] > off["bypass_rate"]


# ------------------------------------------------------- ckpt integration
def test_sharded_blockstore_roundtrip(tmp_path):
    from repro.ckpt.blockstore import make_blockstore
    path = str(tmp_path / "store")
    st = make_blockstore(path, policy="caiti", capacity_bytes=16 << 20,
                         cache_bytes=4 << 20, n_shards=3)
    payload = np.random.default_rng(0).integers(
        0, 256, size=100_000, dtype=np.uint8).tobytes()
    st.put("x", payload)
    st.put("y", b"tiny")
    gen = st.commit()
    st.close()
    st2 = make_blockstore(path, policy="caiti", capacity_bytes=16 << 20,
                          cache_bytes=4 << 20, n_shards=3)
    assert st2.generation == gen
    assert st2.get("x") == payload
    assert st2.get("y") == b"tiny"
    st2.close()
