"""CI wiring guards: the benchmarks-smoke matrix must cover EVERY table
in the ``benchmarks/run.py`` registry (a new entry landing in no CI group
would silently lose its end-to-end smoke coverage — exactly the drift
the smoke job exists to catch), and the perf-floor gate must reference
tables that are really registered."""
import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _registry_tables() -> set[str]:
    with open(os.path.join(ROOT, "benchmarks", "run.py")) as f:
        src = f.read()
    tables = set(re.findall(r'^        "([a-z0-9_]+)": \(', src, re.M))
    assert tables, "failed to parse the benchmark registry out of run.py"
    return tables


def _ci_smoke_tables() -> set[str]:
    with open(os.path.join(ROOT, ".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    groups = re.findall(r"tables: ([a-z0-9_,]+)", ci)
    assert groups, "failed to parse the benchmarks-smoke matrix out of ci.yml"
    return {t for g in groups for t in g.split(",") if t}


def test_smoke_matrix_covers_every_registered_table():
    registered = _registry_tables()
    covered = _ci_smoke_tables()
    assert covered == registered, (
        f"benchmarks-smoke matrix drift: "
        f"missing {sorted(registered - covered)}, "
        f"stale {sorted(covered - registered)}")


def test_floor_gate_references_registered_tables():
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "check_floors", os.path.join(ROOT, "benchmarks", "check_floors.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    registered = _registry_tables()
    assert set(mod.FLOORS) <= registered, \
        sorted(set(mod.FLOORS) - registered)

    # a scalar floor bounds entry["speedup"]; a dict floor bounds each of
    # its keys; a per-key {"min"/"max"} spec picks the direction — fold
    # every shape down to (bar, is_ceiling) the way check() does
    def _norm(spec):
        if isinstance(spec, dict):
            return (float(spec["max"]), True) if "max" in spec \
                else (float(spec["min"]), False)
        return float(spec), False

    keyed = {t: {k: _norm(s) for k, s in
                 (f if isinstance(f, dict) else {"speedup": f}).items()}
             for t, f in mod.FLOORS.items()}
    n_bars = sum(len(k) for k in keyed.values())
    # at least one latency-style ceiling must be registered (hedged p99)
    assert any(ceil for ks in keyed.values() for _b, ceil in ks.values())

    def _vals(ks, passing):
        # direction-aware: a passing value sits on the good side of the
        # bar (below a ceiling, above a floor), a failing one opposite
        return {k: bar * ((0.5 if ceil else 2.0) if passing
                          else (2.0 if ceil else 0.5))
                for k, (bar, ceil) in ks.items()}

    # the gate fails (not passes) when a floored table goes missing
    problems = mod.check({}, allow_missing=False)
    assert len(problems) == len(mod.FLOORS)
    assert mod.check({}, allow_missing=True) == []
    assert mod.check({t: _vals(ks, True) for t, ks in keyed.items()}) == []
    bad = mod.check({t: _vals(ks, False) for t, ks in keyed.items()})
    assert len(bad) == n_bars
    # a dict-floored table missing ONE of its keys is a loud failure
    dict_tables = [t for t, f in mod.FLOORS.items() if isinstance(f, dict)]
    assert dict_tables, "expected at least one multi-key floor"
    t0 = dict_tables[0]
    partial = {t: _vals(ks, True) for t, ks in keyed.items()}
    partial[t0] = dict(list(partial[t0].items())[:-1])
    assert len(mod.check(partial)) == 1


def _load_check_floors():
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "check_floors", os.path.join(ROOT, "benchmarks", "check_floors.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_every_table_floored_or_waived():
    """Adding a bench table forces a conscious gating decision: every
    registry entry must carry a perf floor in FLOORS or an explicit
    reasoned waiver in WAIVERS — and never both."""
    mod = _load_check_floors()
    registered = _registry_tables()
    floors, waivers = set(mod.FLOORS), set(mod.WAIVERS)
    assert floors & waivers == set(), \
        f"tables both floored and waived: {sorted(floors & waivers)}"
    assert floors | waivers == registered, (
        f"ungated tables (add a floor or a waiver): "
        f"{sorted(registered - floors - waivers)}; "
        f"stale entries: {sorted((floors | waivers) - registered)}")
    # a waiver is a DECISION, not a placeholder — it must say why
    for table, reason in mod.WAIVERS.items():
        assert isinstance(reason, str) and len(reason) >= 10, \
            f"waiver for {table!r} has no real justification"


def test_floor_gate_group_contains_every_floored_table():
    """ci.yml runs check_floors on the 'volume' smoke group's artifact
    only; a floored table landing in another group would make the gate
    see it as missing (or worse, never gate it at all)."""
    mod = _load_check_floors()
    with open(os.path.join(ROOT, ".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    groups = dict(re.findall(
        r"group: ([a-z0-9_]+)\n\s+tables: ([a-z0-9_,]+)", ci))
    assert "volume" in groups, "floor-gate group renamed without updating"
    gate_tables = set(groups["volume"].split(","))
    assert set(mod.FLOORS) <= gate_tables, (
        f"floored tables outside the gated smoke group: "
        f"{sorted(set(mod.FLOORS) - gate_tables)}")


def test_nightly_workflow_runs_full_registry():
    """The scheduled nightly job must stay a FULL-registry run: a cron
    trigger, fast (non-smoke) op counts with no --only narrowing, the
    floor gate, and the BENCH_nightly.json artifact with provenance."""
    path = os.path.join(ROOT, ".github", "workflows", "nightly.yml")
    assert os.path.exists(path), "nightly benchmark workflow missing"
    with open(path) as f:
        wf = f.read()
    assert "schedule:" in wf and re.search(r"cron: ", wf), \
        "nightly workflow lost its cron schedule"
    assert "workflow_dispatch:" in wf, \
        "nightly workflow must stay manually triggerable"
    run_lines = [ln for ln in wf.splitlines()
                 if "python -m benchmarks.run" in ln]
    assert len(run_lines) == 1
    assert "--fast" in run_lines[0] and "--smoke" not in run_lines[0] \
        and "--only" not in run_lines[0], \
        "nightly must run the FULL registry at --fast op counts"
    assert "--json BENCH_nightly.json" in run_lines[0]
    assert "check_floors.py BENCH_nightly.json" in wf, \
        "nightly artifact is not floor-gated"
    assert "path: BENCH_nightly.json" in wf, \
        "nightly artifact upload missing"
    assert "requirements-ci.txt" in wf, \
        "nightly pip cache must key on the dependency manifest"


def test_ci_hygiene_concurrency_cache_and_lint():
    """PR pushes cancel superseded runs; every pip cache keys on the
    dependency manifest (not the workflow file); the ruff step runs the
    full default rule set (policy lives in ruff.toml, not --select)."""
    with open(os.path.join(ROOT, ".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    assert "concurrency:" in ci and "cancel-in-progress:" in ci, \
        "ci.yml lost its superseded-run cancellation"
    assert "github.event_name == 'pull_request'" in ci, \
        "cancellation must apply to PR pushes only (main keeps history)"
    deps = re.findall(r"cache-dependency-path: (\S+)", ci)
    assert deps and all(d == "requirements-ci.txt" for d in deps), \
        f"pip caches must key on requirements-ci.txt, got {deps}"
    assert os.path.exists(os.path.join(ROOT, "requirements-ci.txt"))
    assert os.path.exists(os.path.join(ROOT, "ruff.toml")), \
        "lint policy file missing"
    ruff_lines = [ln for ln in ci.splitlines() if "ruff check" in ln]
    assert ruff_lines and all("--select" not in ln for ln in ruff_lines), \
        "ruff must run the full default rule set (no --select narrowing)"


def test_artifact_meta_gate():
    """``run.py --json`` artifacts embed seed + registry fingerprint;
    ``check_floors.check_meta`` must accept the CURRENT registry's own
    meta, reject a stale fingerprint or a foreign seed, and tolerate
    pre-provenance artifacts (no _meta) with a warning only."""
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "check_floors", os.path.join(ROOT, "benchmarks", "check_floors.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    spec_r = importlib.util.spec_from_file_location(
        "benchrun", os.path.join(ROOT, "benchmarks", "run.py"))
    bench_run = importlib.util.module_from_spec(spec_r)
    spec_r.loader.exec_module(bench_run)
    import sys
    sys.modules["run"] = bench_run      # what check_meta imports
    try:
        current = bench_run.registry_version(
            bench_run._registry(1, fast=True, smoke=True))
        good = {"_meta": {"seed": bench_run.SEED,
                          "registry_version": current, "mode": "smoke"}}
        assert mod.check_meta(good) == []
        stale = {"_meta": {"seed": bench_run.SEED,
                           "registry_version": "deadbeef0000",
                           "mode": "smoke"}}
        assert len(mod.check_meta(stale)) == 1
        foreign = {"_meta": {"seed": 7, "registry_version": current,
                             "mode": "smoke"}}
        assert len(mod.check_meta(foreign)) == 1
        assert mod.check_meta({}) == []          # pre-provenance artifact
    finally:
        del sys.modules["run"]
    # the fingerprint is over the table SET — order-insensitive, and
    # any membership change moves it
    v1 = bench_run.registry_version(["a", "b"])
    assert v1 == bench_run.registry_version(["b", "a"])
    assert v1 != bench_run.registry_version(["a", "b", "c"])
