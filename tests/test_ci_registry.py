"""CI wiring guards: the benchmarks-smoke matrix must cover EVERY table
in the ``benchmarks/run.py`` registry (a new entry landing in no CI group
would silently lose its end-to-end smoke coverage — exactly the drift
the smoke job exists to catch), and the perf-floor gate must reference
tables that are really registered."""
import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _registry_tables() -> set[str]:
    with open(os.path.join(ROOT, "benchmarks", "run.py")) as f:
        src = f.read()
    tables = set(re.findall(r'^        "([a-z0-9_]+)": \(', src, re.M))
    assert tables, "failed to parse the benchmark registry out of run.py"
    return tables


def _ci_smoke_tables() -> set[str]:
    with open(os.path.join(ROOT, ".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    groups = re.findall(r"tables: ([a-z0-9_,]+)", ci)
    assert groups, "failed to parse the benchmarks-smoke matrix out of ci.yml"
    return {t for g in groups for t in g.split(",") if t}


def test_smoke_matrix_covers_every_registered_table():
    registered = _registry_tables()
    covered = _ci_smoke_tables()
    assert covered == registered, (
        f"benchmarks-smoke matrix drift: "
        f"missing {sorted(registered - covered)}, "
        f"stale {sorted(covered - registered)}")


def test_floor_gate_references_registered_tables():
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "check_floors", os.path.join(ROOT, "benchmarks", "check_floors.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    registered = _registry_tables()
    assert set(mod.FLOORS) <= registered, \
        sorted(set(mod.FLOORS) - registered)
    # the gate fails (not passes) when a floored table goes missing
    problems = mod.check({}, allow_missing=False)
    assert len(problems) == len(mod.FLOORS)
    assert mod.check({}, allow_missing=True) == []
    assert mod.check({t: {"speedup": 2.0} for t in mod.FLOORS}) == []
    bad = mod.check({t: {"speedup": 0.8} for t in mod.FLOORS})
    assert len(bad) == len(mod.FLOORS)
