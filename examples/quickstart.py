"""Quickstart: the paper's device stack in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. builds a Caiti-cached PMem block device (threaded implementation),
   writes/reads/fsyncs through the bio interface;
2. runs the calibrated virtual-time simulator to reproduce the paper's
   headline contrast (BTT vs staging caches vs Caiti);
3. shows the same algorithm as a checkpoint transit buffer.
"""
import numpy as np

from repro.core import fsync_bio, make_device
from repro.core.sim import run_sim_workload

# -- 1. a real (threaded) Caiti device -------------------------------------
dev = make_device("caiti", n_lbas=4096, cache_bytes=1 << 20)
block = bytes(np.random.default_rng(0).integers(0, 256, 4096, np.uint8))
for lba in range(256):
    dev.write(lba, block)
dev.submit_bio(fsync_bio())                     # PREFLUSH|FUA drain
assert bytes(dev.read(17)) == block
print(f"[device] 256 writes + fsync done; cache occupancy now "
      f"{dev.occupancy():.2f}; background evictions "
      f"{dev.metrics.count.get('bg_evictions', 0)}")
dev.close()

# -- 2. the paper's contrast in virtual time --------------------------------
print("\n[sim] uniform 4K random writes, iodepth 32 (virtual time):")
base = {}
for policy in ("raw", "dax", "btt", "pmbd", "lru", "coactive", "caiti"):
    m = run_sim_workload(policy, n_ops=20_000, n_lbas=262_144,
                         cache_slots=4_096, iodepth=32)
    base[policy] = m.counts["makespan_us"] / 1e6
    print(f"  {policy:10s} {base[policy]:7.3f}s  mean {m.mean():7.2f}us  "
          f"p99.99 {m.pct(99.99):9.1f}us")
print(f"  -> caiti is {base['btt'] / base['caiti']:.2f}x faster than BTT "
      f"(paper: up to 3.6x)")

# -- 3. Caiti as a transit buffer for arbitrary sinks ------------------------
from repro.core import TransitBuffer

stored = []
tb = TransitBuffer(stored.append, capacity_bytes=1 << 20, n_workers=2)
for i in range(100):
    tb.put(f"chunk{i}", nbytes=8 << 10)        # eagerly evicted to the sink
tb.flush()                                      # the fsync analogue
print(f"\n[transit] 100 chunks staged -> {len(stored)} sunk; "
      f"flush found {tb.staged_bytes()} bytes left (eager eviction)")
tb.close()
