"""Distributed cluster volume quickstart: 3 nodes, K=2 chain replication.

    PYTHONPATH=src python examples/cluster_quickstart.py

1. put — chain-replicated writes over virtual-time NetLinks: every chunk
   of the cluster LBA space maps to an ordered chain of K nodes
   (rack-aware spread placement); a write is acknowledged only once ALL
   K members hold it durably, whole-object-atomic end to end via each
   node's chained-tx journal.
2. kill — fail-stop one member mid-cluster.  Reads walk the chain past
   the dead member and keep serving crc-verified data (degraded reads);
   writes whose chains include the corpse fail THEIR op only.
3. restore — the heartbeat monitor declares the silent node dead after
   the timeout and the ReReplicator regenerates every lost block onto a
   survivor, restoring K live copies (scrub shows nothing
   under-replicated).
"""
from repro.cluster import NodeDownError, make_cluster


def blk(x):
    return bytes([x % 256]) * 4096


class Clock:
    """Manual clock so heartbeat timeouts are deterministic here."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


clock = Clock()
cl = make_cluster("caiti", n_lbas=4096, n_nodes=3, replication_k=2,
                  chunk_blocks=64, racks=2, placement="spread",
                  heartbeat_timeout=5.0, now_fn=clock)

# -- 1. put ------------------------------------------------------------------
for obj in range(8):
    cl.write_multi(obj * 64, [blk(obj * 16 + i) for i in range(16)])
cl.fsync()
snap = cl.metrics_snapshot()
print(f"[put] {snap['acked_blocks']} blocks acked on "
      f"{snap['chunks_mapped']} chunks; chains:")
for chunk in sorted(cl._chains):
    names = [cl.nodes[ni].name for ni in cl._chains[chunk]]
    print(f"      chunk {chunk}: {' -> '.join(names)}")

# -- 2. kill -----------------------------------------------------------------
victim = cl._chains[0][0]                     # chunk 0's chain primary
cl.kill_node(victim)
print(f"[kill] {cl.nodes[victim].name} is gone")
ok = sum(1 for obj in range(8) for i in range(16)
         if bytes(cl.read(obj * 64 + i)) == blk(obj * 16 + i))
snap = cl.metrics_snapshot()
print(f"[kill] all {ok}/128 blocks still readable "
      f"({snap.get('read_failovers', 0)} chain failovers, "
      f"{snap.get('degraded_reads', 0)} degraded reads)")
try:
    cl.write(0, blk(99))
except NodeDownError as e:
    print(f"[kill] write through the dead primary fails its op only: {e}")
scrub = cl.scrub()
print(f"[kill] scrub: {len(scrub['under_replicated'])} chunks "
      f"under-replicated")

# -- 3. restore --------------------------------------------------------------
clock.t = 10.0                                # sail past the 5s timeout
st = cl.rereplicator.run_once()
print(f"[restore] heartbeat declared dead: "
      f"{[cl.nodes[ni].name for ni in st['declared_dead']]}; "
      f"re-replicated {st['chunks_repaired']} chunks "
      f"({st['blocks_copied']} blocks) onto survivors")
scrub = cl.scrub()
assert scrub["under_replicated"] == []
print(f"[restore] scrub: 0 under-replicated, "
      f"{scrub['divergent_blocks']} divergent")
cl.write(0, blk(99))                          # repaired chain takes writes
assert bytes(cl.read(0)) == blk(99)
ok = sum(1 for obj in range(1, 8) for i in range(16)
         if bytes(cl.read(obj * 64 + i)) == blk(obj * 16 + i))
print(f"[restore] repaired chain serving writes again; "
      f"{ok}/112 untouched blocks intact")
cl.close()
