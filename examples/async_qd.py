"""Async submission/completion frontend quickstart + queue-depth sweep.

    PYTHONPATH=src python examples/async_qd.py

1. submit/poll against a real threaded volume: overlapped writes, an
   async read, a failed ticket (journal-ring overflow) that does NOT
   tear down the ring, and an async fsync barrier.
2. The zero-copy data plane: a registered buffer pool (pinned payloads
   instead of staging copies) driving a linked write -> fsync ->
   read-back-verify chain — three ops sequenced in-engine by IO_LINK,
   one wait instead of a poll round-trip per dependency.
3. The paper-scale contrast in virtual time: ops/s at queue depth
   1 (what a blocking frontend gets) vs 2/4/8/16 — submission batching
   amortizes the per-op stack cost and submitted ops overlap across the
   engine cores and shard DIMM banks.
"""
import numpy as np

from repro.core.sim import run_aio_sim_workload
from repro.volume import make_volume


def blk(x):
    return bytes([x % 256]) * 4096


# -- 1. real threaded engine -------------------------------------------------
vol = make_volume("caiti", n_lbas=65536, n_shards=4, cache_bytes=16 << 20)
# size the submit-side window up front (the default rides
# cfg.max_inflight; a submit over the bound fails ITS ticket — never
# blocks, never deadlocks the ring)
vol.aio_engine(n_workers=2, max_inflight_per_tenant=128)
rng = np.random.default_rng(0)
tickets = [vol.submit("write", int(lba), data=blk(int(lba)))
           for lba in rng.integers(0, 65536, size=64)]
tickets.append(vol.submit("write_multi", 70_000 % 65536,
                          blocks=[blk(i) for i in range(8)]))
bad = vol.submit("write_multi", 0, blocks=[blk(i) for i in range(4096)])
rd = vol.submit("read", int(tickets[0].lba))
sync = vol.submit("fsync")                   # barrier: runs after the rest
vol.wait(sync)
done = vol.poll()
ok = sum(1 for t in done if t.ok)
print(f"[aio] {len(done)} completions polled, {ok} ok; "
      f"oversized chain failed ITS ticket only: {type(bad.error).__name__}")
print(f"[aio] async read value matches: "
      f"{bytes(rd.value) == blk(int(tickets[0].lba))}")
print(f"[aio] engine stats: {vol.metrics_snapshot()['aio']}")

# -- 2. zero-copy pool + linked write -> fsync -> read-verify chain ----------
reg = vol.register_buffers(8)                # io_uring register_buffers
buf = reg.acquire()                          # pinned, not copied
buf.data[:] = 0xA5
w = vol.submit("write", 123, data=buf)       # head of the chain
f = vol.submit("fsync", link_to=w)           # runs only after w succeeds
verify = np.zeros(vol.block_size, np.uint8)  # read lands HERE, no copy
r = vol.submit("read", 123, link_to=f, out=verify)
vol.wait(r)                                  # ONE wait settles the chain
print(f"[link] write->fsync->read chain ok={w.ok and f.ok and r.ok}; "
      f"read-back verified: {bool((verify == 0xA5).all())}")
zc = vol.scrub()["zerocopy"]
print(f"[link] zerocopy: copies_avoided={zc['copies_avoided']} "
      f"bytes_pinned={zc['bytes_pinned']} "
      f"links={zc['links_submitted']} depth={zc['link_depth_max']} "
      f"pool={zc['registry']}")
vol.close()

# -- 3. queue-depth sweep (virtual time, deterministic) ----------------------
print("\n[sim] qd sweep: 4 shards, 4 tenants, uniform 4K writes")
tenants = [{"name": f"t{j}", "n_ops": 4000} for j in range(4)]
base = None
for qd in (1, 2, 4, 8, 16):
    r = run_aio_sim_workload("caiti", n_shards=4, n_lbas=262144,
                             cache_slots=8192, n_workers=16, qdepth=qd,
                             tenants=tenants)
    base = base or r["ops_s"]
    print(f"  qd={qd:<3d} ops/s={r['ops_s']:12.0f}  "
          f"agg={r['agg_mb_s']:8.1f} MB/s  "
          f"({r['ops_s'] / base:.2f}x vs qd=1)")
print("-> depth 8 is the acceptance point: >= 1.5x over depth 1")
