"""Crash-recovery walkthrough: block-level write atomicity end to end.

    PYTHONPATH=src python examples/crash_recovery.py

1. BTT layer: a power cut mid data-copy leaves a torn block in the lane's
   free block — the committed map still points at the OLD block, so the
   read after Flog replay returns the complete old data.
2. Store layer: a crash between data writes and the root-block flip leaves
   the previous checkpoint generation intact (atomic commit).
"""
import os
import tempfile

import numpy as np

from repro.ckpt import CheckpointEngine, make_blockstore
from repro.core import BTT, PMemSpace, SimulatedCrash


def blk(x):
    return bytes([x]) * 4096


# -- 1. torn write at the BTT layer -----------------------------------------
pmem = PMemSpace(128)
btt = BTT(pmem, n_lbas=64, nfree=2)
btt.write(7, blk(1))
print("[btt] lba7 committed with pattern 0x01")

state = {"arm": True}


def power_cut(label):
    if label == "pmem_write_mid" and state["arm"]:
        state["arm"] = False
        raise SimulatedCrash(label)


pmem.crash_hook = power_cut
try:
    btt.write(7, blk(2))
except SimulatedCrash:
    print("[btt] power cut mid-copy of the overwrite (block is TORN in the "
          "free block)")
pmem.crash_hook = None

btt2 = BTT(pmem, n_lbas=64, fresh=False)          # reboot: Flog replay
data = bytes(btt2.read(7))
assert data == blk(1), "old data must be intact"
print(f"[btt] after recovery ({btt2.recovery_stats}): lba7 reads pattern "
      f"0x{data[0]:02x} — the old, COMPLETE block. No torn state visible.")

# -- 2. atomic checkpoint generations ---------------------------------------
with tempfile.TemporaryDirectory() as td:
    pool = os.path.join(td, "pool.bin")
    s1 = {"w": np.arange(4096, dtype=np.float32)}
    store = make_blockstore(pool, policy="caiti", capacity_bytes=64 << 20)
    eng = CheckpointEngine(store)
    eng.save(0, s1)
    print("[store] generation for step0 committed")
    # stage step1 but 'crash' before commit
    store.put("step%010d/w/0" % 1, (s1["w"] * 9).tobytes())
    del eng, store                                  # no commit, no close
    store2 = make_blockstore(pool, policy="caiti", capacity_bytes=64 << 20)
    eng2 = CheckpointEngine(store2)
    got, step = eng2.restore(like=s1)
    assert step == 0 and np.array_equal(np.asarray(got["w"]), s1["w"])
    print(f"[store] after crash+reopen: latest committed step = {step}, "
          f"restored bit-exact; the half-written step1 is invisible.")
    eng2.close()

print("\nblock-level write atomicity holds at every layer.")
