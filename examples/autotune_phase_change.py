"""Self-tuning control plane quickstart: frozen knobs vs a live
Controller across a workload phase change.

    PYTHONPATH=src python examples/autotune_phase_change.py

1. replays a two-phase trace (YCSB-A with heavy fsync pressure, then a
   zipf read-only YCSB-C) on the virtual-time volume sim, once with the
   knobs frozen at their defaults and once with the feedback controller
   retuning them online — and prints the throughput/latency contrast
   plus every knob move the controller applied;
2. attaches the SAME controller class to a real threaded volume
   (``make_volume(autotune=True)``) and drives one control tick.
"""
from repro.core.sim import run_autotune_sim_workload
from repro.volume import make_default_controller, make_volume

PHASES = [
    {"name": "ycsb_a",                      # 50/50, fsync every 4 ops
     "tenants": [{"name": f"t{j}", "n_ops": 1500, "jobs": 2,
                  "read_frac": 0.5, "fsync_every": 4} for j in range(4)]},
    {"name": "ycsb_c", "lba_dist": "zipf",  # read-only hot set
     "tenants": [{"name": f"t{j}", "n_ops": 1500, "jobs": 2,
                  "read_frac": 1.0} for j in range(4)]},
]

# -- 1. tuned vs frozen on the same trace (virtual time) --------------------
frozen = run_autotune_sim_workload("caiti", phases=PHASES, autotune=None)
ctl = make_default_controller(slos={"*": {"p99_us": 50_000.0}})
tuned = run_autotune_sim_workload("caiti", phases=PHASES, autotune=ctl)

print("[sim] phase-change trace, 4 tenants x 2 streams:")
for label, r in (("frozen", frozen), ("tuned", tuned)):
    print(f"  {label:6s} {r['ops_s']:10.0f} ops/s  p99 {r['p99_us']:8.1f}us")
print(f"  -> tuned/frozen: {tuned['ops_s'] / frozen['ops_s']:.2f}x ops/s, "
      f"{tuned['p99_us'] / frozen['p99_us']:.2f}x p99")
print("  knob moves (virtual time):")
for t_us, changes in tuned["knob_trace"]:
    for name, v in changes.items():
        lo, hi = ctl.clamp_range(name)
        print(f"    t={t_us:9.0f}us  {name:18s} -> {v:7.1f}  "
              f"(clamps [{lo:g}, {hi:g}])")
print(f"  final knobs: {tuned['knob_final']}")

# -- 2. the same controller on the real threaded volume ---------------------
vol = make_volume("caiti", n_lbas=4096, n_shards=2, cache_bytes=2 << 20,
                  shared_workers=2, autotune=True)
try:
    blk = b"\xab" * vol.cfg.block_size
    for i in range(200):
        vol.write(i % 256, blk)
        if i % 4 == 0:
            vol.fsync()
    moves = vol.autotune_step()                  # one live control tick
    snap = vol.metrics_snapshot()["autotune"]
    print(f"\n[real] one control tick on the threaded volume: "
          f"moves={moves or '{} (hysteresis gathering)'}")
    print(f"       ticks={snap['ticks']} total_moves={snap['total_moves']} "
          f"commit_window={vol.cfg.commit_window * 1e6:.0f}us")
finally:
    vol.close()
