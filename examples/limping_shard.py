"""Fail-slow ("limplock") quickstart: one limping shard, hedged reads.

    PYTHONPATH=src python examples/limping_shard.py

A limping device is the failure replication can't see: 10-100x slow,
never erroring, never missing a heartbeat — mean throughput looks fine
(only 1/n_shards of uniform reads land on it) while p99 collapses to
the limping device's service time.

1. sim — the acceptance contrast in virtual time: a 4-shard volume with
   one 25x limping shard, unhedged vs hedged.  The hedge fires the
   replica leg after ~3x a healthy read and takes the first completion;
   p99 drops back to healthy territory at no throughput cost (the same
   contrast CI gates with the `volume_hedge` lower-is-better floor).
2. threaded — the real async engine: stall one shard's read path,
   `hedged_read` escapes through the replica while the loser is
   cancelled (pinned buffers released, counters balance).
3. scoring + steering — per-shard p50/p99 digests classify the shard
   `limping`; `scrub()["tail"]` surfaces the verdicts, the auto hedge
   delay, and the `hedges_fired == hedges_won + hedges_cancelled`
   balance; the same pass prices limping shards up in WFQ and steers
   eviction drains away from them.
"""
import time

from repro.core.sim import run_hedge_sim_workload
from repro.volume import make_volume


def blk(x):
    return bytes([x % 256]) * 4096


# -- 1. sim: hedged vs unhedged under one 25x limping shard ------------------
kw = dict(n_lbas=65536, n_ops=4000, n_shards=4, slow_shard=0,
          slow_factor=25.0)
un = run_hedge_sim_workload("btt", hedge=False, **kw)
he = run_hedge_sim_workload("btt", hedge=True, **kw)
print(f"[sim] unhedged: p50 {un['p50_us']:6.2f}us  p99 {un['p99_us']:6.2f}us"
      f"  ({un['ops_s'] / 1e3:.0f}k ops/s)  <- p99 limping, mean fine")
print(f"[sim]   hedged: p50 {he['p50_us']:6.2f}us  p99 {he['p99_us']:6.2f}us"
      f"  ({he['ops_s'] / 1e3:.0f}k ops/s)")
c = he["counts"]
print(f"[sim] p99 {un['p99_us'] / he['p99_us']:.1f}x better; hedges: "
      f"{c.get('hedges_fired', 0)} fired = {c.get('hedges_won', 0)} won + "
      f"{c.get('hedges_cancelled', 0)} cancelled")

# -- 2. threaded: escape a stalled shard through the replica leg -------------
vol = make_volume("btt", n_lbas=256, n_shards=2, replicas=2,
                  stripe_blocks=1, aio_workers=2)
for i in range(16):
    vol.write(i, blk(i))

shard0 = vol.shards[0].impl
_attr = "read_ex" if hasattr(shard0, "read_ex") else "read"
orig_read = getattr(shard0, _attr)


def limping_read(local, out=None, **kwargs):
    time.sleep(0.02)                       # 20 ms stall, no error
    return orig_read(local, out=out, **kwargs)


setattr(shard0, _attr, limping_read)
lba = next(i for i in range(16) if vol._map(i, 0)[0] == 0)
t0 = time.perf_counter()
data = vol.hedged_read(lba, delay_s=0.002)
dt = (time.perf_counter() - t0) * 1e3
assert bytes(data) == blk(lba)
print(f"[hedge] read of lba {lba} (primary on the stalled shard) served "
      f"in {dt:.1f}ms vs the 20ms stall")

# warm the digests while the shard limps so the scorer can classify
# (min_samples per member); shard 0's p50/p99 sit at the stall, shard
# 1's at healthy service time
for i in range(16):
    vol.read(i)
setattr(shard0, _attr, orig_read)

# -- 3. scoring + steering ---------------------------------------------------
tail = vol.scrub()["tail"]
print(f"[score] verdicts: {tail['states']}  "
      f"(auto hedge delay {tail['hedge_delay_us']:.0f}us)")
assert tail["states"]["shard0"] in ("limping", "dead")
# (on a noisy box the HEALTHY shard can also read "limping" — wall-time
# p99 vs peer-median p50 is jitter-sensitive at microsecond scale; the
# virtual-time sim above is the deterministic contrast)
for name, row in sorted(tail["shards"].items()):
    print(f"[score]   {name}: n={row['n']}  p50 {row['p50_us']:9.1f}us  "
          f"p99 {row['p99_us']:9.1f}us")
assert tail["hedges_fired"] == tail["hedges_won"] + tail["hedges_cancelled"]
print(f"[score] hedge balance holds: {tail['hedges_fired']} fired = "
      f"{tail['hedges_won']} won + {tail['hedges_cancelled']} cancelled "
      f"({tail['primaries_cancelled']} primaries recalled)")
vol.close()
