"""Multi-tenant striped volume walkthrough.

    PYTHONPATH=src python examples/multi_tenant_volume.py

1. Build a 4-shard Caiti volume (shared eviction pool, global bypass
   watermark) and serve three QoS-tiered tenants concurrently.
2. Crash it mid multi-shard write and reopen: per-shard Flog replay plus
   volume-journal replay make the torn write invisible-or-whole.
3. Virtual-time contrast: the same topology in the discrete-event
   simulator, where the >= 2x single-device speedup is measurable.
"""
import os
import tempfile
import threading

import numpy as np

from repro.core import SimulatedCrash
from repro.core.sim import run_volume_sim_workload
from repro.volume import TenantSpec, make_volume


def blk(x):
    return bytes([x % 256]) * 4096


# -- 1. three tenants on one volume -----------------------------------------
vol = make_volume("caiti", n_lbas=65536, n_shards=4, cache_bytes=16 << 20,
                  tenants=[TenantSpec("gold", weight=4.0),
                           TenantSpec("silver", weight=2.0),
                           TenantSpec("bronze", weight=1.0,
                                      rate_mbps=200.0)])


def client(name, base):
    rng = np.random.default_rng(base)
    for lba in rng.integers(0, 65536, size=400):
        vol.write(int(lba), blk(base), tenant=name)


threads = [threading.Thread(target=client, args=(n, i * 7 + 1))
           for i, n in enumerate(("gold", "silver", "bronze"))]
for t in threads:
    t.start()
for t in threads:
    t.join()
vol.fsync()
snap = vol.metrics_snapshot()
print(f"[qos] 3 tenants, 1200 writes: bg_evictions={snap['bg_evictions']} "
      f"bypass={snap['bypass_writes']} "
      f"admitted={ {k: v // 4096 for k, v in vol._gate.admitted_bytes.items()} }")
vol.close()

# -- 2. crash mid multi-shard write, then recover ---------------------------
tmp = tempfile.mkdtemp()
path = os.path.join(tmp, "vol")
vol = make_volume("btt", n_lbas=4096, n_shards=4, stripe_blocks=1,
                  backend="file", path=path)
vol.write_multi(40, [blk(1)] * 4)                 # committed baseline
vol.fsync()

armed = {"on": True}


def power_cut(label):
    if label == "pmem_write_begin" and armed["on"]:
        armed["on"] = False
        raise SimulatedCrash(label)


shard, _ = vol._map(41, 0)                        # cut power on block 2's shard
vol.shards[shard].impl.btt.pmem.crash_hook = power_cut
try:
    vol.write_multi(40, [blk(9)] * 4)             # torn: block 1 lands, 2 dies
except SimulatedCrash:
    print("[crash] power lost mid multi-shard write (after journal commit)")
for d in vol.shards:
    d.impl.btt.pmem.crash_hook = None

vol2 = make_volume("btt", n_lbas=4096, n_shards=4, stripe_blocks=1,
                   backend="file", path=path)
got = {bytes(vol2.read(40 + i))[0] for i in range(4)}
print(f"[recover] replayed_txs={vol2.recovery_stats['replayed_txs']} "
      f"-> all 4 blocks read pattern {got} (whole, never torn)")
assert got == {9}
vol2.close()

# -- 3. virtual-time scaling contrast ---------------------------------------
tenants = [{"name": f"t{j}", "n_ops": 4000} for j in range(4)]
r1 = run_volume_sim_workload("caiti", n_shards=1, n_lbas=262144,
                             cache_slots=8192, n_workers=16, tenants=tenants)
r4 = run_volume_sim_workload("caiti", n_shards=4, n_lbas=262144,
                             cache_slots=8192, n_workers=16, tenants=tenants)
print(f"[sim] caiti aggregate write throughput: 1 shard "
      f"{r1['agg_mb_s']:.0f} MB/s -> 4 shards {r4['agg_mb_s']:.0f} MB/s "
      f"({r4['agg_mb_s'] / r1['agg_mb_s']:.2f}x)")
