"""End-to-end training driver: a small LM for a few hundred steps with
the full production substrate — deterministic data pipeline, AdamW,
Caiti-backed async checkpointing, watchdog, and crash/resume.

    PYTHONPATH=src python examples/train_e2e.py --steps 300
    PYTHONPATH=src python examples/train_e2e.py --steps 300 --resume  # again

(the 8M default keeps a few hundred steps tractable on the 1-core
container; --preset 25m/100m scale up for real hardware.)
"""
import argparse
import os
import time

import jax

from repro.ckpt import CheckpointEngine, make_blockstore
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import build_model
from repro.optim import AdamW
from repro.train.loop import TrainConfig, Trainer

PRESETS = {
    # name: (layers, d_model, heads, kv, d_ff, vocab, seq, batch)
    "8m":   (4, 256, 8, 4, 1024, 8192, 128, 8),
    "25m":  (6, 384, 8, 4, 1536, 12288, 128, 8),
    "100m": (12, 512, 8, 4, 2048, 32768, 256, 8),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--preset", default="8m", choices=list(PRESETS))
    ap.add_argument("--ckpt", default="/tmp/repro_e2e.pool")
    ap.add_argument("--fresh", action="store_true",
                    help="delete the pool and start over")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    L, d, H, kv, ff, V, seq, batch = PRESETS[args.preset]
    cfg = get_config("internlm2-1.8b", smoke=True).with_(
        name=f"lm-{args.preset}", n_layers=L, d_model=d, n_heads=H,
        n_kv_heads=kv, d_ff=ff, vocab=V)
    model = build_model(cfg)
    print(f"[e2e] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"seq {seq}, batch {batch}, steps {args.steps}")

    if args.fresh and os.path.exists(args.ckpt):
        os.unlink(args.ckpt)
    store = make_blockstore(args.ckpt, policy="caiti",
                            capacity_bytes=2 << 30)
    ckpt = CheckpointEngine(store, keep=2)
    if ckpt.latest_step() is not None:
        print(f"[e2e] found checkpoint @ step {ckpt.latest_step()} "
              f"-> resuming")

    opt = AdamW(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    source = SyntheticLM(cfg.vocab, seq, batch)
    trainer = Trainer(model, opt, source, ckpt=ckpt,
                      cfg=TrainConfig(total_steps=args.steps,
                                      ckpt_every=50, async_ckpt=True))
    t0 = time.time()
    out = trainer.run(jax.random.PRNGKey(0))
    dt = time.time() - t0
    n = len(out["losses"])
    print(f"[e2e] {n} steps in {dt:.1f}s ({dt/max(n,1)*1e3:.0f} ms/step) | "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} | "
          f"stragglers logged: {out['stragglers']} | "
          f"ckpt @ {ckpt.latest_step()}")
    ckpt.close()


if __name__ == "__main__":
    main()
