"""Serving example: continuous batching over the BTT-style paged KV cache
with transit tiering (eager page-out of finished sequences, conditional
bypass under pool pressure).

    PYTHONPATH=src python examples/serve_paged.py
    PYTHONPATH=src python examples/serve_paged.py --pool-pages 4  # pressure
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main()
