"""Serving example: continuous batching over the BTT-style paged KV cache
with transit tiering (eager page-out of finished sequences, conditional
bypass under pool pressure) and, with ``--spill-volume``, the full KV
paging story: suspended sessions' packed pages descend past the host
tier onto a striped async volume as content-deduplicated atomic records,
and decode-ahead prefetch restores them before resume.

    PYTHONPATH=src python examples/serve_paged.py
    PYTHONPATH=src python examples/serve_paged.py --pool-pages 4  # pressure
    PYTHONPATH=src python examples/serve_paged.py --spill-volume \\
        --host-pages 2 --suspend-every 4           # KV paging via volume
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main()
