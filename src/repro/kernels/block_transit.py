"""Block transit engine — Caiti's eager-eviction copy as a Pallas kernel.

Two fused primitives the serving/checkpoint tiers use when *transiting*
pages/blocks between memory tiers:

  * ``gather_quantize``  — gather a set of pages from a pool and pack them
    int8 with one f32 scale per (page, head) group: the eviction DMA payload
    (4x smaller than bf16 — the compression codec of the KV spill path and
    the gradient/checkpoint compressor).
  * ``scatter_dequantize`` — the reverse: unpack int8 pages and scatter them
    back into pool rows (page-in / restore).

Both resolve the page indirection *inside* the kernel (BTT-style mapping
walk) so no (n, page, ...) intermediate ever exists in HBM at full
precision.  Grid = one program per transited page; the pool argument stays
in ANY/HBM; only the active page flows through VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_q_kernel(idx_ref, pool_ref, out_ref, scale_ref, *, eps: float):
    """One page: pool[idx[i]] (page, F) -> int8 out[i] + f32 scale row."""
    page = idx_ref[0]
    x = pl.load(pool_ref, (page, slice(None), slice(None))
                ).astype(jnp.float32)                       # (page_sz, F)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)      # (page_sz, 1)
    scale = amax / 127.0 + eps
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    out_ref[...] = q
    scale_ref[...] = scale[:, 0].astype(jnp.float32)


def gather_quantize_pallas(pool, page_ids, *, interpret: bool = False,
                           eps: float = 1e-12):
    """pool: (P, page_sz, F);  page_ids: (n,) int32
    -> (q (n, page_sz, F) int8, scales (n, page_sz) f32)."""
    P, page_sz, F = pool.shape
    n = page_ids.shape[0]
    return pl.pallas_call(
        functools.partial(_gather_q_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),              # pool in HBM
        ],
        out_specs=[
            pl.BlockSpec((None, page_sz, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, page_sz), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, page_sz, F), jnp.int8),
            jax.ShapeDtypeStruct((n, page_sz), jnp.float32),
        ],
        interpret=interpret,
    )(page_ids, pool)


def _scatter_dq_kernel(idx_ref, q_ref, scale_ref, pool_in_ref, pool_out_ref,
                       *, dtype):
    # pool_in is aliased to pool_out (same HBM buffer): untouched pages keep
    # their contents; only the transited page is stored.
    page = idx_ref[0]
    x = q_ref[...].astype(jnp.float32) * scale_ref[...][:, None]
    pl.store(pool_out_ref, (page, slice(None), slice(None)), x.astype(dtype))


def scatter_dequantize_pallas(pool, page_ids, q, scales, *,
                              interpret: bool = False):
    """Inverse of gather_quantize: write dequantized pages into pool rows.

    pool: (P, page_sz, F) — donated/aliased; returns the updated pool.
    """
    P, page_sz, F = pool.shape
    n = page_ids.shape[0]
    return pl.pallas_call(
        functools.partial(_scatter_dq_kernel, dtype=pool.dtype),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((None, page_sz, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, page_sz), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),      # aliased pool in HBM
        ],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((P, page_sz, F), pool.dtype),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(page_ids, q, scales, pool)
