"""Block transit engine — Caiti's eager-eviction copy as a Pallas kernel.

Two fused primitives the serving/checkpoint tiers use when *transiting*
pages/blocks between memory tiers:

  * ``gather_quantize``  — gather a set of pages from a pool and pack them
    int8 with one f32 scale per (page, head) group: the eviction DMA payload
    (4x smaller than bf16 — the compression codec of the KV spill path and
    the gradient/checkpoint compressor).
  * ``scatter_dequantize`` — the reverse: unpack int8 pages and scatter them
    back into pool rows (page-in / restore).

Both resolve the page indirection *inside* the kernel (BTT-style mapping
walk) so no (n, page, ...) intermediate ever exists in HBM at full
precision.  Grid = one program per transited page; the pool argument stays
in ANY/HBM; only the active page flows through VMEM.

The ``*_crc`` variants FUSE the transit checksum into the same VMEM
traversal as the int8 pack: the spill/restore paths previously made
three passes per page (quantize kernel, host checksum over the packed
bytes, scatter kernel) — the fused pass computes the page checksum over
the exact wire payload (the int8 bytes, row-major) while it is already
resident in VMEM, so the data is touched ONCE per direction.  The
checksum is Adler-32 (zlib's second checksum): unlike CRC32's bitwise
recurrence it reduces to two modular sums, which vectorize on the VPU
in one pass, and ``zlib.adler32`` is the host-side oracle
(``ref.transit_crc_ref`` — bit-identical, property-tested).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_q_kernel(idx_ref, pool_ref, out_ref, scale_ref, *, eps: float):
    """One page: pool[idx[i]] (page, F) -> int8 out[i] + f32 scale row."""
    page = idx_ref[0]
    x = pl.load(pool_ref, (page, slice(None), slice(None))
                ).astype(jnp.float32)                       # (page_sz, F)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)      # (page_sz, 1)
    scale = amax / 127.0 + eps
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    out_ref[...] = q
    scale_ref[...] = scale[:, 0].astype(jnp.float32)


def gather_quantize_pallas(pool, page_ids, *, interpret: bool = False,
                           eps: float = 1e-12):
    """pool: (P, page_sz, F);  page_ids: (n,) int32
    -> (q (n, page_sz, F) int8, scales (n, page_sz) f32)."""
    P, page_sz, F = pool.shape
    n = page_ids.shape[0]
    return pl.pallas_call(
        functools.partial(_gather_q_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),              # pool in HBM
        ],
        out_specs=[
            pl.BlockSpec((None, page_sz, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, page_sz), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, page_sz, F), jnp.int8),
            jax.ShapeDtypeStruct((n, page_sz), jnp.float32),
        ],
        interpret=interpret,
    )(page_ids, pool)


_ADLER_MOD = 65521


def _page_adler32(q):
    """Adler-32 of one page's int8 payload, inside the kernel: q is
    (page_sz, F) int8, already in VMEM from the pack/unpack — the
    checksum rides the same traversal.  Bit-identical to
    ``zlib.adler32(q.tobytes())`` (row-major two's-complement bytes).

    The bitwise-sequential CRC recurrence does not vectorize; Adler-32
    is two modular sums, so it reduces on the VPU: S1 = 1 + sum(d),
    S2 = n + sum((n - i) * d_i), checksum = S2 << 16 | S1.  int32 is
    safe up to page_sz, F <= 32767: per-term (n - i) % M * d <= 65520 *
    255 < 2^31, per-row sums of mod-reduced terms <= F * 65520, and the
    cross-row sum of mod-reduced rows <= page_sz * 65520."""
    d = jax.lax.bitcast_convert_type(q, jnp.uint8).astype(jnp.int32)
    page_sz, F = d.shape
    n = page_sz * F
    r = jax.lax.broadcasted_iota(jnp.int32, (page_sz, F), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (page_sz, F), 1)
    w = (n - (r * F + c)) % _ADLER_MOD
    t = (w * d) % _ADLER_MOD
    s2 = (jnp.sum(jnp.sum(t, axis=1) % _ADLER_MOD) + n) % _ADLER_MOD
    s1 = (1 + jnp.sum(jnp.sum(d, axis=1) % _ADLER_MOD)) % _ADLER_MOD
    return (s2.astype(jnp.uint32) << 16) | s1.astype(jnp.uint32)


def _gather_q_crc_kernel(idx_ref, pool_ref, out_ref, scale_ref, crc_ref,
                         *, eps: float):
    """Fused spill pass: gather + int8 pack + wire checksum, one VMEM
    traversal per page (vs the three-pass quantize / host-checksum /
    copy-out composition)."""
    page = idx_ref[0]
    x = pl.load(pool_ref, (page, slice(None), slice(None))
                ).astype(jnp.float32)                       # (page_sz, F)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)      # (page_sz, 1)
    scale = amax / 127.0 + eps
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    out_ref[...] = q
    scale_ref[...] = scale[:, 0].astype(jnp.float32)
    crc_ref[...] = _page_adler32(q).reshape((1,))


def gather_quantize_crc_pallas(pool, page_ids, *, interpret: bool = False,
                               eps: float = 1e-12):
    """Fused gather+quantize+checksum: pool (P, page_sz, F); page_ids
    (n,) int32 -> (q (n, page_sz, F) int8, scales (n, page_sz) f32,
    crcs (n,) uint32) — crcs are Adler-32 of each page's packed int8
    bytes (the DMA wire payload), checked on page-in/restore."""
    P, page_sz, F = pool.shape
    n = page_ids.shape[0]
    q, scales, crcs = pl.pallas_call(
        functools.partial(_gather_q_crc_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),              # pool in HBM
        ],
        out_specs=[
            pl.BlockSpec((None, page_sz, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, page_sz), lambda i: (i, 0)),
            pl.BlockSpec((None, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, page_sz, F), jnp.int8),
            jax.ShapeDtypeStruct((n, page_sz), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.uint32),
        ],
        interpret=interpret,
    )(page_ids, pool)
    return q, scales, crcs[:, 0]


def _scatter_dq_kernel(idx_ref, q_ref, scale_ref, pool_in_ref, pool_out_ref,
                       *, dtype):
    # pool_in is aliased to pool_out (same HBM buffer): untouched pages keep
    # their contents; only the transited page is stored.
    page = idx_ref[0]
    x = q_ref[...].astype(jnp.float32) * scale_ref[...][:, None]
    pl.store(pool_out_ref, (page, slice(None), slice(None)), x.astype(dtype))


def scatter_dequantize_pallas(pool, page_ids, q, scales, *,
                              interpret: bool = False):
    """Inverse of gather_quantize: write dequantized pages into pool rows.

    pool: (P, page_sz, F) — donated/aliased; returns the updated pool.
    """
    P, page_sz, F = pool.shape
    n = page_ids.shape[0]
    return pl.pallas_call(
        functools.partial(_scatter_dq_kernel, dtype=pool.dtype),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((None, page_sz, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, page_sz), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),      # aliased pool in HBM
        ],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((P, page_sz, F), pool.dtype),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(page_ids, q, scales, pool)


def _scatter_dq_crc_kernel(idx_ref, q_ref, scale_ref, pool_in_ref,
                           pool_out_ref, crc_ref, *, dtype):
    # restore pass: the incoming int8 payload is checksummed WHILE it is
    # in VMEM for the dequantize — the caller compares against the crc
    # stored at spill time (a mismatch means the page tore in transit)
    page = idx_ref[0]
    q = q_ref[...]
    x = q.astype(jnp.float32) * scale_ref[...][:, None]
    pl.store(pool_out_ref, (page, slice(None), slice(None)), x.astype(dtype))
    crc_ref[...] = _page_adler32(q).reshape((1,))


def scatter_dequantize_crc_pallas(pool, page_ids, q, scales, *,
                                  interpret: bool = False):
    """Fused scatter+dequantize+checksum: the inverse transit pass.
    Returns ``(pool, crcs)`` — crcs are Adler-32 of the int8 payload as
    RECEIVED; the caller verifies them against the spill-time values
    (one pass over the data, no separate host checksum walk)."""
    P, page_sz, F = pool.shape
    n = page_ids.shape[0]
    new_pool, crcs = pl.pallas_call(
        functools.partial(_scatter_dq_crc_kernel, dtype=pool.dtype),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((None, page_sz, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, page_sz), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),      # aliased pool in HBM
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((None, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, page_sz, F), pool.dtype),
            jax.ShapeDtypeStruct((n, 1), jnp.uint32),
        ],
        input_output_aliases={3: 0},
        interpret=interpret,
    )(page_ids, q, scales, pool)
    return new_pool, crcs[:, 0]
