"""Paged decode attention — the BTT mapping table fused into a Pallas kernel.

The serving engine stores KV in fixed-size *pages* of an HBM pool; a block
table maps (sequence, logical page) -> physical page, exactly as BTT maps
lba -> pba.  This kernel performs one decode step: for each sequence it
walks its block-table row, gathers the pages *inside the kernel* (the
lba->pba translation fused into the attention gather — no materialized
(B, S, ...) KV view in HBM), and computes online-softmax attention of the
single query token against every valid cached token.

Grid: one program per sequence.  The page loop is a fori_loop over that
sequence's pages; each iteration dynamic-slices one (page_size, Hkv*hd)
page of K and V from the pool (resident rows stream HBM->VMEM), applies
the GQA expansion in-register, and folds into the (H, hd) carry.

The pool stays in ANY/HBM memory space (it is far larger than VMEM); only
the block-table row and the query tile are VMEM-blocked.  This mirrors the
paper's transit principle: the cache (VMEM) holds only what is in flight.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_kernel(q_ref, kpool_ref, vpool_ref, table_ref, len_ref, o_ref, *,
                  page_size: int, max_pages: int, n_rep: int, scale: float):
    """One sequence. q_ref: (H, hd); pools: (P, page, Hkv, hd) in ANY;
    table_ref: (max_pages,) physical page ids; len_ref: (1,) seq length."""
    H, hd = q_ref.shape
    q = q_ref[...].astype(jnp.float32) * scale          # (H, hd)
    seq_len = len_ref[...].reshape(())
    n_pages = (seq_len + page_size - 1) // page_size

    def body(pi, carry):
        m_prev, l_prev, acc = carry
        ppage = table_ref[pi]                            # lba -> pba walk
        k = pl.load(kpool_ref,
                    (ppage, slice(None), slice(None), slice(None))
                    ).astype(jnp.float32)                # (page, Hkv, hd)
        v = pl.load(vpool_ref,
                    (ppage, slice(None), slice(None), slice(None))
                    ).astype(jnp.float32)
        # GQA expand: kv head j serves q heads [j*n_rep, (j+1)*n_rep)
        kx = jnp.repeat(k, n_rep, axis=1)                # (page, H, hd)
        vx = jnp.repeat(v, n_rep, axis=1)
        s = jnp.einsum("hd,phd->hp", q, kx)              # (H, page)
        tok = pi * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        valid = tok < seq_len
        s = jnp.where(valid, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + jnp.einsum("hp,phd->hd", p, vx)
        return m_new, l_new, acc

    m0 = jnp.full((H,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((H,), jnp.float32)
    a0 = jnp.zeros((H, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_pages, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pool, v_pool, block_table, seq_lens, *,
                           interpret: bool = False):
    """q: (B, H, hd);  pools: (P, page_size, Hkv, hd);
    block_table: (B, max_pages) int32;  seq_lens: (B,) int32
    -> (B, H, hd)."""
    B, H, hd = q.shape
    P, page_size, Hkv, _ = k_pool.shape
    max_pages = block_table.shape[1]
    n_rep = H // Hkv
    scale = 1.0 / math.sqrt(hd)

    return pl.pallas_call(
        functools.partial(_paged_kernel, page_size=page_size,
                          max_pages=max_pages, n_rep=n_rep, scale=scale),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((None, H, hd), lambda b: (b, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),       # K pool stays in HBM
            pl.BlockSpec(memory_space=pl.ANY),       # V pool stays in HBM
            pl.BlockSpec((None, max_pages), lambda b: (b, 0)),
            pl.BlockSpec((None,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((None, H, hd), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(q, k_pool, v_pool, block_table, seq_lens)
