"""Blocked flash attention (causal / sliding-window) as a Pallas TPU kernel.

TPU-native tiling: the grid is (batch, q_heads, Q_blocks); each program
holds one (BQ, hd) query tile in VMEM and streams (BK, hd) key/value tiles
through the MXU with an online-softmax carry (m, l, acc) kept in VMEM
scratch.  Block sizes are MXU-aligned (multiples of 128 on the lane dim,
8/16 on the sublane dim for f32/bf16).

GQA is handled by indexing the KV head as q_head // (H // Hkv) in the
BlockSpec index_map — no KV duplication in HBM or VMEM.

Causality is exploited at the *block* level: KV blocks strictly above the
diagonal are skipped (the kernel's KV loop bound depends on the Q block
index), so the causal kernel does ~half the FLOPs of a dense one — the same
work-skipping idea Caiti applies to I/O (never touch what you can avoid).

Validated in interpret mode against kernels/ref.py (CPU container); on a
real TPU the same pallas_call lowers to Mosaic.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
                 window: int, bq: int, bk: int, seq_k: int):
    """One (batch, q_head, q_block) program.

    q_ref: (BQ, hd) VMEM tile;  k_ref/v_ref: (S, hd) full rows for the
    program's kv head (streamed in BK chunks below);  o_ref: (BQ, hd).
    """
    qi = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32) * scale
    hd = q.shape[-1]

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    def body(ki, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (pl.dslice(ki * bk, bk), slice(None))
                    ).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(ki * bk, bk), slice(None))
                    ).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        valid = jnp.full((bq, bk), True)
        if causal:
            valid = valid & (k_pos <= q_pos)
        if window:
            valid = valid & (q_pos - k_pos < window)
        s = jnp.where(valid, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)

    n_kv = seq_k // bk
    if causal:
        # block-level causal skip: only blocks with k_start <= q_end
        hi = jnp.minimum(n_kv, (qi * bq + bq + bk - 1) // bk)
    else:
        hi = n_kv
    if window:
        lo = jnp.maximum(0, (qi * bq - window) // bk)
    else:
        lo = 0
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           interpret: bool = False):
    """q: (B, T, H, hd);  k, v: (B, S, Hkv, hd)  ->  (B, T, H, hd).

    T and S must be multiples of bq / bk (callers pad); hd is the lane dim
    and should be a multiple of 128 for MXU efficiency (64 works, half-lane).
    """
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    assert T % bq == 0 and S % bk == 0, (T, S, bq, bk)
    n_rep = H // Hkv
    scale = 1.0 / math.sqrt(hd)

    # layout: (B, H, T, hd) so the head dim is a grid axis
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, T // bq)

    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, seq_k=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, bq, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((None, None, S, hd),
                         lambda b, h, i, n_rep=n_rep: (b, h // n_rep, 0, 0)),
            pl.BlockSpec((None, None, S, hd),
                         lambda b, h, i, n_rep=n_rep: (b, h // n_rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, hd),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
