"""jit'd public wrappers for the Pallas kernels.

On the CPU container every op runs the *same kernel body* in interpret mode
(validating logic + tiling); on TPU (platform == 'tpu') the pallas_call
lowers to Mosaic.  Model code selects the implementation with the config
flag ``attn_impl`` — the dry-run uses the XLA path (Pallas TPU kernels do
not lower on the host platform), which is recorded in DESIGN.md.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .block_transit import (gather_quantize_crc_pallas,
                            gather_quantize_pallas,
                            scatter_dequantize_crc_pallas,
                            scatter_dequantize_pallas)
from .flash_attention import flash_attention_pallas
from .paged_attention import paged_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_attention(q, k, v, causal, window, bq, bk):
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  bq=bq, bk=bk, interpret=not _on_tpu())


def _flash_fwd(q, k, v, causal, window, bq, bk):
    return _flash_attention(q, k, v, causal, window, bq, bk), (q, k, v)


def _flash_bwd(causal, window, bq, bk, res, g):
    # backward through the jnp oracle (XLA recompute — the standard
    # fwd-kernel/bwd-recompute split; a dedicated bwd kernel is a TPU-side
    # optimization outside this container's scope)
    from . import ref
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=causal,
                                                window=window), q, k, v)
    return vjp(g)


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128):
    return _flash_attention(q, k, v, causal, window, bq, bk)


@jax.jit
def paged_attention(q, k_pool, v_pool, block_table, seq_lens):
    return paged_attention_pallas(q, k_pool, v_pool, block_table, seq_lens,
                                  interpret=not _on_tpu())


@jax.jit
def gather_quantize(pool, page_ids):
    return gather_quantize_pallas(pool, page_ids, interpret=not _on_tpu())


@jax.jit
def scatter_dequantize(pool, page_ids, q, scales):
    return scatter_dequantize_pallas(pool, page_ids, q, scales,
                                     interpret=not _on_tpu())


@jax.jit
def gather_quantize_crc(pool, page_ids):
    """Fused spill codec: one VMEM pass per page producing the int8
    payload, the f32 scales, AND the Adler-32 wire checksum (vs the
    three-pass quantize / host-checksum / copy composition)."""
    return gather_quantize_crc_pallas(pool, page_ids,
                                      interpret=not _on_tpu())


@jax.jit
def scatter_dequantize_crc(pool, page_ids, q, scales):
    """Fused restore codec: dequantize+scatter plus the checksum of the
    payload as received, for the caller to verify against spill time."""
    return scatter_dequantize_crc_pallas(pool, page_ids, q, scales,
                                         interpret=not _on_tpu())
