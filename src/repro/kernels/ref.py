"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, T, H, hd); k, v: (B, S, Hkv, hd) -> (B, T, H, hd). f32 math."""
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    n_rep = H // Hkv
    k = jnp.repeat(k, n_rep, axis=2)
    v = jnp.repeat(v, n_rep, axis=2)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(T)[:, None]
    k_pos = jnp.arange(S)[None, :]
    valid = jnp.full((T, S), True)
    if causal:
        valid = valid & (k_pos <= q_pos)
    if window:
        valid = valid & (q_pos - k_pos < window)
    s = jnp.where(valid[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[None, None], p, 0.0)
    out = jnp.einsum("bhts,bshd->bthd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention_ref(q, k_pool, v_pool, block_table, seq_lens):
    """q: (B, H, hd); pools: (P, page, Hkv, hd); block_table: (B, max_pages);
    seq_lens: (B,) -> (B, H, hd)."""
    B, H, hd = q.shape
    P, page, Hkv, _ = k_pool.shape
    n_rep = H // Hkv
    max_pages = block_table.shape[1]
    scale = 1.0 / math.sqrt(hd)

    # materialize (B, max_pages*page, Hkv, hd) views via the table
    k = k_pool[block_table].reshape(B, max_pages * page, Hkv, hd)
    v = v_pool[block_table].reshape(B, max_pages * page, Hkv, hd)
    k = jnp.repeat(k, n_rep, axis=2).astype(jnp.float32)
    v = jnp.repeat(v, n_rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), k) * scale
    tok = jnp.arange(max_pages * page)[None, :]
    valid = tok < seq_lens[:, None]
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, :], p, 0.0)
    out = jnp.einsum("bhs,bshd->bhd", p, v)
    return out.astype(q.dtype)


def gather_quantize_ref(pool, page_ids, eps: float = 1e-12):
    x = pool[page_ids].astype(jnp.float32)          # (n, page, F)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = amax / 127.0 + eps
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def scatter_dequantize_ref(pool, page_ids, q, scales):
    x = q.astype(jnp.float32) * scales[..., None]
    return pool.at[page_ids].set(x.astype(pool.dtype))


def transit_crc_ref(q):
    """Host oracle for the fused transit checksum: per-page Adler-32 of
    the packed int8 payload (row-major two's-complement bytes).  Exact
    int64 numpy math — bit-identical to ``zlib.adler32(page.tobytes())``
    and to the in-kernel ``_page_adler32``.  q: (n, page, F) int8 ->
    (n,) uint32."""
    import numpy as np
    mod = 65521
    qn = np.asarray(q, dtype=np.int8)
    n_pages = qn.shape[0]
    d = qn.view(np.uint8).astype(np.int64).reshape(n_pages, -1)
    n = d.shape[1]
    w = np.arange(n, 0, -1, dtype=np.int64)          # weight n - i
    s2 = (d @ w + n) % mod
    s1 = (1 + d.sum(axis=1)) % mod
    return ((s2 << 16) | s1).astype(np.uint32)
