"""Pallas TPU kernels for the perf-critical compute of the serving/transit
path: blocked flash attention, paged (block-table) decode attention, and the
transit gather/scatter+int8 codec.  See ops.py for the jit'd public API and
ref.py for the pure-jnp oracles every kernel is validated against."""
from .ops import (flash_attention, gather_quantize, paged_attention,
                  scatter_dequantize)

__all__ = ["flash_attention", "paged_attention", "gather_quantize",
           "scatter_dequantize"]
