"""Pallas TPU kernels for the perf-critical compute of the serving/transit
path: blocked flash attention, paged (block-table) decode attention, and the
transit gather/scatter+int8 codec.  See ops.py for the jit'd public API and
ref.py for the pure-jnp oracles every kernel is validated against."""
from .ops import (flash_attention, gather_quantize, gather_quantize_crc,
                  paged_attention, scatter_dequantize,
                  scatter_dequantize_crc)

__all__ = ["flash_attention", "paged_attention", "gather_quantize",
           "scatter_dequantize", "gather_quantize_crc",
           "scatter_dequantize_crc"]
