"""AdamW with decoupled weight decay, f32 moments over (possibly bf16)
params, global-norm clipping, and linear-warmup/cosine schedules.  Pure
pytree-functional (optax-style update/init pair) so opt-state sharding is
fully controlled by the caller (ZeRO-1 in parallel/sharding.py)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1

    def schedule(self, step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(1, self.warmup_steps))
        prog = jnp.clip((step - self.warmup_steps) /
                        max(1, self.total_steps - self.warmup_steps), 0, 1)
        cos = self.min_lr_frac + (1 - self.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
        return self.lr * warm * cos

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params):
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(g32)))
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9)) \
            if self.clip_norm else 1.0
        g32 = jax.tree.map(lambda g: g * scale, g32)
        step = state.step + 1
        lr = self.schedule(step)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)

        m = jax.tree.map(lambda mm, g: self.b1 * mm + (1 - self.b1) * g,
                         state.m, g32)
        v = jax.tree.map(lambda vv, g: self.b2 * vv + (1 - self.b2) * g * g,
                         state.v, g32)

        def upd(p, mm, vv):
            mh = mm / bc1
            vh = vv / bc2
            u = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:                       # decay matrices only
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, params, m, v)
        return updates, AdamWState(step=step, m=m, v=v), \
            {"gnorm": gnorm, "lr": lr}


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)
