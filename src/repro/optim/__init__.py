from .adamw import AdamW, AdamWState, apply_updates

__all__ = ["AdamW", "AdamWState", "apply_updates"]
