"""Block object store on top of the PMem block device (the paper's stack,
used as the checkpoint substrate).

Layout (in lbas):
    [0]            root pointer block — THE atomic commit point: holds
                   (magic, generation, manifest_lba, manifest_len, checksum)
    [1 .. M]       manifest area (two ping-pong regions, written CoW-style)
    [M+1 .. end]   data blocks, bump-allocated per generation

A checkpoint *commit* depends on the device's atomicity primitive:

  * **single device** (block-level atomicity only): write the manifest
    blocks for the next generation into the inactive ping-pong region,
    fsync, then write the root block last and fsync again.  The BTT makes
    the root flip all-or-nothing, so a crash anywhere leaves the previous
    generation intact — at the price of double-written manifests and an
    extra fsync round trip;
  * **striped volume** (``supports_chained_tx``): root + manifest are one
    ``write_multi`` starting at lba 0 — the volume's chained-tx journal
    commits the whole object atomically (tail header = commit point), so
    the ping-pong double write and the separate root-flip pass are gone:
    one logical write, one fsync, same crash guarantee.
"""
from __future__ import annotations

import json
import struct
import zlib

import numpy as np

from repro.core import BlockDevice, make_device
from repro.core.pmem import LatencyModel

_MAGIC = 0xCA171B10
_ROOT_FMT = "<QQQQQ"          # magic, generation, manifest_lba, manifest_len(bytes), crc


class BlockStore:
    """Keyed object store with generation-atomic commits."""

    def __init__(self, device, n_lbas: int,
                 manifest_blocks: int = 256, aio: bool = False) -> None:
        # ``device`` is anything speaking write/read/fsync/close — a single
        # BlockDevice or a repro.volume.StripedVolume (sharded checkpoints)
        self.dev = device
        self.block_size = getattr(device, "block_size", None) or \
            (device.impl.btt.block_size
             if hasattr(getattr(device, "impl", None), "btt") else 4096)
        self.n_lbas = n_lbas
        self._manifest_cap = manifest_blocks
        self._data_base = 1 + 2 * manifest_blocks
        # chained-tx commit (striped volumes): root + manifest land as ONE
        # whole-object-atomic write_multi — no ping-pong, no root flip
        self._chained = bool(getattr(device, "supports_chained_tx", False)
                             and hasattr(device, "write_multi"))
        # overlapped I/O (striped volumes with the async frontend):
        # ``put`` submits its block writes and returns while they are in
        # flight; ``get`` fans its block reads out over the engine
        # workers.  Outstanding put tickets are settled (checked for
        # per-ticket errors) before any dependent read or commit.
        self._aio = bool(aio and hasattr(device, "submit"))
        # registered buffer pool (zero-copy puts): chunks serialize
        # straight into pre-pinned engine buffers — the engine takes the
        # handle without a defensive staging snapshot and releases the
        # slot from the completion path
        self._registry = (device.register_buffers(64)
                          if self._aio and hasattr(device,
                                                   "register_buffers")
                          else None)
        self._pending: list = []
        self._unsettled_keys: set[str] = set()
        self.generation = 0
        self._alloc_ptr = self._data_base
        # the manifest region the committed root points at — a fallback
        # (ping-pong) commit must never overwrite it before the flip
        self._active_mlba = 0
        # key -> (lba_start, n_blocks, nbytes) for the *current* generation
        self.directory: dict[str, tuple[int, int, int]] = {}
        self._load_root()

    # ------------------------------------------------------------- root I/O
    def _load_root(self) -> None:
        raw = bytes(self.dev.read(0)[: struct.calcsize(_ROOT_FMT)])
        magic, gen, mlba, mlen, crc = struct.unpack(_ROOT_FMT, raw)
        if magic != _MAGIC:
            return                                    # fresh store
        blocks = (mlen + self.block_size - 1) // self.block_size
        buf = b"".join(bytes(self.dev.read(mlba + i)) for i in range(blocks))
        payload = buf[:mlen]
        if zlib.crc32(payload) != crc:                # torn manifest: stale root
            return
        man = json.loads(payload.decode())
        self.generation = gen
        self._active_mlba = mlba
        self.directory = {k: tuple(v) for k, v in man["objects"].items()}
        self._alloc_ptr = man["alloc_ptr"]

    def _manifest_region(self, gen: int) -> int:
        """Ping-pong: even generations in region 0, odd in region 1."""
        return 1 + (gen % 2) * self._manifest_cap

    # ----------------------------------------------------------------- data
    def _alloc(self, n_blocks: int) -> int:
        lba = self._alloc_ptr
        if lba + n_blocks > self.n_lbas:
            # simple generational GC: restart the bump region (old data is
            # unreachable once a new root commits)
            lba = self._data_base
            self._alloc_ptr = lba
        self._alloc_ptr = lba + n_blocks
        assert self._alloc_ptr <= self.n_lbas, "store exhausted"
        return lba

    def _settle_pending(self) -> None:
        """Wait out EVERY in-flight put ticket (consuming their
        completions — a failure must not abandon siblings on the shared
        ring), then surface the first per-ticket device error here (on
        the dependent read/commit/close), not mid-flight."""
        pending, self._pending = self._pending, []
        keys, self._unsettled_keys = self._unsettled_keys, set()
        first_err = None
        for t in pending:
            self.dev.wait(t)
            if t.error is not None and first_err is None:
                first_err = t.error
        if first_err is not None:
            # the sync path never registers a key whose write failed; a
            # key whose blocks may be torn must not stay readable —
            # drop the whole unsettled batch (callers re-put on error)
            for k in keys:
                self.directory.pop(k, None)
            raise first_err


    def put(self, key: str, payload: bytes | memoryview) -> None:
        """Stage one object (writes go through the device's cache policy).

        With ``aio`` the block writes are SUBMITTED, not performed: the
        caller overlaps serialization of the next object with this one's
        descent through the stack; ``commit``/``get`` settle the
        tickets."""
        nbytes = len(payload)
        bs = self.block_size
        n_blocks = max(1, (nbytes + bs - 1) // bs)
        lba = self._alloc(n_blocks)
        mv = memoryview(payload)
        # plain per-block writes even on a striped volume: torn puts are
        # already invisible until commit() flips the root, so the volume's
        # redo journal would only double the write volume here
        for i in range(n_blocks):
            part = mv[i * bs:(i + 1) * bs]
            if self._aio and self._registry is not None:
                # zero-copy put: serialize the chunk straight into a
                # registered buffer — the one unavoidable copy (payload
                # -> wire) lands in the pinned slot, and the engine takes
                # the handle without a second staging snapshot
                buf = self._registry.acquire()
                arr = buf.data
                n = len(part)
                arr[:n] = np.frombuffer(part, dtype=np.uint8)
                if n < bs:
                    arr[n:] = 0
                # block=True: the engine's in-flight window is the flow
                # control — a put burst waits its turn, never fails
                self._pending.append(self.dev.submit("write", lba + i,
                                                     data=buf,
                                                     block=True))
                continue
            chunk = bytes(part)
            if len(chunk) < bs:
                chunk = chunk + b"\x00" * (bs - len(chunk))
            if self._aio:
                self._pending.append(self.dev.submit("write", lba + i,
                                                     data=chunk,
                                                     block=True))
            else:
                self.dev.write(lba + i, chunk)
        if self._aio:
            self._unsettled_keys.add(key)
        self.directory[key] = (lba, n_blocks, nbytes)

    def get(self, key: str) -> bytes:
        lba, n_blocks, nbytes = self.directory[key]
        out = np.empty(n_blocks * self.block_size, dtype=np.uint8)
        if self._aio:
            # overlapped ZERO-COPY restore: fan the block reads out
            # across the engine workers (a sliding window honoring the
            # in-flight bound), each landing directly in its slice of
            # the destination array (``out=`` — no post-poll copy out
            # of the completion ring), then settle in order
            self._settle_pending()   # reads must see completed puts
            bs = self.block_size
            tickets: dict[int, object] = {}
            next_sub = 0

            def pump(need: int = -1) -> None:
                nonlocal next_sub
                while next_sub < n_blocks:
                    dst = out[next_sub * bs:(next_sub + 1) * bs]
                    if next_sub <= need:
                        t = self.dev.submit("read", lba + next_sub,
                                            out=dst, block=True)
                    else:
                        # probe, don't count refusals as failures
                        t = self.dev.try_submit("read", lba + next_sub,
                                                out=dst)
                        if t is None:
                            return       # window full: gather first
                    tickets[next_sub] = t
                    next_sub += 1

            pump()
            err = None
            for i in range(n_blocks):
                if i not in tickets:
                    if err is not None:
                        break            # never submitted past a failure
                    pump(need=i)         # blocks until read i submitted
                t = tickets[i]
                self.dev.wait(t)         # consume even failed siblings
                if t.error is not None:
                    err = err or t.error
                    continue
                if err is None:          # data already landed in out=
                    pump()
            if err is not None:
                raise err
            return bytes(out[:nbytes])
        for i in range(n_blocks):
            self.dev.read(lba + i, out=out[i * self.block_size:
                                           (i + 1) * self.block_size])
        return bytes(out[:nbytes])

    def delete(self, key: str) -> None:
        self.directory.pop(key, None)

    def keys(self):
        return list(self.directory)

    # --------------------------------------------------------------- commit
    def commit(self) -> int:
        """Atomically publish the current directory as a new generation."""
        gen = self.generation + 1
        man = json.dumps({"objects": {k: list(v)
                                      for k, v in self.directory.items()},
                          "alloc_ptr": self._alloc_ptr}).encode()
        crc = zlib.crc32(man)
        bs = self.block_size
        n_blocks = (len(man) + bs - 1) // bs
        assert n_blocks <= self._manifest_cap, "manifest too large"
        chained = self._chained and (1 + n_blocks) <= \
            self.dev.max_atomic_write_blocks()
        if chained:
            mlba = 1
        else:
            mlba = self._manifest_region(gen)
            if mlba == self._active_mlba:
                # a prior chained commit parked the root on this region
                # (parity broken): use the OTHER one — writing over the
                # active manifest before the flip would destroy the
                # previous generation on crash
                mlba = 1 + self._manifest_cap if mlba == 1 else 1
        root = struct.pack(_ROOT_FMT, _MAGIC, gen, mlba, len(man), crc)
        root = root + b"\x00" * (bs - len(root))
        chunks = [man[i * bs:(i + 1) * bs] for i in range(n_blocks)]
        chunks = [c + b"\x00" * (bs - len(c)) for c in chunks]
        # 1. settle in-flight async puts, then drain the transit cache +
        #    BTT (all data durable first)
        self._settle_pending()
        if self._aio and chained:
            # linked-SQE commit: the whole fsync -> publish -> fsync
            # protocol is ONE ticket chain, waited once on the tail —
            # the dependencies execute in-engine instead of costing a
            # poll round trip per hop, and a failed stage CANCELS the
            # stages behind it (a failed data barrier can never be
            # followed by the atomic publish)
            t1 = self.dev.submit("fsync", block=True)
            t2 = self.dev.submit("write_multi", 0, blocks=[root] + chunks,
                                 link_to=t1, block=True)
            t3 = self.dev.submit("fsync", link_to=t2, block=True)
            self.dev.wait(t3)
            for t in (t1, t2, t3):       # settle + surface the ROOT cause
                self.dev.wait(t)
                if t.error is not None:
                    raise t.error
            self.generation = gen
            self._active_mlba = mlba
            return gen
        if self._aio:
            # ping-pong commit over the async frontend: data barrier ->
            # parallel manifest writes (linked to the barrier, so a
            # failed barrier cancels them) -> one settle point -> linked
            # root-flip chain.  Two waits total; the settle before the
            # flip mirrors the sync path's abort-before-root guarantee
            # (a torn manifest must never be published).
            head = self.dev.submit("fsync", block=True)
            writes = [self.dev.submit("write", mlba + i, data=chunk,
                                      link_to=head, block=True)
                      for i, chunk in enumerate(chunks)]
            barrier = self.dev.submit("fsync", block=True)  # IO_DRAIN
            self.dev.wait(barrier)
            for t in (head, *writes, barrier):
                self.dev.wait(t)
                if t.error is not None:
                    raise t.error
            troot = self.dev.submit("write", 0, data=root, block=True)
            tfin = self.dev.submit("fsync", link_to=troot, block=True)
            self.dev.wait(tfin)
            for t in (troot, tfin):
                self.dev.wait(t)
                if t.error is not None:
                    raise t.error
            self.generation = gen
            self._active_mlba = mlba
            return gen
        self.dev.fsync()
        if chained:
            # 2. ONE whole-object-atomic logical write: root + manifest.
            #    The chained-tx journal's tail header is the commit point
            #    — no ping-pong double write, no separate root flip.
            self.dev.write_multi(0, [root] + chunks)
            self.dev.fsync()
            self.generation = gen
            self._active_mlba = mlba
            return gen
        # 2. manifest into the inactive ping-pong region
        for i, chunk in enumerate(chunks):
            self.dev.write(mlba + i, chunk)
        self.dev.fsync()
        # 3. THE flip: one atomic root-block write (BTT CoW makes it
        #    all-or-nothing), then the final durability barrier
        self.dev.write(0, root)
        self.dev.fsync()
        self.generation = gen
        self._active_mlba = mlba
        return gen

    def close(self) -> None:
        # surface any in-flight put failure instead of silently
        # swallowing the only error report (the sync path raises in put)
        self._settle_pending()
        self.dev.close()


def make_blockstore(path: str | None = None, *, policy: str = "caiti",
                    capacity_bytes: int = 1 << 30, block_size: int = 4096,
                    cache_bytes: int = 64 << 20,
                    latency: LatencyModel | None = None,
                    n_shards: int = 1,
                    read_tier_bytes: int = 0,
                    aio: bool = False,
                    cluster: int = 0,
                    replication_k: int = 2) -> BlockStore:
    """``n_shards > 1`` stripes the store over a multi-device volume:
    checkpoint blocks spread across all shards' PMem (aggregate bandwidth)
    and multi-block puts ride the volume journal.  ``read_tier_bytes > 0``
    fronts the device(s) with a clean DRAM read tier — the restore path
    (``get`` walking manifest + chunk blocks) re-reads hot metadata blocks
    through DRAM instead of PMem.  ``aio`` (volumes only) issues put/get
    block I/O through the volume's async frontend: writes overlap the
    caller's next serialization step, restore reads fan out across the
    engine workers.

    ``cluster = N > 0`` backs the store with an N-node distributed
    ``ClusterVolume`` instead (``replication_k`` copies per chunk):
    checkpoints survive whole-node loss — puts are chain-replicated and
    acked on K durable tails, restores fail over past dead or corrupt
    members via the cluster crc ledger.  The BlockStore itself is
    unchanged: the cluster speaks the same chained-tx write_multi /
    verified-read surface as the striped volume, and manifest commits
    stay whole-object atomic because the cluster caps
    ``max_atomic_write_blocks`` at one placement chunk."""
    n_lbas = capacity_bytes // block_size
    if cluster > 0:
        from repro.cluster import make_cluster
        dev = make_cluster(policy, n_lbas=n_lbas, n_nodes=cluster,
                           replication_k=replication_k,
                           block_size=block_size, cache_bytes=cache_bytes,
                           node_shards=n_shards if n_shards > 1 else 2,
                           backend="file" if path else "ram", path=path,
                           read_tier_bytes=read_tier_bytes)
    elif n_shards > 1:
        from repro.volume import make_volume
        dev = make_volume(policy, n_lbas=n_lbas, n_shards=n_shards,
                          block_size=block_size, cache_bytes=cache_bytes,
                          backend="file" if path else "ram", path=path,
                          latency=latency, read_tier_bytes=read_tier_bytes)
    else:
        dev = make_device(policy, n_lbas=n_lbas, block_size=block_size,
                          cache_bytes=cache_bytes,
                          backend="file" if path else "ram", path=path,
                          latency=latency, read_tier_bytes=read_tier_bytes)
    return BlockStore(dev, n_lbas, aio=aio)
