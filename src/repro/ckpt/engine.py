"""Caiti-backed distributed checkpoint engine.

The training loop calls ``save_async(step, state)``; the engine

  1. snapshots device arrays to host (jax.device_get — the only sync point),
  2. cuts every leaf into fixed-size chunks and *transits* them through a
     :class:`repro.core.TransitBuffer` (eager eviction: background threads
     stream chunks into the block store while the next training step runs;
     conditional bypass: if staging RAM is exhausted, the chunk is written
     synchronously instead of stalling the whole save),
  3. commits the store generation (atomic root flip — the fsync analogue).

Restore is mesh-elastic: leaves are stored as full (unsharded) arrays with a
dtype/shape header, so a checkpoint saved on mesh A restores onto mesh B (or
a single device) — the caller passes target shardings and the engine places
shards with ``jax.device_put``.

Wire format per leaf:  header json {dtype, shape} | raw little-endian bytes,
chunked as ``<key>/<i>``; a ``<key>`` entry in the step manifest records the
chunk count.  Optional int8 codec (per-chunk scale) halves/quarters the
volume for moments — the same codec the transit kernels use on-device.
"""
from __future__ import annotations

import json
import threading
import time

import jax
import numpy as np

from repro.core import Metrics, TransitBuffer
from .blockstore import BlockStore

_CHUNK = 4 << 20          # 4 MB chunks — large enough to amortize, small
                          # enough that bypass granularity stays fine


def _leaf_paths(tree) -> list[tuple[str, np.ndarray]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def _encode_header(arr: np.ndarray) -> bytes:
    h = json.dumps({"dtype": str(arr.dtype), "shape": list(arr.shape)}
                   ).encode()
    return len(h).to_bytes(4, "little") + h


def _int8_encode(arr: np.ndarray) -> tuple[bytes, dict]:
    flat = arr.astype(np.float32).reshape(-1)
    amax = float(np.abs(flat).max()) if flat.size else 0.0
    scale = amax / 127.0 + 1e-12
    q = np.clip(np.round(flat / scale), -127, 127).astype(np.int8)
    return q.tobytes(), {"codec": "int8", "scale": scale}


class CheckpointEngine:
    def __init__(self, store: BlockStore, *, staging_bytes: int = 256 << 20,
                 n_workers: int = 4, keep: int = 3,
                 codec: str = "raw") -> None:
        self.store = store
        self.keep = keep
        self.codec = codec
        self.metrics = Metrics()
        self._store_lock = threading.Lock()   # store.put is not thread-safe
        self.transit = TransitBuffer(self._sink, capacity_bytes=staging_bytes,
                                     n_workers=n_workers,
                                     metrics=self.metrics)
        self._save_thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # ------------------------------------------------------------- internals
    def _sink(self, item) -> None:
        key, payload = item
        with self._store_lock:
            self.store.put(key, payload)

    def _write_state(self, step: int, state) -> None:
        t0 = time.perf_counter()
        prefix = f"step{step:010d}"
        manifest: dict[str, dict] = {}
        for key, leaf in _leaf_paths(state):
            arr = np.asarray(leaf)
            if self.codec == "int8" and arr.dtype in (np.float32, np.float16
                                                      ) and arr.size > 1024:
                body, meta = _int8_encode(arr)
            else:
                body, meta = arr.tobytes(), {"codec": "raw"}
            header = _encode_header(arr)
            blob = header + body
            n_chunks = max(1, (len(blob) + _CHUNK - 1) // _CHUNK)
            for i in range(n_chunks):
                self.transit.put(
                    (f"{prefix}/{key}/{i}", blob[i * _CHUNK:(i + 1) * _CHUNK]),
                    nbytes=min(_CHUNK, len(blob) - i * _CHUNK))
            manifest[key] = {"chunks": n_chunks, **meta}
        # wait for every staged chunk to land, then commit atomically
        self.transit.flush()
        with self._store_lock:
            self.store.put(f"{prefix}/MANIFEST",
                           json.dumps(manifest).encode())
            steps = self.list_steps()
            if step not in steps:
                steps.append(step)
            steps = sorted(steps)[-self.keep:]
            self._gc(steps)
            self.store.put("STEPS", json.dumps(steps).encode())
            self.store.commit()
        self.metrics.add_ns("ckpt_save",
                            int((time.perf_counter() - t0) * 1e9))

    def _gc(self, keep_steps: list[int]) -> None:
        prefixes = {f"step{s:010d}" for s in keep_steps}
        for key in self.store.keys():
            if key.startswith("step") and key.split("/")[0] not in prefixes:
                self.store.delete(key)

    # ------------------------------------------------------------ public API
    def save(self, step: int, state) -> None:
        """Synchronous save + commit."""
        host = jax.device_get(state)
        self._write_state(step, host)

    def save_async(self, step: int, state) -> None:
        """Snapshot now, persist in the background (overlaps next steps)."""
        self.wait()                           # one in-flight save at a time
        host = jax.device_get(state)

        def run():
            try:
                self._write_state(step, host)
            except BaseException as e:        # surfaced on wait()
                self._error = e

        self._save_thread = threading.Thread(target=run, daemon=True,
                                             name=f"ckpt-save-{step}")
        self._save_thread.start()

    def wait(self) -> None:
        if self._save_thread is not None:
            self._save_thread.join()
            self._save_thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def list_steps(self) -> list[int]:
        if "STEPS" not in self.store.directory:
            return []
        return list(json.loads(self.store.get("STEPS").decode()))

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, *, like=None, shardings=None):
        """Rebuild the pytree of ``step`` (default latest).

        ``like``: a pytree of arrays/ShapeDtypeStructs giving the structure.
        ``shardings``: optional matching pytree of jax.sharding.Sharding —
        enables cross-mesh (elastic) restore via device_put per leaf.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no committed checkpoint")
        prefix = f"step{step:010d}"
        manifest = json.loads(self.store.get(f"{prefix}/MANIFEST").decode())

        arrays: dict[str, np.ndarray] = {}
        for key, meta in manifest.items():
            blob = b"".join(self.store.get(f"{prefix}/{key}/{i}")
                            for i in range(meta["chunks"]))
            hlen = int.from_bytes(blob[:4], "little")
            h = json.loads(blob[4:4 + hlen].decode())
            body = blob[4 + hlen:]
            if meta.get("codec") == "int8":
                q = np.frombuffer(body, dtype=np.int8).astype(np.float32)
                arr = (q * meta["scale"]).astype(h["dtype"]
                                                 ).reshape(h["shape"])
            else:
                arr = np.frombuffer(body, dtype=np.dtype(h["dtype"])
                                    ).reshape(h["shape"]).copy()
            arrays[key] = arr

        if like is None:
            return arrays, step
        flat = _leaf_paths(like)
        shard_flat = (_leaf_paths(shardings) if shardings is not None
                      else [(k, None) for k, _ in flat])
        leaves = []
        for (key, proto), (_, shd) in zip(flat, shard_flat):
            arr = arrays[key]
            want = np.dtype(jax.numpy.result_type(proto)) \
                if hasattr(proto, "dtype") else arr.dtype
            arr = arr.astype(want) if arr.dtype != want else arr
            leaves.append(jax.device_put(arr, shd) if shd is not None
                          else jax.numpy.asarray(arr))
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, leaves), step

    def close(self) -> None:
        self.wait()
        self.transit.close()
        self.store.close()
