from .blockstore import BlockStore, make_blockstore
from .engine import CheckpointEngine

__all__ = ["BlockStore", "make_blockstore", "CheckpointEngine"]
