"""llama-3.2-vision-11b [hf:meta-llama/Llama-3.2-11B-Vision; unverified] —
40L d4096 32H (GQA kv=8) d_ff 14336, vocab 128256; gated cross-attn image
layers every 5th layer; vision tower is a STUB: input_specs provides patch
embeddings (B, 1600, 4096)."""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256, cross_every=5,
    n_img_tokens=1600)

SMOKE = ModelConfig(
    name="llama-vision-smoke", family="vlm", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, cross_every=2,
    n_img_tokens=16, attn_chunk=64)
