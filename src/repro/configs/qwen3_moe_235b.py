"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B family; hf] — 94L d4096 64H
(GQA kv=4) per-expert d_ff=1536, vocab 151936, MoE 128e top-8."""
from repro.models.common import ModelConfig, MoECfg

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, d_ff=1536, vocab=151936,
    moe=MoECfg(n_experts=128, top_k=8, d_expert=1536))

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=96, vocab=256,
    moe=MoECfg(n_experts=8, top_k=2, d_expert=96), attn_chunk=64)
