"""whisper-large-v3 [arXiv:2212.04356; unverified] — enc-dec, 32L enc + 32L
dec, d1280 20H, d_ff 5120, vocab 51866. Conv frontend is a STUB: input_specs
provides precomputed frame embeddings (B, 1500, 1280)."""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="whisper-large-v3", family="encdec", n_layers=32, enc_layers=32,
    d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120, vocab=51866,
    norm="ln", act="gelu", pos="sinusoidal", enc_seq=1500)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec", n_layers=2, enc_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
    norm="ln", act="gelu", pos="sinusoidal", enc_seq=30, attn_chunk=64)
