"""recurrentgemma-9b [arXiv:2402.19427; unverified] — 38L, (RG-LRU, RG-LRU,
local attn) 2:1 pattern, d4096 16H (MQA kv=1), d_ff 12288, vocab 256000,
window 2048. lru_width = d_model (documented deviation)."""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, d_ff=12288, vocab=256000, attn_window=2048,
    block_pattern=("rec", "rec", "attn"))

SMOKE = ModelConfig(
    name="rg-smoke", family="hybrid", n_layers=5, d_model=64,
    n_heads=4, n_kv_heads=1, d_ff=128, vocab=256, attn_window=32,
    block_pattern=("rec", "rec", "attn"), attn_chunk=64)
