"""xlstm-1.3b [arXiv:2405.04517; unverified] — 48 blocks, mLSTM:sLSTM = 7:1,
d2048 4H (head 512), d_ff=0 (self-contained blocks), vocab 50304."""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="xlstm-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304, pos="none")

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm", n_layers=8, d_model=64,
    n_heads=2, n_kv_heads=2, d_ff=0, vocab=256, pos="none")
