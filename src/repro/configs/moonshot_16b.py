"""moonshot-v1-16b-a3b (kimi/moonlight) [hf:moonshotai/Moonlight-16B-A3B; hf]
— 48L d2048 16H (GQA kv=16 ≡ MHA) per-expert d_ff=1408, MoE 64e top-6."""
from repro.models.common import ModelConfig, MoECfg

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=163840,
    moe=MoECfg(n_experts=64, top_k=6, d_expert=1408))

SMOKE = ModelConfig(
    name="moonshot-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=96, vocab=256,
    moe=MoECfg(n_experts=4, top_k=2, d_expert=96), attn_chunk=64)
