"""deepseek-coder-33b [arXiv:2401.14196; hf] — dense llama-arch 62L d7168
56H (GQA kv=8) d_ff 19200, vocab 32256."""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="deepseek-coder-33b", family="dense", n_layers=62, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=19200, vocab=32256)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="dense", n_layers=2, d_model=56,
    n_heads=7, n_kv_heads=1, d_ff=128, vocab=256, attn_chunk=64)
