"""Architecture registry: ``get_config(arch_id, smoke=False)`` and the
canonical list of assigned architectures (``--arch`` values)."""
from __future__ import annotations

from importlib import import_module

from repro.models.common import ModelConfig

_MODULES = {
    "qwen3-moe-235b-a22b":  "repro.configs.qwen3_moe_235b",
    "moonshot-v1-16b-a3b":  "repro.configs.moonshot_16b",
    "whisper-large-v3":     "repro.configs.whisper_large_v3",
    "phi3-mini-3.8b":       "repro.configs.phi3_mini",
    "deepseek-coder-33b":   "repro.configs.deepseek_coder_33b",
    "qwen2.5-3b":           "repro.configs.qwen25_3b",
    "internlm2-1.8b":       "repro.configs.internlm2_1p8b",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "xlstm-1.3b":           "repro.configs.xlstm_1p3b",
    "recurrentgemma-9b":    "repro.configs.recurrentgemma_9b",
}

ARCHS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False, **overrides) -> ModelConfig:
    mod = import_module(_MODULES[arch])
    cfg = mod.SMOKE if smoke else mod.FULL
    return cfg.with_(**overrides) if overrides else cfg
