"""qwen2.5-3b [hf:Qwen/Qwen2.5-0.5B family; hf] — dense 36L d2048 16H
(GQA kv=2) d_ff 11008, vocab 151936, QKV bias."""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-3b", family="dense", n_layers=36, d_model=2048,
    n_heads=16, n_kv_heads=2, d_ff=11008, vocab=151936, qkv_bias=True)

SMOKE = ModelConfig(
    name="qwen25-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, qkv_bias=True,
    attn_chunk=64)
