"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --smoke --steps 50 --ckpt /tmp/ckpt.pool

On the CPU container this trains the reduced (smoke) config end-to-end with
the full production substrate: deterministic pipeline, Caiti-backed async
checkpointing, watchdog, restart-resume (run it twice with the same --ckpt
to see the resume).  On a TPU fleet the same entry point takes the full
config plus the production mesh (see launch/mesh.py and launch/dryrun.py
for the lowering contract).
"""
from __future__ import annotations

import argparse

import jax

from repro.ckpt import CheckpointEngine, make_blockstore
from repro.configs import ARCHS, get_config
from repro.data import SyntheticLM
from repro.models import build_model
from repro.optim import AdamW
from repro.train.loop import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None, help="block-pool file path")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-policy", default="caiti")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    opt = AdamW(lr=args.lr, total_steps=args.steps)
    source = SyntheticLM(cfg.vocab, args.seq, args.batch)

    ckpt = None
    if args.ckpt:
        store = make_blockstore(args.ckpt, policy=args.ckpt_policy,
                                capacity_bytes=2 << 30)
        ckpt = CheckpointEngine(store)

    trainer = Trainer(model, opt, source, ckpt=ckpt,
                      cfg=TrainConfig(total_steps=args.steps,
                                      ckpt_every=args.ckpt_every,
                                      accum=args.accum))
    out = trainer.run(jax.random.PRNGKey(0))
    print(f"[train] arch={args.arch} steps->{out['last_step']} "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"stragglers={out['stragglers']}")
    if ckpt is not None:
        ckpt.close()


if __name__ == "__main__":
    main()
