"""Serving launcher: batched requests against the paged-KV engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --requests 16 --max-new 24

Demonstrates continuous batching, the BTT-style block table, eager
page-out of finished sequences, and conditional bypass under pool pressure
(shrink --pool-pages to force it).

With ``--spill-volume`` the engine gets a volume-backed KV spill tier
(serve.kvpager.KVPager on a striped async volume): requests are
periodically suspended mid-decode, their packed pages descend past
``--host-pages`` onto the volume as content-deduplicated atomic records,
and decode-ahead prefetch restores them before resume.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.serve import PagedCacheConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--pool-pages", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--use-kernel", action="store_true",
                    help="paged-attention Pallas kernel (interpret on CPU)")
    ap.add_argument("--spill-volume", action="store_true",
                    help="attach a volume-backed KV spill tier and "
                         "suspend/resume requests through it")
    ap.add_argument("--host-pages", type=int, default=4,
                    help="host-tier budget before pages spill to the "
                         "volume (with --spill-volume)")
    ap.add_argument("--suspend-every", type=int, default=6,
                    help="scheduler ticks between preemptions "
                         "(with --spill-volume)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.family != "dense":
        raise SystemExit("the paged engine serves the dense family; pick a "
                         "dense arch (qwen2.5-3b, phi3-mini-3.8b, ...)")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    pager = None
    if args.spill_volume:
        from repro.serve import KVPager
        from repro.volume.volume import make_volume
        vol = make_volume(n_lbas=1 << 14, n_shards=2, aio_workers=2,
                          cache_bytes=1 << 22)
        pager = KVPager(vol, capacity_blocks=1 << 13)
    cache_cfg = PagedCacheConfig(
        n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        page_size=args.page_size, n_pages=args.pool_pages,
        host_pages=args.host_pages if args.spill_volume else 1 << 30,
        max_pages_per_seq=max(4, (args.prompt_len + args.max_new)
                              // args.page_size + 2))
    eng = ServeEngine(cfg, params, cache_cfg=cache_cfg,
                      max_batch=args.max_batch, use_kernel=args.use_kernel,
                      pager=pager)

    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        prompt = rng.integers(2, cfg.vocab, size=(args.prompt_len,)).tolist()
        eng.submit(prompt, max_new_tokens=args.max_new,
                   temperature=args.temperature)

    t0 = time.perf_counter()
    if args.spill_volume:
        # drive the scheduler by hand so we can preempt mid-decode: the
        # suspended request's pages transit host -> volume, and the
        # decode-ahead prefetch restores them before _admit resumes it
        ticks = 0
        while eng.queue or eng.running or eng.suspended:
            eng.step()
            ticks += 1
            if eng.running and ticks % args.suspend_every == 0:
                eng.suspend(eng.running[0])
        done = eng.finished
    else:
        done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    lat = [r.t_done - r.t_submit for r in done]
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s) "
          f"| mean latency {np.mean(lat)*1e3:.0f}ms "
          f"| pool occupancy now {eng.cache.occupancy():.2f} "
          f"| pages out/in {eng.metrics.count.get('pages_out', 0)}/"
          f"{eng.metrics.count.get('pages_in', 0)} "
          f"| bypass pages {eng.metrics.count.get('bypass_pages', 0)}")
    if args.spill_volume:
        path = eng.metrics.kv_paging_path()
        print(f"[spill] suspends {eng.metrics.count.get('suspends', 0)} "
              f"resumes {eng.metrics.count.get('resumes', 0)} "
              f"| spills {path['kv_spills']} "
              f"(dedup rate {path['dedup_rate']:.2f}) "
              f"| restores {path['kv_restores']} "
              f"(prefetch hit rate {path['prefetch_hit_rate']:.2f}) "
              f"| crc errors {path['kv_restore_crc_errors']}")


if __name__ == "__main__":
    main()
