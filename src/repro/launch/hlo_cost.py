"""Exact-ish cost model over post-SPMD optimized HLO text.

``compiled.cost_analysis()`` counts every computation ONCE — a scanned
94-layer transformer reports 1 layer of FLOPs.  This module re-derives the
three roofline inputs by walking the HLO call graph with **while-loop trip
multipliers**:

  * flops        — 2 * prod(result_shape) * prod(contracting_dims) per dot
                   (convolutions handled analogously)
  * hbm bytes    — sum of (operand + result) bytes of ops per computation,
                   with fusion-internal ops excluded (they live in
                   registers/VMEM) — i.e. an HBM-traffic model
  * collectives  — per-kind counts/bytes (payload shape), trip-multiplied,
                   with replica-group sizes for wire-byte modeling

The text is the *partitioned* (per-device) module, so every number is
per-device — the roofline convention used throughout EXPERIMENTS.md.
Validated against known matmul/scan/remat programs in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "s4": 1, "u4": 1, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# `%name = <type> <op>(<rest>` where <type> may be a tuple and carries
# layout suffixes like {1,0}
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[\w\[\],{}:#*_ ]+?))\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls|branch_computations)="
                      r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*\))?\s*->")

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota", "reshape", "copy-start", "copy-done"}
_TRANSCEND_OPS = {"exponential", "log", "tanh", "logistic", "rsqrt", "sqrt",
                  "power", "sine", "cosine", "exponential-minus-one"}


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * b
    return total


def _first_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


def _last_shape_bytes(type_str: str) -> int:
    shapes = _SHAPE_RE.findall(type_str)
    if not shapes:
        return 0
    dt, dims = shapes[-1]
    b = _DTYPE_BYTES.get(dt, 0)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


@dataclass
class Comp:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    transcend: float = 0.0
    colls: dict = field(default_factory=dict)
    calls: list = field(default_factory=list)        # (callee, kind)
    while_conds: dict = field(default_factory=dict)  # body_name -> cond_name
    max_const: int = 0                               # for trip-count guess
    # HBM-access model for *fused* computations: parameter position ->
    # bytes actually touched (slice bytes when every use is a
    # dynamic-slice; full buffer otherwise); root DUS write is the slice.
    param_access: dict = field(default_factory=dict)
    root_write_bytes: float | None = None


def parse_hlo(text: str) -> dict[str, Comp]:
    comps: dict[str, Comp] = {}
    cur: Comp | None = None
    sym_bytes: dict[str, int] = {}
    sym_dims: dict[str, list[int]] = {}
    # per-comp param tracking: name -> position; position -> (full, sliced,
    # slice_bytes, wholesale)
    params: dict[str, int] = {}
    pstat: dict[int, list] = {}

    def finish_comp():
        if cur is None:
            return
        for pos, (full, sliced, slice_by, whole) in pstat.items():
            if whole or not sliced:
                cur.param_access[pos] = full
            else:
                cur.param_access[pos] = min(full, slice_by)

    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        stripped = line.strip()
        if stripped.endswith("{") and "=" not in stripped.split("(")[0]:
            hdr = _COMP_HDR.match(stripped)
            if hdr and "->" in stripped:
                finish_comp()
                cur = Comp(hdr.group(1))
                comps[cur.name] = cur
                sym_bytes = {}
                sym_dims = {}
                params = {}
                pstat = {}
                continue
        if cur is None:
            continue
        if stripped == "}":
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        rname, rtype, op, rest = m.groups()
        rbytes = shape_bytes(rtype)
        sym_bytes[rname] = rbytes
        sym_dims[rname] = _first_dims(rtype)
        cm = re.search(r"constant\((\d+)\)", line)
        if cm:
            cur.max_const = max(cur.max_const, int(cm.group(1)))
        # operand names up to the argument-list closing paren
        args_part = rest.split(")", 1)[0]
        operands = _OPERAND_RE.findall(args_part)
        obytes = sum(sym_bytes.get(o, 0) for o in operands)
        # ---- parameter access tracking (for the fusion HBM model) -------
        if op == "parameter":
            pm = re.search(r"parameter\((\d+)\)", line)
            if pm:
                pos = int(pm.group(1))
                params[rname] = pos
                pstat[pos] = [rbytes, False, 0.0, False]
        else:
            # param aliases flow through pure shape/type plumbing ops
            if op in ("convert", "copy", "bitcast", "reshape") and operands \
                    and operands[0] in params:
                params[rname] = params[operands[0]]
            for oi, o in enumerate(operands):
                if o in params:
                    st = pstat[params[o]]
                    if op == "dynamic-slice":
                        st[1] = True
                        st[2] += rbytes
                    elif op == "dynamic-update-slice" and oi == 0:
                        # in-place DUS target: written through, not read
                        st[1] = True
                    elif op in ("get-tuple-element", "bitcast", "reshape",
                                "tuple", "convert", "copy"):
                        pass                      # shape plumbing, not access
                    else:
                        st[3] = True              # wholesale use
        is_root = stripped.startswith("ROOT")
        if is_root and op == "dynamic-update-slice" and len(operands) > 1:
            cur.root_write_bytes = sym_bytes.get(operands[1], 0)
        if op == "dot":
            contract = 1
            lhs_dims = sym_dims.get(operands[0], []) if operands else []
            dm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            if dm and dm.group(1):
                for ci in dm.group(1).split(","):
                    ci = int(ci)
                    if ci < len(lhs_dims):
                        contract *= lhs_dims[ci]
            out_elems = 1
            for d in _first_dims(rtype):
                out_elems *= d
            cur.flops += 2.0 * out_elems * contract
        elif op == "convolution":
            out_elems = 1
            for d in _first_dims(rtype):
                out_elems *= d
            in_dims = sym_dims.get(operands[0], []) if operands else []
            k = in_dims[-1] if in_dims else 1
            cur.flops += 2.0 * out_elems * k
        if op in COLLECTIVE_KINDS or any(
                op == f"{k}-start" for k in COLLECTIVE_KINDS):
            kind = op.replace("-start", "")
            gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
            if gm:
                gsize = int(gm.group(2))
            else:
                gm2 = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
                gsize = len(gm2.group(1).split(",")) if gm2 else 0
            cbytes = _last_shape_bytes(rtype) if op.endswith("-start") \
                else rbytes
            ent = cur.colls.setdefault(kind, {"count": 0, "bytes": 0.0,
                                              "group": gsize})
            ent["count"] += 1
            ent["bytes"] += cbytes
            ent["group"] = max(ent["group"], gsize)
        if op == "dynamic-update-slice":
            # in-place DUS: traffic = read-modify-write of the *slice*
            # (operand 1), not the whole carried buffer
            upd = sym_bytes.get(operands[1], 0) if len(operands) > 1 else 0
            cur.bytes += 2 * upd
        elif op == "dynamic-slice":
            # traffic = the extracted slice, not the sliced buffer
            cur.bytes += 2 * rbytes
        elif op == "fusion":
            # reads: per-parameter access model of the fused computation
            # (a param only ever dynamic-sliced costs its slices, not the
            # whole stacked buffer); writes: root DUS writes its slice.
            fm0 = re.search(r"calls=%?([\w.\-]+)", line)
            callee = comps.get(fm0.group(1)) if fm0 else None
            if callee is not None:
                reads = sum(
                    callee.param_access.get(i, sym_bytes.get(o, 0))
                    for i, o in enumerate(operands))
                write = (callee.root_write_bytes
                         if callee.root_write_bytes is not None else rbytes)
                cur.bytes += reads + write
            else:
                cur.bytes += rbytes + obytes
        elif op == "while":
            pass          # carry stays in place; the body accounts traffic
        elif op not in _SKIP_BYTES_OPS and not op.endswith("-done"):
            cur.bytes += rbytes + obytes
        if op in _TRANSCEND_OPS:
            out_elems = 1
            for d in _first_dims(rtype):
                out_elems *= d
            cur.transcend += out_elems
        # --- call-graph edges -------------------------------------------
        if op == "while":
            bm = re.search(r"body=%?([\w.\-]+)", line)
            cm2 = re.search(r"condition=%?([\w.\-]+)", line)
            if bm:
                cur.calls.append((bm.group(1), "while_body"))
                if cm2:
                    cur.while_conds[bm.group(1)] = cm2.group(1)
        elif op == "fusion":
            fm = re.search(r"calls=%?([\w.\-]+)", line)
            if fm:
                cur.calls.append((fm.group(1), "fusion"))
        else:
            for cm3 in _CALL_RE.finditer(line):
                for callee in re.split(r",\s*", cm3.group(1)):
                    cur.calls.append((callee.strip().lstrip("%"), "call"))
    finish_comp()
    return comps


class HloCost:
    """Roofline totals for the entry computation of an optimized module."""

    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: dict[str, tuple] = {}
        # entry = the computation no one calls (fallback: named main)
        called = {c for comp in self.comps.values() for c, _ in comp.calls}
        entries = [n for n in self.comps if n not in called]
        self._entry = None
        for n in entries:
            if "main" in n:
                self._entry = n
                break
        if self._entry is None:
            self._entry = entries[0] if entries else next(iter(self.comps))

    def _trips(self, caller: Comp, body: str) -> int:
        cond = caller.while_conds.get(body)
        if cond and cond in self.comps:
            c = self.comps[cond].max_const
            if c > 0:
                return c
        # condition constant may be folded into the body counter init
        return max(1, self.comps[body].max_const) if body in self.comps else 1

    def _cost(self, name: str, seen=()) -> tuple:
        if name in self._memo:
            return self._memo[name]
        if name not in self.comps or name in seen:
            return (0.0, 0.0, 0.0, {})
        c = self.comps[name]
        fl, by, tr = c.flops, c.bytes, c.transcend
        colls = {k: dict(v) for k, v in c.colls.items()}
        for callee, kind in c.calls:
            if callee not in self.comps:
                continue
            cf, cb, ct, cc = self._cost(callee, seen + (name,))
            mult = self._trips(c, callee) if kind == "while_body" else 1
            fl += cf * mult
            # HBM bytes: while bodies re-run their traffic every trip;
            # fusion internals live in VMEM/registers — the fusion op's own
            # operands/result were already counted at the call site.
            if kind != "fusion":
                by += cb * mult
            tr += ct * mult
            for k, v in cc.items():
                ent = colls.setdefault(k, {"count": 0, "bytes": 0.0,
                                           "group": v.get("group", 0)})
                ent["count"] += v["count"] * mult
                ent["bytes"] += v["bytes"] * mult
                ent["group"] = max(ent["group"], v.get("group", 0))
        out = (fl, by, tr, colls)
        self._memo[name] = out
        return out

    def entry(self) -> str:
        return self._entry

    def totals(self) -> dict:
        fl, by, tr, colls = self._cost(self.entry())
        wire = 0.0
        for k, v in colls.items():
            g = max(2, v.get("group", 2))
            frac = (g - 1) / g
            if k == "all-reduce":
                # ring AR = RS + AG: 2·(g-1)/g × payload crosses each link
                wire += 2 * frac * v["bytes"]
            elif k == "collective-permute":
                wire += v["bytes"]
            elif k == "reduce-scatter":
                # payload recorded is the scattered output shard: ring input
                # traffic is (g-1) × shard per device
                wire += (g - 1) * v["bytes"]
            else:
                wire += frac * v["bytes"]
        return {"flops": fl, "bytes": by, "transcendentals": tr,
                "collectives": colls,
                "collective_bytes": sum(v["bytes"] for v in colls.values()),
                "wire_bytes": wire}
