import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run driver (deliverable e + the data source for g).

For every (architecture x input-shape x mesh) cell this lowers + compiles the
real step function (train_step / prefill / serve_step) against ShapeDtypeStruct
inputs on the production mesh, then records:
  * memory_analysis()      — per-device bytes: args/outputs/temps (fits HBM?)
  * cost_analysis()        — HLO FLOPs + bytes accessed (roofline terms 1-2)
  * collective inventory   — parsed from the post-SPMD optimized HLO
                             (roofline term 3)

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
Results are appended as JSON, one file per cell, so long sweeps are resumable.
"""
import argparse
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.models import SHAPES, build_model, shape_applicable
from repro.optim import AdamW
from repro.parallel import (batch_spec_tree, cache_spec_tree, make_ctx,
                            named, param_spec_tree, zero_spec_tree)
from repro.train.step import make_train_step
from repro.launch.hlo_cost import HloCost
from repro.launch.mesh import make_production_mesh

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum result bytes per collective kind from post-partitioning HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+ = (\(.*?\)|[\w\[\],{}\/ ]+?) "
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start|-done)?\(", line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue                     # counted at -start
        kind = m.group(2)
        out[kind]["count"] += 1
        out[kind]["bytes"] += _shape_bytes(m.group(1))
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # some backends lack it
        return {"error": str(e)}
    d = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            d[f] = int(v)
    if not d and ma is not None:
        d["repr"] = str(ma)
    return d


def build_cell(arch: str, shape_name: str, mesh, opts: dict):
    """Returns (jitted_fn, example_args_shapedtype) for one cell."""
    cfg = get_config(arch, **opts.get("cfg_overrides", {}))
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    ctx = make_ctx(mesh, shape.batch)
    pspecs = param_spec_tree(model.param_shape(), mesh)
    pshard = named(pspecs, mesh)
    specs = model.input_specs(shape)

    if shape.kind == "train":
        opt = AdamW()
        params_sds = model.param_shape()
        opt_sds = jax.eval_shape(opt.init, params_sds)
        ospecs = jax.tree.map(lambda s: P(), opt_sds,
                              is_leaf=lambda x: hasattr(x, "shape"))
        # moments follow params (+ZeRO-1 over data when enabled)
        mspec = pspecs if not opts.get("zero1", True) else \
            zero_spec_tree(pspecs, params_sds, mesh)
        ospecs = type(opt_sds)(step=P(), m=mspec, v=jax.tree.map(
            lambda x: x, mspec))
        oshard = named(ospecs, mesh)
        bspecs = batch_spec_tree(specs["batch"], ctx)
        bshard = named(bspecs, mesh)
        step = make_train_step(model, opt, ctx,
                               accum=opts.get("accum", 1),
                               grad_compression=opts.get("compression",
                                                         "none"))
        fn = jax.jit(step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        args = (params_sds, opt_sds, specs["batch"])
        return fn, args

    if shape.kind == "prefill":
        bspecs = batch_spec_tree(specs["batch"], ctx)
        cache_sds = model.cache_shape(shape.batch, shape.seq)
        cspecs = cache_spec_tree(cache_sds, ctx, mesh)

        def prefill(params, batch):
            return model.prefill(params, batch, ctx)

        fn = jax.jit(prefill,
                     in_shardings=(pshard, named(bspecs, mesh)),
                     out_shardings=(None, named(cspecs, mesh)))
        return fn, (model.param_shape(), specs["batch"])

    # decode
    cache_sds = specs["cache"]
    cspecs = cache_spec_tree(cache_sds, ctx, mesh)
    cshard = named(cspecs, mesh)
    b = ctx.batch_axes if ctx.batch_axes else None
    tshard = NamedSharding(mesh, P(b))

    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos, ctx)

    fn = jax.jit(serve_step,
                 in_shardings=(pshard, cshard, tshard, tshard),
                 out_shardings=(None, cshard),
                 donate_argnums=(1,))
    return fn, (model.param_shape(), cache_sds, specs["token"], specs["pos"])


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             opts: dict | None = None, tag: str = "") -> dict:
    opts = opts or {}
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    outp = pathlib.Path(out_dir)
    outp.mkdir(parents=True, exist_ok=True)
    fpath = outp / f"{cell_id}.json"
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, SHAPES[shape_name])
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
           "opts": {k: v for k, v in opts.items() if k != "cfg_overrides"},
           "params": cfg.param_count(),
           "active_params": cfg.active_param_count()}
    if not ok:
        rec.update(status="SKIP", reason=why)
        fpath.write_text(json.dumps(rec, indent=1))
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        fn, args = build_cell(arch, shape_name, mesh, opts)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        cost = compiled.cost_analysis() or {}
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and
                k in ("flops", "bytes accessed", "transcendentals",
                      "optimal_seconds")}
        mem = _mem_dict(compiled)
        hlo_text = compiled.as_text()
        coll = parse_collectives(hlo_text)
        try:
            # trip-multiplied per-device roofline terms (see hlo_cost.py)
            hc = HloCost(hlo_text).totals()
            hc.pop("collectives", None)
        except Exception as e:          # never fail the cell on the analyzer
            hc = {"error": str(e)}
        rec.update(status="OK", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1), cost=cost, memory=mem,
                   collectives=coll, hlo_cost=hc, n_devices=mesh.size)
    except Exception as e:
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    fpath.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compression", default="none")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--attn-chunk", type=int, default=None)
    args = ap.parse_args()

    opts = {"zero1": not args.no_zero1, "accum": args.accum,
            "compression": args.compression, "cfg_overrides": {}}
    if args.remat:
        opts["cfg_overrides"]["remat"] = args.remat
    if args.attn_chunk:
        opts["cfg_overrides"]["attn_chunk"] = args.attn_chunk

    archs = list(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if (args.both_meshes or
                               (args.all and not args.multi_pod)) \
        else [args.multi_pod]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                cell = f"{arch}__{shape}__{mesh_name}" + \
                    (f"__{args.tag}" if args.tag else "")
                fpath = pathlib.Path(args.out) / f"{cell}.json"
                if args.skip_existing and fpath.exists():
                    prev = json.loads(fpath.read_text())
                    if prev.get("status") in ("OK", "SKIP"):
                        print(f"[skip] {cell}: {prev['status']}")
                        continue
                rec = run_cell(arch, shape, mp, args.out, opts, args.tag)
                msg = rec["status"]
                if rec["status"] == "OK":
                    msg += (f" flops={rec['cost'].get('flops', 0):.3e}"
                            f" coll={rec['collectives']['total_bytes']:.3e}B"
                            f" compile={rec['compile_s']}s")
                elif rec["status"] == "FAIL":
                    msg += f" {rec['error'][:200]}"
                print(f"[{cell}] {msg}", flush=True)


if __name__ == "__main__":
    main()
