"""Production mesh builders.  Functions (never module-level constants) so
importing this module does not touch jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips, axes (data, model).
    Multi-pod: 2 pods x 256 = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1, axes=("data", "model")):
    """Whatever devices exist locally, folded into (data, model)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), axes)
