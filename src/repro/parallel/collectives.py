"""Distributed-optimization collectives built with shard_map + ppermute.

Two beyond-paper tricks the trainer can enable:

  * **int8-compressed gradient all-reduce** — a bidirectional ring
    reduce-scatter/all-gather where every hop ships int8 + per-chunk f32
    scales (4x+ less ICI traffic than bf16).  The Caiti analogy is direct:
    gradients "transit" the ring eagerly in compressed form rather than
    staging full-precision copies.
  * **hierarchical all-reduce** — reduce within a pod first, then across the
    'pod' axis (one inter-pod hop instead of a 512-wide ring), matching the
    2x16x16 production mesh's slow inter-pod links.

Both are exact drop-ins for the DP gradient mean; compression is lossy
(quantization error ~1e-2 relative — bounded in tests) and therefore an
explicit opt-in flag on the train step.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import MeshCtx
from repro.parallel.compat import axis_size, shard_map


def _quantize_int8(x):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = (amax / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ring_allreduce_int8(x, axis: str):
    """Ring reduce-scatter + all-gather with int8 hops (inside shard_map).

    x: (N, ...) flat chunked tensor where N == axis size; each device owns
    the full tensor (DP-replicated grads) and the result is the mean.
    """
    n = axis_size(axis)
    me = jax.lax.axis_index(axis)
    perm_fwd = [(i, (i + 1) % n) for i in range(n)]

    # --- reduce-scatter: after n-1 hops, device i holds the full sum of
    # chunk (i+1) % n ------------------------------------------------------
    def rs_body(k, acc):
        # send chunk (me - k) mod n, receive chunk (me - k - 1) mod n
        send_idx = (me - k) % n
        q, s = _quantize_int8(acc[send_idx])
        q = jax.lax.ppermute(q, axis, perm_fwd)
        s = jax.lax.ppermute(s, axis, perm_fwd)
        recv_idx = (me - k - 1) % n
        return acc.at[recv_idx].add(_dequantize_int8(q, s))

    acc = jax.lax.fori_loop(0, n - 1, rs_body, x)

    # --- all-gather: circulate the reduced chunks ---------------------------
    def ag_body(k, acc):
        send_idx = (me - k + 1) % n
        q, s = _quantize_int8(acc[send_idx])
        q = jax.lax.ppermute(q, axis, perm_fwd)
        s = jax.lax.ppermute(s, axis, perm_fwd)
        recv_idx = (me - k) % n
        return acc.at[recv_idx].set(_dequantize_int8(q, s))

    acc = jax.lax.fori_loop(0, n - 1, ag_body, acc)
    return acc / n


def compressed_allreduce_tree(grads, ctx: MeshCtx):
    """Mean-reduce a grad pytree across the DP axes with int8 ring hops.

    Grads arrive DP-replicated per-shard (pjit already reduced within the
    model axis); we flatten every leaf, ring-reduce over the (flattened) DP
    axes, and restore shapes.  Leaves too small to chunk fall back to psum.
    """
    if ctx.mesh is None or not ctx.batch_axes:
        return grads
    axes = ctx.batch_axes
    mesh = ctx.mesh
    n = 1
    for a in axes:
        n *= mesh.shape[a]

    leaves, treedef = jax.tree.flatten(grads)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    pad = (-flat.size) % n
    flat = jnp.pad(flat, (0, pad)).reshape(n, -1)

    def f(x):
        # collapse multi-axis DP into one logical ring
        if len(axes) == 1:
            return ring_allreduce_int8(x, axes[0])
        # hierarchical: ring within the fast axis, psum across 'pod'
        inner = axes[-1]
        outer = axes[0]
        x = ring_allreduce_int8(x, inner)
        return jax.lax.pmean(x, outer)

    out = shard_map(
        f, mesh=mesh,
        in_specs=P(*(None,) * 2),
        out_specs=P(*(None,) * 2),
        check_vma=False,
    )(flat)
    out = out.reshape(-1)[:sum(sizes)]
    outs = []
    off = 0
    for sh, sz, l in zip(shapes, sizes, leaves):
        outs.append(out[off:off + sz].reshape(sh).astype(l.dtype))
        off += sz
    return jax.tree.unflatten(treedef, outs)


def hierarchical_psum_tree(grads, ctx: MeshCtx):
    """Exact hierarchical mean over DP axes: psum(model-local) per pod, then
    across pods.  XLA usually does this itself on a mesh with a 'pod' axis;
    exposed for A/B comparison in the perf loop."""
    if ctx.mesh is None or not ctx.batch_axes:
        return grads

    def f(*ls):
        outs = []
        for l in ls:
            for a in reversed(ctx.batch_axes):
                l = jax.lax.pmean(l, a)
            outs.append(l)
        return tuple(outs)

    leaves, treedef = jax.tree.flatten(grads)
    outs = shard_map(
        f, mesh=ctx.mesh,
        in_specs=tuple(P() for _ in leaves),
        out_specs=tuple(P() for _ in leaves),
        check_vma=False,
    )(*leaves)
    return jax.tree.unflatten(treedef, list(outs))
