from .sharding import (MODEL_AXIS, batch_axes_for, batch_spec_tree,
                       cache_spec_tree, make_ctx, named, param_spec_tree,
                       zero_spec, zero_spec_tree)

__all__ = ["MODEL_AXIS", "batch_axes_for", "batch_spec_tree",
           "cache_spec_tree", "make_ctx", "named", "param_spec_tree",
           "zero_spec", "zero_spec_tree"]
