"""Version compatibility shims for jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` (keyword
``check_rep``) to ``jax.shard_map`` (keyword ``check_vma``).  Call sites
use the modern spelling; this shim translates on older jax.
"""
from __future__ import annotations

import jax

try:
    _shard_map = jax.shard_map            # jax >= 0.6
    _CHECK_KW = "check_vma"
except AttributeError:                    # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})


def axis_size(axis) -> int:
    """Static size of a named mesh axis, from inside shard_map.

    ``jax.lax.axis_size`` is recent; on older jax ``jax.core.axis_frame``
    resolves the bound axis (returning either a frame or the bare size).
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    frame = jax.core.axis_frame(axis)         # pragma: no cover - versioned
    return getattr(frame, "size", frame)
