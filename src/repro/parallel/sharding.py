"""Sharding rules: parameter/batch/cache PartitionSpecs for the production
meshes, derived from param-tree paths (see layout conventions in
models/layers.py).

TP strategy (baseline): megatron-style column/row parallel on the flat
projection axes — the flat axis (H*hd, F, V, R, …) is always divisible by
the 16-way model axis for the assigned archs, even when the head count is
not; GSPMD resolves the (H*hd)->(H,hd) reshape, which is exactly the kind of
layout decision the roofline analysis surfaces (and the perf loop tunes).
EP: MoE expert tensors are sharded on the expert axis over 'model'.
DP: batch over ('pod','data') when divisible.  ZeRO-1: optimizer moments are
additionally sharded over 'data' (see zero_spec).
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import MeshCtx, ModelConfig

MODEL_AXIS = "model"
# keys whose -2 axis (contracting / vocab-in) is model-sharded (row-parallel)
_ROW_KEYS = {"wo", "wout", "w_out", "wd", "embed"}
# keys never sharded.  rz: the sLSTM per-head recurrence matrix is 4 MB and
# is consumed every token inside the sequential scan — sharding it forced a
# per-step replicate+repartition (SPMD 'involuntary full rematerialization')
_REPL_KEYS = {"scale", "bias", "ln", "xgate", "router", "lam", "bif", "bf",
              "conv_b", "ri", "rf", "rz"}


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _leaf_key(path) -> str:
    return str(getattr(path[-1], "key", ""))


def param_spec_tree(param_shapes, mesh: Mesh):
    """PartitionSpec for every param leaf, by path rules + divisibility."""
    tp = mesh.shape.get(MODEL_AXIS, 1)

    def rule(path, leaf):
        key = _leaf_key(path)
        pstr = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        none = (None,) * nd
        if key in _REPL_KEYS or nd == 0:
            return P(*none)
        if "moe" in pstr and key in ("wg", "wu", "wd") and nd >= 3:
            ax = nd - 3                      # expert axis of (.., E, D, F)
            if shape[ax] % tp != 0:
                return P(*none)
            parts = list(none)
            parts[ax] = MODEL_AXIS
            # ZeRO-3 expert storage: per-expert FFN axis over 'data'
            # (matches moe_apply's in_specs; gathered per layer on use)
            dp = mesh.shape.get("data", 1)
            f_ax = nd - 1 if key in ("wg", "wu") else nd - 2
            if dp > 1 and shape[f_ax] % dp == 0:
                parts[f_ax] = "data"
            return P(*parts)
        if key in _ROW_KEYS and nd >= 2:
            ax = nd - 2
            if shape[ax] % tp == 0:
                return P(*none[:ax], MODEL_AXIS, *none[ax + 1:])
            return P(*none)
        # default: column-parallel on the last axis
        if shape[-1] % tp == 0 and shape[-1] >= tp:
            return P(*none[:-1], MODEL_AXIS)
        return P(*none)

    return jax.tree_util.tree_map_with_path(rule, param_shapes)


def batch_axes_for(mesh: Mesh, batch: int) -> tuple:
    """Largest prefix of (pod, data) that divides the global batch."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    chosen = []
    size = 1
    for a in axes:
        if batch % (size * mesh.shape[a]) == 0:
            chosen.append(a)
            size *= mesh.shape[a]
    return tuple(chosen)


def make_ctx(mesh: Mesh | None, batch: int) -> MeshCtx:
    if mesh is None:
        return MeshCtx()
    return MeshCtx(mesh=mesh, batch_axes=batch_axes_for(mesh, batch),
                   model_axis=MODEL_AXIS if MODEL_AXIS in mesh.shape else None)


def batch_spec_tree(batch_shapes, ctx: MeshCtx):
    b = ctx.batch_axes if ctx.batch_axes else None

    def rule(path, leaf):
        nd = len(leaf.shape)
        return P(b, *(None,) * (nd - 1))

    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


def cache_spec_tree(cache_shapes, ctx: MeshCtx, mesh: Mesh):
    """KV caches: batch over DP axes; the S axis over 'model' when divisible
    (sequence-sharded decode attention — see layers.decode_attention); SSM
    states: last axis over 'model' when divisible."""
    tp = mesh.shape.get(MODEL_AXIS, 1)
    b = ctx.batch_axes if ctx.batch_axes else None

    def rule(path, leaf):
        key = _leaf_key(path)
        pstr = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        none = [None] * nd
        if key in ("k", "v", "pos") and "cross" not in pstr.split("/")[-1]:
            # (.., B, S, Hkv, hd) or (.., B, S): locate B as the axis before S
            s_ax = nd - 3 if key != "pos" else nd - 1
            b_ax = s_ax - 1
            none[b_ax] = b
            if shape[s_ax] % tp == 0:
                none[s_ax] = MODEL_AXIS
            return P(*none)
        if key in ("cross_k", "cross_v"):
            none[nd - 4] = b                 # (.., B, S_enc, Hkv, hd)
            return P(*none)
        # ssm states.  mLSTM C (.., d, e) is contracted over e (h = C q):
        # shard the OUTPUT axis d (-2) so per-step reads need no psum /
        # resharding (sharding e forced a collective per recurrence step).
        if key == "C" and nd >= 2:
            if shape[-2] % tp == 0 and shape[-2] >= tp:
                none[-2] = MODEL_AXIS
            return P(*none)
        if key in ("n", "m", "c", "h", "tail"):
            if shape[-1] % tp == 0 and nd >= 2 and shape[-1] >= tp:
                none[-1] = MODEL_AXIS
            return P(*none)
        return P(*none)

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


def named(tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def zero_spec(spec: P, shape, mesh: Mesh, axis: str = "data") -> P:
    """ZeRO-1: additionally shard optimizer moments over the DP axis, on the
    largest not-yet-sharded tensor axis that divides."""
    if axis not in mesh.shape:
        return spec
    dp = mesh.shape[axis]
    parts = list(spec) + [None] * (len(shape) - len(spec))
    if axis in parts:
        return spec          # already sharded over this axis (ZeRO-3 experts)
    best, best_ax = 0, -1
    for i, (s, cur) in enumerate(zip(shape, parts)):
        if cur is None and s % dp == 0 and s > best:
            best, best_ax = s, i
    if best_ax < 0:
        return spec
    parts[best_ax] = axis
    return P(*parts)


def zero_spec_tree(spec_tree, shape_tree, mesh: Mesh):
    return jax.tree.map(
        lambda sp, sh: zero_spec(sp, sh.shape, mesh), spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))
