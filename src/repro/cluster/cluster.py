"""ClusterVolume: chain-replicated block volume over networked nodes.

The distributed sibling of :class:`repro.volume.StripedVolume` — same
convenience surface (``write`` / ``write_multi`` / ``read`` / ``fsync``
/ ``flush`` plus the async ``submit`` / ``poll`` / ``wait`` frontend),
but the unit of redundancy is a **node**, not a shard:

  * the LBA space is carved into chunks; each chunk's
    :class:`~repro.cluster.placement.PlacementPolicy` chain is its write
    pipeline (primary first, K members, rack-spread);
  * a logical write is **pipelined down the chain**: the payload is
    delivered to each member's :class:`~repro.cluster.node.NetLink` and
    landed through that node's own ``StripedVolume`` —
    ``write_multi`` there, so every hop commits the object through its
    chained-tx journal (per-node whole-object atomicity).  The write is
    ACKED only after all K durable tails landed;
  * the cluster keeps its own write-crc **ledger updated at ack time
    only**: a write that died mid-pipeline (node killed between hops)
    leaves the ledger on the OLD version, so verified reads fail over
    past the torn copies and keep serving the old object — acknowledged
    writes are never lost, unacknowledged ones never tear;
  * **crc-degraded reads**: a copy failing ledger verification (or a
    dead/partitioned member) fails over down the chain; if every live
    copy agrees and only the ledger disagrees it is a mid-flight write,
    served quietly (``verify_races``) — the same arbitration ladder as
    ``StripedVolume._read_verified``, one level up;
  * the :class:`ReReplicator` (cluster-scale sibling of
    ``ReplicaResyncer``) watches the :class:`HeartbeatMonitor`, declares
    stale nodes dead, and regenerates every affected chunk onto a
    placement-chosen survivor — optionally riding the shared eviction
    pool through the same participant interface;
  * **every pipeline step is observable**: ``step_hook`` fires before
    each transfer/write/ack step with the node involved, so the crash
    sweep in ``tests/aio_harness.py`` can kill the node at step N for
    every N — "no acked write is ever lost" becomes a swept property.

The async frontend is the *existing* ``AsyncIOEngine`` verbatim: it
works over anything speaking write/write_multi/read/fsync/flush, so a
node death during an async op fails THAT ticket (per-ticket isolation)
and never the ring.
"""
from __future__ import annotations

import threading
import time
import zlib

import numpy as np

from repro.core.metrics import Metrics, ShardScorer
from repro.volume import TenantSpec, make_volume
from repro.volume.aio import (AsyncIOEngine, RegisteredBuf,
                              hedged_read as _hedged_read)

from .node import (ClusterError, ClusterNode, ClusterUnavailableError,
                   HeartbeatMonitor, NetLink, NodeDownError)
from .placement import NodeInfo, PlacementPolicy


class ClusterConfig:
    """Geometry + policy for a cluster volume (blocks of ``block_size``;
    ``chunk_blocks`` is the placement/replication unit)."""

    def __init__(self, *, n_lbas: int, replication_k: int = 2,
                 chunk_blocks: int = 64, block_size: int = 4096,
                 heartbeat_timeout: float = 5.0,
                 max_inflight: int = 16, aio_workers: int = 2,
                 hedge_delay_us: float = 0.0) -> None:
        assert n_lbas >= 1 and chunk_blocks >= 1 and replication_k >= 1
        self.n_lbas = n_lbas
        self.replication_k = replication_k
        self.chunk_blocks = chunk_blocks
        self.block_size = block_size
        self.heartbeat_timeout = heartbeat_timeout
        self.max_inflight = max_inflight
        self.aio_workers = aio_workers
        # hedged chain reads: wait this long on the primary before the
        # next chain member (0 = auto: healthy-cohort median p99)
        self.hedge_delay_us = hedge_delay_us

    @property
    def n_chunks(self) -> int:
        return -(-self.n_lbas // self.chunk_blocks)


class ClusterVolume:
    """The logical distributed device (see module docstring)."""

    #: single-chunk ``write_multi`` is whole-object atomic on every
    #: chain member (per-node chained-tx journal) and acked only when
    #: all K durable tails landed
    supports_chained_tx = True

    def __init__(self, nodes: list[ClusterNode], cfg: ClusterConfig, *,
                 placement: PlacementPolicy, now_fn=None,
                 evict_pool=None) -> None:
        self.nodes = list(nodes)
        self.cfg = cfg
        self.placement = placement
        self.block_size = cfg.block_size
        self.n_lbas = cfg.n_lbas
        self._now = now_fn or time.monotonic
        self.metrics = Metrics()
        # fail-slow scoring: per-node p50/p99 digests over svc::node{i}
        # (hedged chain reads + placement steering consume the verdicts)
        self.scorer = ShardScorer(self.metrics, family="node")
        # cluster write-crc ledger — updated at ACK only (see module doc)
        self._crcs: dict[int, int] = {}
        self._chains: dict[int, list[int]] = {}
        self._lock = threading.Lock()
        self.monitor = HeartbeatMonitor(self.nodes,
                                        timeout=cfg.heartbeat_timeout,
                                        now_fn=self._now)
        self.rereplicator = ReReplicator(self, pool=evict_pool)
        # crash-sweep instrumentation: hook(step_no, phase, node_idx)
        # fires BEFORE each pipeline step ('xfer' | 'write' | 'ack')
        self.step_hook = None
        self._step_no = 0
        self._aio: AsyncIOEngine | None = None
        # self-tuning control plane (attach_autotuner): None = frozen
        self.autotuner = None

    # -------------------------------------------------------------- mapping
    def _chain_for(self, chunk: int) -> list[int]:
        with self._lock:
            chain = self._chains.get(chunk)
            if chain is None:
                alive = [n.idx for n in self.nodes if n.alive]
                chain = self.placement.assign(chunk, self.cfg.chunk_blocks,
                                              eligible=alive or None)
                self._chains[chunk] = chain
            return chain

    @staticmethod
    def _crc(data) -> int:
        if isinstance(data, (bytes, bytearray, memoryview)):
            return zlib.crc32(data)
        return zlib.crc32(np.ascontiguousarray(data, dtype=np.uint8))

    def _step(self, phase: str, node_idx: int) -> None:
        self._step_no += 1
        if self.step_hook is not None:
            self.step_hook(self._step_no, phase, node_idx)

    # ------------------------------------------------------------------ QoS
    def add_tenant(self, name: str, weight: float = 1.0,
                   rate_mbps: float = 0.0,
                   burst_bytes: int = 4 << 20) -> None:
        """Tenant QoS applies on every member volume (each node runs its
        own WFQ gate + token bucket over its local media)."""
        for n in self.nodes:
            n.volume.add_tenant(name, weight=weight, rate_mbps=rate_mbps,
                                burst_bytes=burst_bytes)

    # ------------------------------------------------------------------ I/O
    def write(self, lba: int, data, tenant: str | None = None) -> int:
        return self.write_multi(lba, [data], tenant=tenant)

    def write_multi(self, lba: int, blocks, tenant: str | None = None) -> int:
        """Pipelined chain-replicated logical write.  Within one chunk
        the write is whole-object atomic end to end (every member lands
        it through its chained-tx journal; the ack — and the cluster
        ledger update — happen only after all K durable tails).  A write
        spanning chunks commits chunk group by chunk group, each group
        atomic on its own chain.  :class:`RegisteredBuf` handles are
        accepted anywhere a block is (the same zero-copy surface the
        async engine pins — one code path for pooled callers)."""
        blocks = [b.data if isinstance(b, RegisteredBuf) else b
                  for b in blocks]
        assert blocks, "empty write"
        assert 0 <= lba and lba + len(blocks) <= self.n_lbas, \
            f"write [{lba}, {lba + len(blocks)}) out of volume range"
        cb = self.cfg.chunk_blocks
        i = 0
        while i < len(blocks):
            start = lba + i
            room = cb - (start % cb)
            n = min(room, len(blocks) - i)
            self._write_chain(start, blocks[i:i + n], tenant)
            i += n
        return 0

    def _write_chain(self, lba: int, blocks, tenant) -> None:
        """One chunk-local write down its chain: xfer + durable write per
        hop, ack (and ledger update) last.  Any hop failing — node down,
        partition, device error — aborts BEFORE the ack: the cluster
        ledger keeps the old crcs, so verified reads resolve the torn
        copies back to the old version."""
        chain = self._chain_for(lba // self.cfg.chunk_blocks)
        nbytes = len(blocks) * self.block_size
        for ni in chain:
            node = self.nodes[ni]
            self._step("xfer", ni)
            node.deliver(nbytes, self._now())
            self._step("write", ni)
            if not node.alive:          # killed between transfer and write
                raise NodeDownError(f"node {node.name} died mid-pipeline")
            t0 = time.perf_counter_ns()
            if len(blocks) == 1:
                node.volume.write(lba, blocks[0], tenant=tenant)
            else:
                node.volume.write_multi(lba, blocks, tenant=tenant)
            dt = time.perf_counter_ns() - t0
            self.metrics.observe(f"svc::node{ni}", dt)
            self.placement.observe_load(ni, dt / 1e3)
        self._step("ack", chain[0])
        for i, b in enumerate(blocks):
            self._crcs[lba + i] = self._crc(b)
        self.metrics.bump("acked_writes")
        self.metrics.bump("acked_blocks", len(blocks))

    def read(self, lba: int, out: np.ndarray | None = None,
             tenant: str | None = None, replica: int = 0) -> np.ndarray:
        """Verified chain read with failover: walk the chain from the
        primary; a dead/partitioned member or a copy failing the cluster
        ledger crc fails over to the next.  Arbitration when nothing
        verifies mirrors ``StripedVolume._read_verified``: all live
        copies agreeing means a mid-flight write (serve quietly);
        otherwise surface the primary-most copy and count it.
        ``replica=`` rotates the walk to start at that chain position —
        the hedge path's backup leg reads the NEXT member first (the
        full failover ladder is preserved)."""
        assert 0 <= lba < self.n_lbas
        chain = self._chain_for(lba // self.cfg.chunk_blocks)
        if replica:
            r = replica % len(chain)
            chain = chain[r:] + chain[:r]
        want = self._crcs.get(lba)
        candidates: list[bytes] = []
        for pos, ni in enumerate(chain):
            node = self.nodes[ni]
            try:
                node.deliver(self.block_size, self._now())
            except ClusterError:
                self.metrics.bump("read_failovers")
                continue
            t0 = time.perf_counter_ns()
            data = node.volume.read(lba, tenant=tenant)
            dt = time.perf_counter_ns() - t0
            self.metrics.observe(f"svc::node{ni}", dt)
            self.placement.observe_load(ni, dt / 1e3)
            if want is None or self._crc(data) == want:
                if pos > 0 or candidates:
                    self.metrics.bump("degraded_reads")
                return self._fill(out, data)
            self.metrics.bump("verify_failures")
            candidates.append(bytes(data))
        if candidates:
            if all(c == candidates[0] for c in candidates):
                self.metrics.bump("verify_races")
            else:
                self.metrics.bump("unrecoverable_reads")
            return self._fill(out, np.frombuffer(candidates[0], np.uint8))
        raise ClusterUnavailableError(
            f"no live replica for lba {lba} (chain {chain})")

    @staticmethod
    def _fill(out, data):
        if out is not None:
            out[:] = data
            return out
        return data

    # ----------------------------------------------------------- tail latency
    def refresh_tail_state(self) -> dict:
        """Recompute the per-node healthy/limping/dead verdicts (dead
        nodes are marked by the failure detector) and push the penalties
        into placement scoring, so new chains route around a limping
        node before it ever misses a heartbeat.  Returns the state
        map."""
        for n in self.nodes:
            if not n.alive:
                self.scorer.mark_dead(f"node{n.idx}")
        states = self.scorer.states()
        pens: dict[int, float] = {}
        for member in states:
            if member.startswith("node"):
                try:
                    idx = int(member[4:])
                except ValueError:
                    continue
                pens[idx] = self.scorer.penalty(member)
        before = self.placement.steered_placements
        self.placement.set_penalties(pens)
        delta = self.placement.steered_placements - before
        if delta:
            self.metrics.bump("steered_placements", delta)
        return states

    def hedge_delay(self) -> float:
        """Seconds to wait on the chain primary before hedging to the
        next member (``hedge_delay_us`` or auto from the scorer)."""
        us = self.cfg.hedge_delay_us
        if us <= 0:
            us = self.scorer.hedge_delay_us(default_us=1000.0)
        return max(us, 1.0) / 1e6

    def hedged_read(self, lba: int, out=None, tenant: str | None = None,
                    delay_s: float | None = None):
        """Tail-tolerant chain read: primary first; after one hedge
        delay the NEXT chain member races it, first completion wins and
        the loser is cancelled (same contract as
        ``StripedVolume.hedged_read`` — counters balance in
        ``Metrics.tail_path()``).  Single-copy chains fall back to a
        plain :meth:`read`."""
        if min(self.cfg.replication_k, len(self.nodes)) < 2:
            return self.read(lba, out=out, tenant=tenant)
        delay = self.hedge_delay() if delay_s is None else delay_s
        return _hedged_read(self, lba, delay_s=delay, out=out,
                            tenant=tenant)

    def flush(self) -> int:
        for n in self.nodes:
            if n.alive and not n.partitioned:
                n.volume.flush()
        return 0

    def fsync(self) -> int:
        """Durability point on every reachable member (each node runs
        its own group-committed checkpoint)."""
        for n in self.nodes:
            if n.alive and not n.partitioned:
                n.volume.fsync()
        self.metrics.bump("cluster_fsyncs")
        return 0

    def max_atomic_write_blocks(self) -> int:
        """Largest whole-object-atomic ``write_multi``: bounded by the
        chunk (a chain never splits an object) and by every member
        journal's ring."""
        node_max = min(n.volume.max_atomic_write_blocks()
                       for n in self.nodes)
        return min(node_max, self.cfg.chunk_blocks)

    # --------------------------------------------------------- async frontend
    def aio_engine(self, *, n_workers: int | None = None,
                   max_inflight_per_tenant: int | None = None) \
            -> AsyncIOEngine:
        """The cluster's :class:`~repro.volume.aio.AsyncIOEngine` —
        the SAME engine the striped volume uses (it speaks the shared
        write/write_multi/read/fsync/flush surface), so per-ticket
        failure isolation extends to node deaths: a chain losing a
        member mid-op fails that ticket with :class:`NodeDownError`,
        never the ring.  Same first-call-configures contract as
        ``StripedVolume.aio_engine``."""
        if self._aio is None:
            self._aio = AsyncIOEngine(
                self,
                n_workers=self.cfg.aio_workers if n_workers is None
                else n_workers,
                max_inflight_per_tenant=self.cfg.max_inflight
                if max_inflight_per_tenant is None
                else max_inflight_per_tenant)
        else:
            assert n_workers is None \
                or n_workers == len(self._aio._workers), \
                "aio engine already running a different worker count"
            assert max_inflight_per_tenant is None \
                or max_inflight_per_tenant \
                == self._aio.max_inflight_per_tenant, \
                "aio engine already running a different in-flight bound"
        return self._aio

    def submit(self, op: str, lba: int = 0, data=None, blocks=None,
               tenant: str | None = None, block: bool = False,
               link_to=None, out=None, replica: int = 0):
        return self.aio_engine().submit(op, lba=lba, data=data,
                                        blocks=blocks, tenant=tenant,
                                        block=block, link_to=link_to,
                                        out=out, replica=replica)

    def try_submit(self, op: str, lba: int = 0, data=None, blocks=None,
                   tenant: str | None = None, link_to=None, out=None,
                   replica: int = 0):
        return self.aio_engine().try_submit(op, lba=lba, data=data,
                                            blocks=blocks, tenant=tenant,
                                            link_to=link_to, out=out,
                                            replica=replica)

    def register_buffers(self, n_buffers: int,
                         buf_bytes: int | None = None):
        """Registered zero-copy buffer pool on the cluster's engine
        (same contract as ``StripedVolume.register_buffers``)."""
        return self.aio_engine().register_buffers(
            n_buffers, self.block_size if buf_bytes is None else buf_bytes)

    def poll(self, max_ops: int | None = None) -> list:
        if self._aio is None:
            return []
        return self._aio.poll(max_ops)

    def wait(self, ticket, timeout: float | None = None):
        return self.aio_engine().wait(ticket, timeout=timeout)

    # ------------------------------------------------------------- liveness
    def kill_node(self, idx: int) -> None:
        """Fail-stop ``idx`` (test/ops hook): deliveries start raising;
        detection still goes through the heartbeat channel."""
        self.nodes[idx].kill()

    def partition_node(self, idx: int, flag: bool = True) -> None:
        self.nodes[idx].partition(flag)

    def heartbeat_tick(self, now: float | None = None) -> None:
        """One heartbeat exchange (reachable nodes beat)."""
        self.monitor.tick(now)

    def resync(self, sample_every: int = 1) -> int:
        """Repair cross-node divergence (partition-heal convergence):
        rewrite every sampled ledger'd block whose copy disagrees with
        the cluster crc from a verified sibling."""
        return self.rereplicator.repair_divergent(sample_every)

    # --------------------------------------------------------- control plane
    def attach_autotuner(self, controller=None):
        """Attach a self-tuning controller at CLUSTER scope: the hedge
        delay is tuned from the node scorer's verdicts, and every other
        knob move (commit/log windows, watermark, scan threshold) fans
        out to each live member's :class:`StripedVolume`, so one control
        loop retunes the whole fleet coherently."""
        if controller is None:
            from repro.volume.autotune import make_default_controller
            controller = make_default_controller()
        member = self.nodes[0].volume
        seed = {"commit_window_us": member.cfg.commit_window * 1e6,
                "log_window_us": member.cfg.log_window * 1e6,
                "bypass_watermark": member.cfg.bypass_watermark,
                "scan_threshold": float(member.cfg.scan_threshold)}
        if self.cfg.hedge_delay_us > 0:
            seed["hedge_delay_us"] = self.cfg.hedge_delay_us
        controller.bind(seed)
        self.autotuner = controller
        return controller

    def autotune_signals(self) -> dict:
        """Fleet-wide signal window: member volumes' windows aggregated
        ops-weighted, with the tail verdicts replaced by the CLUSTER
        scorer's (a limping node, not a limping shard, is what the
        cluster hedge trigger must track)."""
        members = [n.volume.autotune_signals() for n in self.nodes
                   if n.alive]
        agg: dict = {"ops": sum(s["ops"] for s in members)}
        total = max(1, agg["ops"])
        for key in ("fsync_rate", "coalesce_rate", "log_rate",
                    "log_coalesce_rate", "stall_rate", "bypass_rate",
                    "staged_frac", "read_rate", "tier_hit_rate",
                    "scan_denial_rate"):
            agg[key] = sum(s.get(key, 0.0) * max(1, s["ops"])
                           for s in members) / total
        states = self.scorer.states()
        agg["limping"] = any(s != "healthy" for s in states.values())
        agg["healthy_p99_us"] = self.scorer.hedge_delay_us(default_us=0.0)
        return agg

    def autotune_step(self) -> dict:
        """One cluster control tick (see :meth:`attach_autotuner`)."""
        if self.autotuner is None:
            return {}
        changes = self.autotuner.observe(self.autotune_signals())
        if changes:
            if "hedge_delay_us" in changes:
                self.cfg.hedge_delay_us = changes["hedge_delay_us"]
            member_changes = {k: v for k, v in changes.items()
                              if k != "hedge_delay_us"}
            if member_changes:
                for n in self.nodes:
                    if n.alive:
                        n.volume._apply_knobs(member_changes)
            self.metrics.bump("autotune_moves", len(changes))
            for name in changes:
                self.metrics.bump(f"autotune_moves::{name}")
        self.metrics.bump("autotune_ticks")
        return changes

    # ---------------------------------------------------------------- stats
    def scrub(self, sample_every: int = 1) -> dict:
        """Operator scrub: replication health per chunk, cross-node
        divergence against the cluster ledger, the per-node service-time
        EWMAs (``Metrics.per_node`` — the fail-slow signal) and link
        accounting."""
        want_k = min(self.cfg.replication_k, len(self.nodes))
        under = []
        divergent = 0
        with self._lock:
            chains = dict(self._chains)
        for chunk, chain in sorted(chains.items()):
            live = [ni for ni in chain if self.nodes[ni].alive]
            if len(live) < want_k:
                under.append(chunk)
            base = chunk * self.cfg.chunk_blocks
            top = min(base + self.cfg.chunk_blocks, self.n_lbas)
            for lba in range(base, top, sample_every):
                want = self._crcs.get(lba)
                if want is None:
                    continue
                for ni in live:
                    node = self.nodes[ni]
                    if node.partitioned:
                        continue
                    if self._crc(node.volume.read(lba)) != want:
                        divergent += 1
        states = self.refresh_tail_state()
        return {
            "chunks": len(chains),
            "under_replicated": under,
            "divergent_blocks": divergent,
            "per_node": self.metrics.per_node(),
            "tail": {"states": states,
                     "nodes": self.scorer.table(),
                     "hedge_delay_us": round(self.hedge_delay() * 1e6, 3),
                     **self.metrics.tail_path()},
            "placement": self.placement.stats(),
            "nodes": [{"name": n.name, "rack": n.rack, "alive": n.alive,
                       "partitioned": n.partitioned,
                       "link": n.link.stats()} for n in self.nodes],
        }

    def metrics_snapshot(self) -> dict:
        out = dict(self.metrics.snapshot()["count"])
        out["per_node_svc"] = self.metrics.per_node()
        out["tail"] = {"states": self.scorer.states(),
                       **self.metrics.tail_path()}
        out["chunks_mapped"] = len(self._chains)
        if self._aio is not None:
            out["aio"] = self._aio.stats()
        if self.autotuner is not None:
            out["autotune"] = self.autotuner.stats()
        return out

    def close(self) -> None:
        if self._aio is not None:
            self._aio.close()
        self.rereplicator.close()
        for n in self.nodes:
            n.close()


class ReReplicator:
    """Cluster-scale sibling of ``ReplicaResyncer``: heartbeat-driven
    death detection + chunk regeneration onto survivors.

    ``run_once`` is the deterministic entry point (tests, the quickstart
    and the benches drive it with a manual clock): tick the heartbeat
    exchange, declare stale nodes dead, then repair every chain that
    lost a member — placement picks the target, the surviving copy that
    matches the cluster ledger sources the blocks, and the chain entry
    is swapped so future I/O uses the regenerated copy.

    With ``pool`` given, repairs ride the shared eviction pool through
    the SAME participant interface a shard cache exposes
    (``_evict_slot`` / ``_complete_eviction``): re-replication storms
    share the background cores with eviction traffic instead of
    spawning their own."""

    def __init__(self, cluster: ClusterVolume, *, pool=None,
                 socket: int = 0) -> None:
        self.cluster = cluster
        self.pool = pool
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queued: set[tuple[int, int]] = set()   # (chunk, dead_node)
        self._inflight = 0
        self._stop = False
        self.declared_dead: list[int] = []
        if pool is not None:
            pool.register(self, socket=socket)

    # ------------------------------------------------------------ detection
    def detect(self, now: float | None = None) -> list[int]:
        """One failure-detector round: heartbeat exchange, then declare
        every stale node dead (fail-stop from the cluster's point of
        view — a partitioned node past the timeout is declared too,
        HDFS-style; if it ever heals it must rejoin as a new member)."""
        cl = self.cluster
        cl.monitor.tick(now)
        newly = []
        for ni in cl.monitor.check(now):
            node = cl.nodes[ni]
            if node.alive:
                node.kill()
            if ni not in self.declared_dead:
                self.declared_dead.append(ni)
                newly.append(ni)
                cl.metrics.bump("dead_nodes_declared")
                # fail-stop is the terminal fail-slow state: the scorer
                # pins the node 'dead' so steering penalties survive
                # even after its service samples age out
                cl.scorer.mark_dead(f"node{ni}")
        return newly

    # --------------------------------------------------------------- repair
    def run_once(self, now: float | None = None) -> dict:
        """Detect + synchronously repair every under-replicated chain.
        Returns the storm's accounting."""
        newly = self.detect(now)
        cl = self.cluster
        stats = {"declared_dead": newly, "chunks_repaired": 0,
                 "blocks_copied": 0, "unplaceable": 0}
        with cl._lock:
            chains = list(cl._chains.items())
        for chunk, chain in chains:
            for dead in [ni for ni in chain if not cl.nodes[ni].alive]:
                copied = self._repair_chunk(chunk, dead)
                if copied is None:
                    stats["unplaceable"] += 1
                else:
                    stats["chunks_repaired"] += 1
                    stats["blocks_copied"] += copied
        return stats

    def request(self, chunk: int, dead: int) -> bool:
        """Queue one chunk repair on the shared pool (deduplicated)."""
        job = (chunk, dead)
        with self._cond:
            if self._stop or self.pool is None or job in self._queued:
                return False
            self._queued.add(job)
            self._inflight += 1
            self.pool.submit(self, job)
        return True

    def wait_idle(self, timeout: float = 30.0) -> bool:
        with self._cond:
            return self._cond.wait_for(lambda: self._inflight == 0,
                                       timeout=timeout)

    # ----------------------------------------- pool-participant interface
    def _evict_slot(self, job: tuple[int, int]) -> None:
        try:
            self._repair_chunk(*job)
        finally:
            with self._cond:
                self._queued.discard(job)

    def _complete_eviction(self, n: int = 1) -> None:
        with self._cond:
            self._inflight -= n
            self._cond.notify_all()

    def _repair_chunk(self, chunk: int, dead: int) -> int | None:
        """Regenerate ``dead``'s copy of ``chunk`` onto a placement-
        chosen survivor.  Only ledger'd (ever-acked) blocks move — the
        copy that matches the cluster crc sources each one.  Returns
        blocks copied, or None when no target exists (the chain stays
        under-replicated and keeps showing up in ``scrub``)."""
        cl = self.cluster
        chain = cl._chains.get(chunk)
        if chain is None or dead not in chain:
            return 0
        alive = [n.idx for n in cl.nodes if n.alive and not n.partitioned]
        target = cl.placement.replacement(chain, dead, alive)
        if target is None:
            cl.metrics.bump("rereplication_unplaceable")
            return None
        tnode = cl.nodes[target]
        base = chunk * cl.cfg.chunk_blocks
        top = min(base + cl.cfg.chunk_blocks, cl.n_lbas)
        copied = 0
        for lba in range(base, top):
            want = cl._crcs.get(lba)
            if want is None:
                continue                      # never acked: nothing to move
            data = None
            for ni in chain:
                if ni == dead or ni not in alive:
                    continue
                got = cl.nodes[ni].volume.read(lba)
                if cl._crc(got) == want:
                    data = got
                    break
            if data is None:
                cl.metrics.bump("rereplication_failed_blocks")
                continue
            tnode.deliver(cl.block_size, cl._now())
            tnode.volume.write(lba, data)
            copied += 1
        chain[chain.index(dead)] = target
        cl.placement.transfer(dead, target, copied)
        cl.metrics.bump("rereplicated_chunks")
        cl.metrics.bump("rereplicated_blocks", copied)
        return copied

    def repair_divergent(self, sample_every: int = 1) -> int:
        """Partition-heal convergence: rewrite every sampled block whose
        live copy disagrees with the cluster ledger from a verified
        sibling (the cross-node analogue of ``ReplicaResyncer`` repair;
        counted as ``resync_repairs``)."""
        cl = self.cluster
        repaired = 0
        with cl._lock:
            chains = list(cl._chains.items())
        for chunk, chain in chains:
            base = chunk * cl.cfg.chunk_blocks
            top = min(base + cl.cfg.chunk_blocks, cl.n_lbas)
            for lba in range(base, top, sample_every):
                want = cl._crcs.get(lba)
                if want is None:
                    continue
                good, bad = None, []
                for ni in chain:
                    node = cl.nodes[ni]
                    if not node.alive or node.partitioned:
                        continue
                    data = node.volume.read(lba)
                    if cl._crc(data) == want:
                        good = data
                    else:
                        bad.append(ni)
                if good is None or not bad:
                    continue
                for ni in bad:
                    node = cl.nodes[ni]
                    node.deliver(cl.block_size, cl._now())
                    node.volume.write(lba, good)
                    repaired += 1
        if repaired:
            cl.metrics.bump("resync_repairs", repaired)
        return repaired

    def close(self) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify_all()
            self._cond.wait_for(lambda: self._inflight == 0, timeout=10.0)
        if self.pool is not None:
            dropped = self.pool.unregister(self)
            if dropped:
                self._complete_eviction(len(dropped))


def make_cluster(policy: str = "caiti", *, n_lbas: int, n_nodes: int = 3,
                 replication_k: int = 2, chunk_blocks: int = 64,
                 racks: int = 2, placement: str = "spread",
                 node_shards: int = 2, stripe_blocks: int = 16,
                 cache_bytes: int = 8 << 20, shared_workers: int = 2,
                 journal_slots: int = 16, journal_span: int = 8,
                 backend: str = "ram", path: str | None = None,
                 block_size: int = 4096,
                 net_latency_us: float = 5.0, net_mb_s: float = 3000.0,
                 heartbeat_timeout: float = 5.0, now_fn=None,
                 max_inflight: int = 16, aio_workers: int = 2,
                 read_tier_bytes: int = 0,
                 hedge_delay_us: float = 0.0,
                 tenants: list[TenantSpec] | None = None,
                 autotune=None) -> ClusterVolume:
    """Build a cluster volume: ``n_nodes`` member ``StripedVolume``s
    (each unreplicated internally — the CLUSTER provides redundancy; its
    crc ledger does the verification) behind simulated links, spread
    over ``racks`` racks round-robin.  ``path`` prefixes file-backed
    members (``{path}.node{i}``).  ``now_fn`` injects the heartbeat
    clock (tests drive a manual one)."""
    cfg = ClusterConfig(n_lbas=n_lbas, replication_k=replication_k,
                        chunk_blocks=chunk_blocks, block_size=block_size,
                        heartbeat_timeout=heartbeat_timeout,
                        max_inflight=max_inflight, aio_workers=aio_workers,
                        hedge_delay_us=hedge_delay_us)
    infos = [NodeInfo(f"node{i}", rack=i % max(1, racks))
             for i in range(n_nodes)]
    place = PlacementPolicy(infos, k=replication_k, policy=placement)
    nodes = []
    for i, info in enumerate(infos):
        vol = make_volume(policy, n_lbas=n_lbas, n_shards=node_shards,
                          stripe_blocks=stripe_blocks, replicas=1,
                          block_size=block_size, cache_bytes=cache_bytes,
                          shared_workers=shared_workers,
                          journal_slots=journal_slots,
                          journal_span=journal_span, backend=backend,
                          path=f"{path}.node{i}" if path else None,
                          read_tier_bytes=read_tier_bytes,
                          aio_workers=0)
        nodes.append(ClusterNode(
            i, info.name, vol, rack=info.rack,
            link=NetLink(latency_us=net_latency_us, mb_s=net_mb_s),
            now_fn=now_fn))
    cl = ClusterVolume(nodes, cfg, placement=place, now_fn=now_fn)
    for t in (tenants or []):
        cl.add_tenant(t.name, weight=t.weight, rate_mbps=t.rate_mbps,
                      burst_bytes=t.burst_bytes)
    # cluster-scope control plane: autotune=True attaches the stock
    # controller; a Controller instance attaches that one
    if autotune:
        cl.attach_autotuner(None if autotune is True else autotune)
    return cl
