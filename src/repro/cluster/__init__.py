"""repro.cluster — network-replicated multi-node volume layer.

Lifts the single-box ``StripedVolume`` to a cluster: each member node
runs the full paper stack (transit cache over BTT over PMem, chained-tx
journal, group commit) behind a virtual-time network link, and the
cluster layer adds HDFS-style chunk placement, pipelined chain
replication, crc-ledger verified failover reads, heartbeat failure
detection and automatic re-replication.

    make_cluster(...)      — N-node cluster volume factory
    ClusterVolume          — the logical device (write/read/fsync +
                             submit/poll async surface, same as
                             StripedVolume)
    ClusterConfig          — geometry + policy knobs
    PlacementPolicy        — chunk -> chain mapping (ring / spread /
                             balanced; rack- and load-aware)
    NodeInfo               — static member topology description
    ClusterNode, NetLink   — one member volume behind a simulated link
    HeartbeatMonitor       — staleness-based failure suspicion
    ReReplicator           — dead-node detection + chunk regeneration
                             (cluster sibling of ReplicaResyncer)
    ClusterError and friends — delivery / availability failures
"""
from .cluster import ClusterConfig, ClusterVolume, ReReplicator, make_cluster
from .node import (ClusterError, ClusterNode, ClusterUnavailableError,
                   HeartbeatMonitor, NetLink, NetworkPartitionError,
                   NodeDownError)
from .placement import POLICIES, NodeInfo, PlacementPolicy

__all__ = [
    "ClusterConfig", "ClusterVolume", "ReReplicator", "make_cluster",
    "ClusterError", "ClusterNode", "ClusterUnavailableError",
    "HeartbeatMonitor", "NetLink", "NetworkPartitionError",
    "NodeDownError", "POLICIES", "NodeInfo", "PlacementPolicy",
]
