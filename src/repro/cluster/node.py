"""Cluster member: one striped volume behind a simulated network link.

A :class:`ClusterNode` wraps a per-node ``StripedVolume`` (the paper's
full stack: transit cache over BTT over PMem, journaled and striped)
behind a :class:`NetLink` that models the wire in **virtual time** —
the same technique as ``core/sim.py``: latency and bandwidth are
accounted, never slept, so the functional layer stays single-core fast
and deterministic while the performance contrasts live in ``SimCluster``.

Failure modes are explicit and separable:

  * ``kill()`` — fail-stop: the node's process is gone.  Every delivery
    raises :class:`NodeDownError`; the data on its volume is considered
    lost to the cluster (re-replication regenerates it onto survivors);
  * ``partition(True)`` — the node is healthy but unreachable:
    deliveries raise :class:`NetworkPartitionError` until the partition
    heals.  A heal brings the old data back, possibly divergent — the
    cluster's crc ledger arbitrates;
  * heartbeats — every successful delivery (and every
    :meth:`HeartbeatMonitor.tick`) stamps ``last_beat``; a node whose
    beat goes stale past the timeout is *suspected dead* regardless of
    why (fail-stop and partition look identical from the outside, the
    classic failure-detector ambiguity), and the ReReplicator treats
    suspicion as death — HDFS semantics.

Clocks are injected (``now_fn``): tests drive a manual clock so the
heartbeat timeout sweep is deterministic; production defaults to
``time.monotonic``.
"""
from __future__ import annotations

import time


class ClusterError(RuntimeError):
    """Base class for cluster-layer delivery failures."""


class NodeDownError(ClusterError):
    """Delivery to a fail-stopped (killed) node."""


class NetworkPartitionError(ClusterError):
    """Delivery to a partitioned (unreachable but alive) node."""


class ClusterUnavailableError(ClusterError):
    """No live replica could serve the request."""


class NetLink:
    """Virtual-time network pipe: ``latency_us`` per message plus
    ``mb_s`` streaming bandwidth (MB/s == bytes/us, so the math is exact
    in virtual time).  Transfers are *accounted*, not slept."""

    __slots__ = ("latency_us", "mb_s", "bytes_moved", "msgs", "vtime_us")

    def __init__(self, latency_us: float = 5.0, mb_s: float = 3000.0) -> None:
        assert mb_s > 0
        self.latency_us = latency_us
        self.mb_s = mb_s
        self.bytes_moved = 0
        self.msgs = 0
        self.vtime_us = 0.0

    def xfer_us(self, nbytes: int) -> float:
        return self.latency_us + nbytes / self.mb_s

    def account(self, nbytes: int) -> float:
        """Record one transfer; returns its virtual duration (us)."""
        dur = self.xfer_us(nbytes)
        self.bytes_moved += nbytes
        self.msgs += 1
        self.vtime_us += dur
        return dur

    def stats(self) -> dict:
        return {"bytes_moved": self.bytes_moved, "msgs": self.msgs,
                "vtime_us": round(self.vtime_us, 3)}


class ClusterNode:
    """One datanode: volume + link + liveness state."""

    def __init__(self, idx: int, name: str, volume, *, rack: int = 0,
                 link: NetLink | None = None, now_fn=None) -> None:
        self.idx = idx
        self.name = name
        self.volume = volume
        self.rack = rack
        self.link = link or NetLink()
        self._now = now_fn or time.monotonic
        self.alive = True
        self.partitioned = False
        self.last_beat = self._now()

    # ------------------------------------------------------------- liveness
    def beat(self, now: float | None = None) -> None:
        self.last_beat = self._now() if now is None else now

    def kill(self) -> None:
        self.alive = False

    def partition(self, flag: bool = True) -> None:
        self.partitioned = flag

    # ------------------------------------------------------------- delivery
    def deliver(self, nbytes: int, now: float | None = None) -> float:
        """One message of ``nbytes`` arrives over the link.  Raises when
        the node cannot receive it; otherwise accounts the transfer,
        refreshes the heartbeat and returns the virtual duration."""
        if not self.alive:
            raise NodeDownError(f"node {self.name} is down")
        if self.partitioned:
            raise NetworkPartitionError(f"node {self.name} is partitioned")
        dur = self.link.account(nbytes)
        self.beat(now)
        return dur

    def close(self) -> None:
        # a killed node's volume still owns threads (eviction pool, aio
        # workers) in this process — release them quietly; its media is
        # already considered lost to the cluster
        try:
            self.volume.close()
        except Exception:
            if self.alive:
                raise

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        st = "up" if self.alive else "DOWN"
        if self.partitioned:
            st += "/partitioned"
        return f"ClusterNode({self.name}, rack={self.rack}, {st})"


class HeartbeatMonitor:
    """Suspicion by staleness: a node whose last beat is older than
    ``timeout`` is suspected dead.  The monitor never reads ``alive``
    directly — detection goes through the beat channel only, so a
    partition and a crash are (correctly) indistinguishable to it."""

    def __init__(self, nodes: list[ClusterNode], *, timeout: float = 5.0,
                 now_fn=None) -> None:
        self.nodes = nodes
        self.timeout = timeout
        self._now = now_fn or time.monotonic

    def tick(self, now: float | None = None) -> None:
        """One heartbeat exchange: every reachable node beats.  Dead and
        partitioned nodes cannot answer, so their stamps go stale."""
        now = self._now() if now is None else now
        for n in self.nodes:
            if n.alive and not n.partitioned:
                n.beat(now)

    def check(self, now: float | None = None) -> list[int]:
        """Indices of suspected-dead nodes (stale beats)."""
        now = self._now() if now is None else now
        return [n.idx for n in self.nodes
                if now - n.last_beat > self.timeout]
