"""Topology-aware block placement for the distributed cluster volume.

HDFS-style: the cluster LBA space is carved into fixed *chunks* of
``chunk_blocks`` consecutive blocks, and every chunk maps to an ordered
**chain** of K nodes — the write pipeline (primary first, replicas
downstream).  The chain is the unit of replication, failover and
re-replication; blocks inside a chunk never split across chains, so a
``write_multi`` that stays inside one chunk keeps the per-node
chained-tx journal's whole-object atomicity end to end.

Three policies, all deterministic for a given assignment order:

  ``ring``      primary = ``chunk % n``, replicas on the next indices —
                the baseline with no topology awareness;
  ``spread``    rack-aware spread-K (the HDFS default): the primary
                rotates by chunk, each replica maximizes rack diversity
                against the chain so far, capacity-balanced (fewest
                placed blocks wins) within the eligible set;
  ``balanced``  capacity *and* load balanced everywhere: every member —
                primary included — is the candidate minimizing
                ``placed_blocks + load_weight * svc_ewma_us``, with rack
                diversity still preferred.  ``observe_load`` feeds the
                service-time EWMAs (the same fail-slow signal
                ``Metrics.per_node`` surfaces), so a limping node stops
                attracting new chains before it ever fails a heartbeat.

:meth:`PlacementPolicy.replacement` picks the re-replication target for
a chain that lost a member: an alive node outside the chain, rack
diversity against the survivors first, then least-placed.
"""
from __future__ import annotations

from repro.core.metrics import EWMA_ALPHA

POLICIES = ("ring", "spread", "balanced")


class NodeInfo:
    """Static description of one cluster member (topology + capacity)."""

    __slots__ = ("name", "rack", "socket", "capacity_blocks")

    def __init__(self, name: str, *, rack: int = 0, socket: int = 0,
                 capacity_blocks: int | None = None) -> None:
        self.name = name
        self.rack = rack
        self.socket = socket
        self.capacity_blocks = capacity_blocks

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"NodeInfo({self.name!r}, rack={self.rack})"


class PlacementPolicy:
    """Maps chunk ids to node chains; tracks placed blocks and load."""

    def __init__(self, nodes: list[NodeInfo], *, k: int = 2,
                 policy: str = "spread",
                 load_weight: float = 1.0) -> None:
        assert policy in POLICIES, f"unknown placement policy {policy!r}"
        assert nodes, "placement needs at least one node"
        assert 1 <= k <= len(nodes), \
            f"replication factor k={k} needs k distinct nodes " \
            f"(have {len(nodes)})"
        self.nodes = list(nodes)
        self.k = min(k, len(self.nodes))
        self.policy = policy
        self.load_weight = load_weight
        self.placed = [0] * len(self.nodes)      # blocks placed per node
        self.load = [0.0] * len(self.nodes)      # svc-ewma us per node
        # fail-slow steering: node -> score multiplier (>= 1.0) pushed
        # from the cluster's ShardScorer — a limping node's candidacy
        # costs more under EVERY policy, not just 'balanced'
        self.penalty = [1.0] * len(self.nodes)
        self.steered_placements = 0

    # ------------------------------------------------------------- feedback
    def observe_load(self, node: int, svc_us: float) -> None:
        """Fold one service time into ``node``'s load EWMA (same alpha
        as ``Metrics.observe`` so the two views agree)."""
        self.load[node] += EWMA_ALPHA * (svc_us - self.load[node])

    def set_penalties(self, penalties: dict[int, float]) -> None:
        """Install the scorer's per-node multipliers (healthy 1x,
        limping/dead higher); missing nodes reset to 1.0."""
        changed = 0
        for i in range(len(self.nodes)):
            p = max(1.0, float(penalties.get(i, 1.0)))
            if p > 1.0 and self.penalty[i] <= 1.0:
                changed += 1
            self.penalty[i] = p
        self.steered_placements += changed

    def _score(self, i: int) -> float:
        """Lower is better: capacity first, load-shaded for 'balanced',
        limping-penalized always (a 25x-slow node should not win a chain
        just because it is empty — it is empty BECAUSE it is slow)."""
        s = float(self.placed[i])
        if self.policy == "balanced":
            s += self.load_weight * self.load[i]
        return (s + 1.0) * self.penalty[i] - 1.0

    # ------------------------------------------------------------ assignment
    def assign(self, chunk_id: int, n_blocks: int = 0,
               eligible: list[int] | None = None) -> list[int]:
        """The ordered chain for ``chunk_id`` (primary first), recording
        ``n_blocks`` of placed capacity on every member.  ``eligible``
        restricts candidates (re-assignment after node death)."""
        n = len(self.nodes)
        cand_all = list(range(n)) if eligible is None else list(eligible)
        assert cand_all, "no eligible nodes"
        k = min(self.k, len(cand_all))
        if self.policy == "ring":
            chain = [cand_all[(chunk_id + j) % len(cand_all)]
                     for j in range(k)]
        else:
            if self.policy == "balanced":
                primary = min(cand_all, key=lambda i: (self._score(i), i))
            else:                      # spread: rotate primaries by chunk
                primary = cand_all[chunk_id % len(cand_all)]
            chain = [primary]
            racks = {self.nodes[primary].rack}
            while len(chain) < k:
                rest = [i for i in cand_all if i not in chain]
                fresh = [i for i in rest if self.nodes[i].rack not in racks]
                pool = fresh or rest
                nxt = min(pool, key=lambda i: (self._score(i), i))
                chain.append(nxt)
                racks.add(self.nodes[nxt].rack)
        for i in chain:
            self.placed[i] += n_blocks
        return chain

    def replacement(self, chain: list[int], dead: int,
                    alive: list[int]) -> int | None:
        """The node to regenerate ``dead``'s copy of a chain onto: alive,
        outside the chain, rack-diverse against the survivors if
        possible, least placed otherwise.  None when every alive node
        already holds a copy (the chain stays under-replicated)."""
        survivors = [i for i in chain if i != dead and i in alive]
        cand = [i for i in alive if i not in chain]
        if not cand:
            return None
        racks = {self.nodes[i].rack for i in survivors}
        fresh = [i for i in cand if self.nodes[i].rack not in racks]
        pool = fresh or cand
        return min(pool, key=lambda i: (self._score(i), i))

    def transfer(self, src: int, dst: int, n_blocks: int) -> None:
        """Re-replication accounting: ``n_blocks`` moved off ``src``'s
        ledger onto ``dst``."""
        self.placed[src] = max(0, self.placed[src] - n_blocks)
        self.placed[dst] += n_blocks

    # ---------------------------------------------------------------- stats
    def rack_diversity(self, chain: list[int]) -> int:
        return len({self.nodes[i].rack for i in chain})

    def balance(self) -> float:
        """max/mean placed blocks — 1.0 is perfectly even."""
        total = sum(self.placed)
        if not total:
            return 1.0
        mean = total / len(self.placed)
        return max(self.placed) / mean

    def stats(self) -> dict:
        return {"policy": self.policy, "k": self.k,
                "placed": list(self.placed),
                "load_ewma_us": [round(x, 3) for x in self.load],
                "penalty": list(self.penalty),
                "steered_placements": self.steered_placements,
                "balance": self.balance()}
