"""Family-dispatching model API.

Every architecture exposes the same five entry points:
    init(rng) -> params
    loss(params, batch, ctx) -> scalar          (train_step builds on this)
    prefill(params, batch, ctx) -> (logits, cache/state)
    decode_step(params, cache, token, pos, ctx) -> (logits, cache/state)
    cache_shape(B, S) -> pytree of ShapeDtypeStruct (no allocation)
plus ``input_specs(shape)`` producing ShapeDtypeStruct stand-ins for every
model input of the given (train/prefill/decode) shape — the dry-run contract.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from .common import ModelConfig, ShapeCfg
from . import rglru, transformer, xlstm


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    forward: Callable
    prefill: Callable
    decode_step: Callable
    make_cache: Callable          # (B, S) -> concrete zeroed cache
    cache_shape: Callable         # (B, S) -> ShapeDtypeStruct pytree

    # ---------------------------------------------------------- input specs
    def input_specs(self, shape: ShapeCfg) -> dict:
        cfg = self.cfg
        B, S = shape.batch, shape.seq
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        extras = {}
        if cfg.family == "encdec":
            extras["frames"] = sds((B, cfg.enc_seq, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            extras["image_embeds"] = sds((B, cfg.n_img_tokens, cfg.d_model),
                                         cfg.dtype)
        if shape.kind == "train":
            return {"batch": {"tokens": sds((B, S), i32),
                              "targets": sds((B, S), i32), **extras}}
        if shape.kind == "prefill":
            return {"batch": {"tokens": sds((B, S), i32), **extras}}
        # decode: one new token against an S-token cache
        return {"cache": self.cache_shape(B, S),
                "token": sds((B,), i32),
                "pos": sds((B,), i32)}

    def param_shape(self, rng=None):
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, rng)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "ssm":
        return Model(
            cfg=cfg,
            init=partial(xlstm.init_xlstm, cfg),
            loss=lambda p, b, ctx=None: xlstm.xlstm_loss(p, b, cfg, ctx),
            forward=lambda p, b, ctx=None: xlstm.xlstm_forward(p, b, cfg, ctx),
            prefill=lambda p, b, ctx=None, s_max=None:
                xlstm.xlstm_prefill(p, b, cfg, ctx),
            decode_step=lambda p, c, t, pos, ctx=None:
                xlstm.xlstm_decode_step(p, c, t, pos, cfg, ctx),
            make_cache=lambda B, S: xlstm.xlstm_states(cfg, B),
            cache_shape=lambda B, S: jax.eval_shape(
                lambda: xlstm.xlstm_states(cfg, B)),
        )
    if cfg.family == "hybrid":
        return Model(
            cfg=cfg,
            init=partial(rglru.init_rg, cfg),
            loss=lambda p, b, ctx=None: rglru.rg_loss(p, b, cfg, ctx),
            forward=lambda p, b, ctx=None: rglru.rg_forward(p, b, cfg, ctx),
            prefill=lambda p, b, ctx=None, s_max=None:
                rglru.rg_prefill(p, b, cfg, ctx),
            decode_step=lambda p, c, t, pos, ctx=None:
                rglru.rg_decode_step(p, c, t, pos, cfg, ctx),
            make_cache=lambda B, S: rglru.rg_states(cfg, B),
            cache_shape=lambda B, S: jax.eval_shape(
                lambda: rglru.rg_states(cfg, B)),
        )
    return Model(
        cfg=cfg,
        init=partial(transformer.init_lm, cfg),
        loss=lambda p, b, ctx=None: transformer.lm_loss(p, b, cfg, ctx),
        forward=lambda p, b, ctx=None: transformer.lm_forward(p, b, cfg, ctx),
        prefill=lambda p, b, ctx=None, s_max=None:
            transformer.lm_prefill(p, b, cfg, ctx, s_max=s_max),
        decode_step=lambda p, c, t, pos, ctx=None:
            transformer.lm_decode_step(p, c, t, pos, cfg, ctx),
        make_cache=lambda B, S: transformer.make_cache(cfg, B, S),
        cache_shape=lambda B, S: jax.eval_shape(
            lambda: transformer.make_cache(cfg, B, S)),
    )
