"""Shared model primitives: norms, RoPE, flash-pattern chunked attention
(XLA path), GQA, SwiGLU/GELU MLPs, and the capacity-routed MoE block
(expert-parallel over the TP axis via shard_map).

Everything is pure-functional over explicit param pytrees; parameter layout
conventions (documented here because sharding rules key off them):

  attn:  wq (D, H*hd)   wk/wv (D, Hkv*hd)   wo (H*hd, D)   [+ optional biases]
  mlp:   wg/wu (D, F)   wd (F, D)
  moe:   router (D, E)  wg/wu (E, D, F)     wd (E, F, D)
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import shard_map


# --------------------------------------------------------------------- norms
def rms_norm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return (((x32 - mu) * jax.lax.rsqrt(var + eps)) * scale + bias).astype(x.dtype)


def apply_norm(x, p, kind: str):
    if kind == "ln":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def init_norm(d: int, kind: str):
    if kind == "ln":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------- RoPE
def rope(x, positions, theta: float):
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]                                 # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions, d: int, dtype):
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) *
                    jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------- chunked attention
def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def chunked_attention(q, k, v, *, q_pos, k_pos, causal: bool, window: int = 0,
                      kv_mask=None, chunk: int = 512, dtype=jnp.bfloat16):
    """Online-softmax attention, scanning KV in chunks (flash pattern in XLA).

    q: (B, T, H, hd);  k, v: (B, S, Hkv, hd);  q_pos: (B, T);  k_pos: (B, S)
    kv_mask: optional (B, S) bool of valid kv entries.
    Memory is bounded by (B, T, H, chunk) — the TPU Pallas kernel in
    repro.kernels implements the same contract with VMEM tiles.

    GQA is computed GROUPED ("btgrd,bcgd->btgrc"): the KV is never
    repeated to H heads nor upcast to f32 in HBM — operands stay bf16 and
    the MXU accumulates in f32 (preferred_element_type).  The repeat+cast
    used to dominate the HBM roofline term of GQA archs.
    """
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    n_rep = H // Hkv
    scale = 1.0 / math.sqrt(hd)
    if S <= max(chunk, 2048) or S % chunk != 0:
        return _dense_attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                                causal=causal, window=window, kv_mask=kv_mask,
                                dtype=dtype)
    n_chunks = S // chunk
    kc = k.reshape(B, n_chunks, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    kpc = k_pos.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    mc = (kv_mask.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
          if kv_mask is not None else jnp.ones((n_chunks, B, chunk), bool))
    qg = q.reshape(B, T, Hkv, n_rep, hd)

    def body(carry, xs):
        m, l, acc = carry                  # (B,T,g,r) / (B,T,g,r,hd)
        kch, vch, kp, msk = xs
        s = jnp.einsum("btgrd,bcgd->btgrc", qg, kch,
                       preferred_element_type=jnp.float32) * scale
        valid = msk[:, None, :]                              # (B, 1, C)
        if causal:
            valid = valid & (kp[:, None, :] <= q_pos[:, :, None])
        if window:
            valid = valid & (q_pos[:, :, None] - kp[:, None, :] < window)
        vmask = valid[:, :, None, None, :]                   # (B,T,1,1,C)
        s = jnp.where(vmask, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(vmask, p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "btgrc,bcgd->btgrd", p.astype(dtype), vch,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, T, Hkv, n_rep), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, T, Hkv, n_rep), jnp.float32)
    a0 = jnp.zeros((B, T, Hkv, n_rep, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, kpc, mc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, T, H, hd).astype(dtype)


def sharded_attention(q, k, v, *, q_pos, k_pos, causal: bool,
                      window: int = 0, kv_mask=None, chunk: int = 512,
                      dtype=jnp.bfloat16, ctx=None):
    """chunked_attention with explicit Q-sequence sharding over the model
    axis when the head count does not divide TP.

    Why: GSPMD shards attention intermediates by head; with H % tp != 0
    (deepseek 56 heads on a 16-way axis) it *replicates* the (B,T,H,S)
    score tensors on every device — the dominant HBM term of the train_4k
    roofline.  Sharding the query/sequence axis instead keeps per-device
    scores at 1/tp and costs one all-gather of the (small) K/V plus one of
    the (B,T,hidden) output.
    """
    if (ctx is None or getattr(ctx, "mesh", None) is None
            or ctx.model_axis is None):
        return chunked_attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                                 causal=causal, window=window,
                                 kv_mask=kv_mask, chunk=chunk, dtype=dtype)
    tp = ctx.mesh.shape[ctx.model_axis]
    B, T, H, hd = q.shape
    if H % tp == 0 or T % tp != 0:
        return chunked_attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                                 causal=causal, window=window,
                                 kv_mask=kv_mask, chunk=chunk, dtype=dtype)
    axis = ctx.model_axis
    b = ctx.batch_axes if ctx.batch_axes else None
    msk = kv_mask if kv_mask is not None else \
        jnp.ones(k.shape[:2], dtype=bool)

    def f(q_l, qp_l, k_l, v_l, kp_l, m_l):
        S_l = k_l.shape[1]
        c = chunk if S_l % chunk == 0 else S_l
        return chunked_attention(q_l, k_l, v_l, q_pos=qp_l, k_pos=kp_l,
                                 causal=causal, window=window, kv_mask=m_l,
                                 chunk=c, dtype=dtype)

    return shard_map(
        f, mesh=ctx.mesh,
        in_specs=(P(b, axis, None, None), P(b, axis),
                  P(b, None, None, None), P(b, None, None, None),
                  P(b, None), P(b, None)),
        out_specs=P(b, axis, None, None),
        check_vma=False,
    )(q, q_pos, k, v, k_pos, msk)


def _dense_attention(q, k, v, *, q_pos, k_pos, causal, window, kv_mask, dtype):
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    n_rep = H // Hkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, T, Hkv, n_rep, hd)
    s = jnp.einsum("btgrd,bsgd->btgrs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.ones((B, T, S), bool)
    if kv_mask is not None:
        valid = valid & kv_mask[:, None, :]
    if causal:
        valid = valid & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        valid = valid & (q_pos[:, :, None] - k_pos[:, None, :] < window)
    vmask = valid[:, :, None, None, :]
    s = jnp.where(vmask, s, -jnp.inf)
    # fully-masked rows (can happen for padded kv) -> uniform-zero output
    m = s.max(axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.where(vmask, jnp.exp(s - m), 0.0)
    p = e / jnp.maximum(e.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("btgrs,bsgd->btgrd", p.astype(dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, T, H, hd).astype(dtype)


def decode_update_and_attend(q, cache_k, cache_v, cache_pos, new_k, new_v,
                             pos, *, window: int, ctx, chunk: int, dtype):
    """One decode step against an S-sharded KV cache, with the new token's
    K/V scattered INSIDE the shard_map.

    Why: the cache's S axis is sharded over 'model'; a batch-indexed
    ``.at[b, slot].set`` outside the shard_map is a dynamic scatter across a
    sharded axis — GSPMD falls back to 'involuntary full rematerialization'
    (replicate + repartition the whole multi-GB cache, per layer, per
    token).  Doing the write shard-locally (the owning shard applies it,
    the rest no-op) removes that traffic entirely; attention then merges
    per-shard online-softmax stats with one tiny psum, flash-decoding
    style.

    q: (B,1,H,hd); cache_k/v: (B,S,Hkv,hd); cache_pos: (B,S);
    new_k/v: (B,1,Hkv,hd); pos: (B,).
    Returns (attn_out (B,1,H,hd), ck, cv, cpos).
    """
    B, T, H, hd = q.shape
    S = cache_k.shape[1]
    if (ctx is None or ctx.mesh is None or ctx.model_axis is None
            or S % ctx.mesh.shape[ctx.model_axis] != 0):
        bidx = jnp.arange(B)
        slot = pos % S if window else pos
        ck = cache_k.at[bidx, slot].set(new_k[:, 0].astype(cache_k.dtype))
        cv = cache_v.at[bidx, slot].set(new_v[:, 0].astype(cache_v.dtype))
        cpos = cache_pos.at[bidx, slot].set(pos)
        out = decode_attention(q, ck, cv, k_pos=cpos, pos=pos, window=window,
                               kv_mask=cpos >= 0, ctx=ctx, chunk=chunk,
                               dtype=dtype)
        return out, ck, cv, cpos
    axis = ctx.model_axis
    tp = ctx.mesh.shape[axis]
    bspec = ctx.batch_axes if ctx.batch_axes else None
    Hkv = cache_k.shape[2]
    n_rep = H // Hkv
    scale = 1.0 / math.sqrt(hd)
    S_l = S // tp

    def f(q_l, k_l, v_l, cp_l, nk_l, nv_l, pos_l):
        Bl = q_l.shape[0]
        bidx = jnp.arange(Bl)
        shard = jax.lax.axis_index(axis)
        slot = pos_l % S if window else pos_l
        local = slot - shard * S_l
        in_range = (local >= 0) & (local < S_l)
        idx = jnp.clip(local, 0, S_l - 1)
        cur_k = k_l[bidx, idx]
        cur_v = v_l[bidx, idx]
        cur_p = cp_l[bidx, idx]
        k_l = k_l.at[bidx, idx].set(jnp.where(
            in_range[:, None, None], nk_l[:, 0].astype(k_l.dtype), cur_k))
        v_l = v_l.at[bidx, idx].set(jnp.where(
            in_range[:, None, None], nv_l[:, 0].astype(v_l.dtype), cur_v))
        cp_l = cp_l.at[bidx, idx].set(jnp.where(in_range, pos_l, cur_p))
        # ---- local online-softmax stats over this shard's KV ------------
        # GQA grouped: KV never repeated/upcast (bf16 operands, f32 accum)
        qg = q_l.reshape(Bl, T, Hkv, n_rep, hd)
        s = jnp.einsum("btgrd,bcgd->btgrc", qg, k_l,
                       preferred_element_type=jnp.float32) * scale
        valid = (cp_l >= 0)[:, None, :] & \
            (cp_l[:, None, :] <= pos_l[:, None, None])
        if window:
            valid = valid & (pos_l[:, None, None] - cp_l[:, None, :] < window)
        vmask = valid[:, :, None, None, :]
        s = jnp.where(vmask, s, -jnp.inf)
        m = s.max(axis=-1)
        m_safe = jnp.where(jnp.isfinite(m), m, -1e30)
        p = jnp.where(vmask, jnp.exp(s - m_safe[..., None]), 0.0)
        l = p.sum(axis=-1)
        acc = jnp.einsum("btgrc,bcgd->btgrd", p.astype(dtype), v_l,
                         preferred_element_type=jnp.float32)
        m_all = jax.lax.pmax(m_safe, axis)
        corr = jnp.exp(m_safe - m_all)
        l_all = jax.lax.psum(l * corr, axis)
        acc_all = jax.lax.psum(acc * corr[..., None], axis)
        out = acc_all / jnp.maximum(l_all, 1e-30)[..., None]
        return out.reshape(Bl, T, H, hd).astype(dtype), k_l, v_l, cp_l

    return shard_map(
        f, mesh=ctx.mesh,
        in_specs=(P(bspec, None, None, None), P(bspec, axis, None, None),
                  P(bspec, axis, None, None), P(bspec, axis),
                  P(bspec, None, None, None), P(bspec, None, None, None),
                  P(bspec)),
        out_specs=(P(bspec, None, None, None), P(bspec, axis, None, None),
                   P(bspec, axis, None, None), P(bspec, axis)),
        check_vma=False,
    )(q, cache_k, cache_v, cache_pos, new_k, new_v, pos)


def decode_attention(q, k, v, *, k_pos, pos, window: int, kv_mask, ctx,
                     chunk: int, dtype):
    """Single-token decode attention with a sequence-sharded KV cache.

    Flash-decoding style TP: the cache's S axis is sharded over the model
    axis; every shard computes partial online-softmax stats over its local
    KV chunk for ALL heads, then stats are merged with one tiny psum of
    (m, l, acc) — the collective is O(B·H·hd), not O(S).  Falls back to the
    plain chunked path off-mesh.
    """
    B, T, H, hd = q.shape
    S = k.shape[1]
    if (ctx is None or ctx.mesh is None or ctx.model_axis is None
            or S % ctx.mesh.shape[ctx.model_axis] != 0):
        return chunked_attention(q, k, v, q_pos=pos[:, None], k_pos=k_pos,
                                 causal=True, window=window, kv_mask=kv_mask,
                                 chunk=chunk, dtype=dtype)
    axis = ctx.model_axis
    bspec = ctx.batch_axes if ctx.batch_axes else None
    Hkv = k.shape[2]
    n_rep = H // Hkv
    scale = 1.0 / math.sqrt(hd)

    def f(q_l, k_l, v_l, kp_l, pos_l, msk_l):
        Bl, T = q_l.shape[0], q_l.shape[1]
        qg = q_l.reshape(Bl, T, Hkv, n_rep, hd)
        s = jnp.einsum("btgrd,bcgd->btgrc", qg, k_l,
                       preferred_element_type=jnp.float32) * scale
        valid = msk_l[:, None, :] & (kp_l[:, None, :] <= pos_l[:, None, None])
        if window:
            valid = valid & (pos_l[:, None, None] - kp_l[:, None, :] < window)
        vmask = valid[:, :, None, None, :]
        s = jnp.where(vmask, s, -jnp.inf)
        m = s.max(axis=-1)
        m_safe = jnp.where(jnp.isfinite(m), m, -1e30)
        p = jnp.where(vmask, jnp.exp(s - m_safe[..., None]), 0.0)
        l = p.sum(axis=-1)
        acc = jnp.einsum("btgrc,bcgd->btgrd", p.astype(dtype), v_l,
                         preferred_element_type=jnp.float32)
        # merge partial stats across the model axis
        m_all = jax.lax.pmax(m_safe, axis)
        corr = jnp.exp(m_safe - m_all)
        l_all = jax.lax.psum(l * corr, axis)
        acc_all = jax.lax.psum(acc * corr[..., None], axis)
        out = acc_all / jnp.maximum(l_all, 1e-30)[..., None]
        return out.reshape(Bl, T, H, hd).astype(dtype)

    return shard_map(
        f, mesh=ctx.mesh,
        in_specs=(P(bspec, None, None, None), P(bspec, axis, None, None),
                  P(bspec, axis, None, None), P(bspec, axis), P(bspec),
                  P(bspec, axis)),
        out_specs=P(bspec, None, None, None),
        check_vma=False,
    )(q, k, v, k_pos, pos, kv_mask)


# ---------------------------------------------------------------- MLP blocks
def mlp_apply(x, p, act: str):
    if act == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    return h @ p["wd"]


def mlp_init(rng, d: int, f: int, act: str, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    if act == "swiglu":
        return {"wg": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
                "wu": (jax.random.normal(k2, (d, f)) * s_in).astype(dtype),
                "wd": (jax.random.normal(k3, (f, d)) * s_out).astype(dtype)}
    return {"wi": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
            "wd": (jax.random.normal(k3, (f, d)) * s_out).astype(dtype)}


# ----------------------------------------------------------------------- MoE
def moe_local(x, router, wg, wu, wd, *, top_k: int, capacity: int,
              n_experts: int, expert_offset):
    """Token-choice routing with per-expert top-C capacity, on LOCAL tokens
    and LOCAL experts. x: (T, D); wg/wu: (E_l, D, F); wd: (E_l, F, D).
    Returns the partial output (T, D) — caller psums across expert shards.
    """
    T, D = x.shape
    E_l = wg.shape[0]
    logits = (x @ router.astype(x.dtype)).astype(jnp.float32)     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, top_k)                       # (T, k)
    topw = topw / (jnp.sum(topw, axis=-1, keepdims=True) + 1e-9)
    local_ids = expert_offset + jnp.arange(E_l)
    hit = (topi[:, :, None] == local_ids[None, None, :])           # (T, k, E_l)
    score = jnp.where(hit, topw[:, :, None], 0.0).sum(axis=1)      # (T, E_l)
    gate, idx = jax.lax.top_k(score.T, capacity)                   # (E_l, C)
    xe = jnp.take(x, idx, axis=0)                                  # (E_l, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * \
        jnp.einsum("ecd,edf->ecf", xe, wu)
    ye = jnp.einsum("ecf,efd->ecd", h, wd)
    ye = ye * gate[..., None].astype(ye.dtype)
    out = jnp.zeros((T, D), ye.dtype).at[idx.reshape(-1)].add(
        ye.reshape(-1, D))
    return out


def moe_capacity(n_tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(math.ceil(n_tokens * top_k / n_experts * cf))
    c = max(c, min(4, n_tokens))       # decode floor: tiny T, skewed routing
    return max(1, min(n_tokens, c))


def moe_apply(x, p, moe_cfg, ctx):
    """x: (B, T, D). Experts sharded over the TP ('model') axis when a mesh
    context is present (EP-over-TP: activations are replicated across 'model'
    here, each shard computes its owned experts, outputs are psum-combined —
    the psum fuses with the usual TP output reduction).

    ZeRO-3 experts: when a 'data' axis exists and the per-expert FFN axis
    divides it, expert weights are additionally STORED sharded over 'data'
    and all-gathered per layer inside the shard_map (storage /dp, transient
    working set = one layer's experts).  A 235B MoE does not fit a 16 GB/
    chip pod otherwise — 29 GB/device of expert params at 16-way EP."""
    B, T, D = x.shape
    E, k, cf = moe_cfg.n_experts, moe_cfg.top_k, moe_cfg.capacity_factor
    if ctx is None or ctx.mesh is None or ctx.model_axis is None:
        cap = moe_capacity(B * T, k, E, cf)
        out = moe_local(x.reshape(-1, D), p["router"], p["wg"], p["wu"],
                        p["wd"], top_k=k, capacity=cap, n_experts=E,
                        expert_offset=0)
        return out.reshape(B, T, D)

    model_axis = ctx.model_axis
    tp = ctx.mesh.shape[model_axis]
    assert E % tp == 0, f"{E} experts not divisible by TP={tp}"
    batch_spec = ctx.batch_axes if ctx.batch_axes else None
    F = p["wg"].shape[-1]
    fsdp = None
    if "data" in ctx.mesh.shape and ctx.mesh.shape["data"] > 1 \
            and F % ctx.mesh.shape["data"] == 0:
        fsdp = "data"       # must mirror parallel.sharding's param rule
    wg_spec = P(model_axis, None, fsdp)
    wu_spec = P(model_axis, None, fsdp)
    wd_spec = P(model_axis, fsdp, None)

    def f(xl, router, wg, wu, wd):
        if fsdp is not None:
            # ZeRO-3 gather: materialize this layer's expert shard
            wg = jax.lax.all_gather(wg, fsdp, axis=2, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp, axis=2, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp, axis=1, tiled=True)
        Bl, Tl = xl.shape[0], xl.shape[1]
        cap = moe_capacity(Bl * Tl, k, E, cf)
        off = jax.lax.axis_index(model_axis) * (E // tp)
        out = moe_local(xl.reshape(-1, D), router, wg, wu, wd, top_k=k,
                        capacity=cap, n_experts=E, expert_offset=off)
        out = jax.lax.psum(out, model_axis)
        return out.reshape(Bl, Tl, D)

    return shard_map(
        f, mesh=ctx.mesh,
        in_specs=(P(batch_spec, None, None), P(None, None),
                  wg_spec, wu_spec, wd_spec),
        out_specs=P(batch_spec, None, None),
        check_vma=False,
    )(x, p["router"], p["wg"], p["wu"], p["wd"])


def moe_init(rng, d: int, moe_cfg, dtype):
    k0, k1, k2, k3 = jax.random.split(rng, 4)
    E, F = moe_cfg.n_experts, moe_cfg.d_expert
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(F)
    return {
        "router": (jax.random.normal(k0, (d, E)) * s_in).astype(jnp.float32),
        "wg": (jax.random.normal(k1, (E, d, F)) * s_in).astype(dtype),
        "wu": (jax.random.normal(k2, (E, d, F)) * s_in).astype(dtype),
        "wd": (jax.random.normal(k3, (E, F, d)) * s_out).astype(dtype),
    }


# ------------------------------------------------------------ attn (proj) ---
def attn_init(rng, d: int, n_heads: int, n_kv: int, hd: int, bias: bool, dtype):
    ks = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(d)
    p = {"wq": (jax.random.normal(ks[0], (d, n_heads * hd)) * s).astype(dtype),
         "wk": (jax.random.normal(ks[1], (d, n_kv * hd)) * s).astype(dtype),
         "wv": (jax.random.normal(ks[2], (d, n_kv * hd)) * s).astype(dtype),
         "wo": (jax.random.normal(ks[3], (n_heads * hd, d))
                * (1.0 / math.sqrt(n_heads * hd))).astype(dtype)}
    if bias:
        p["bq"] = jnp.zeros((n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((n_kv * hd,), dtype)
        p["bv"] = jnp.zeros((n_kv * hd,), dtype)
    return p


def qkv_proj(x, p, n_heads: int, n_kv: int, hd: int):
    B, T, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return (q.reshape(B, T, n_heads, hd), k.reshape(B, T, n_kv, hd),
            v.reshape(B, T, n_kv, hd))


def out_proj(attn_out, p):
    B, T = attn_out.shape[:2]
    return attn_out.reshape(B, T, -1) @ p["wo"]
