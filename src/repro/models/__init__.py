from .api import Model, build_model
from .common import MeshCtx, ModelConfig, MoECfg, ShapeCfg, SHAPES, \
    shape_applicable

__all__ = ["Model", "build_model", "MeshCtx", "ModelConfig", "MoECfg",
           "ShapeCfg", "SHAPES", "shape_applicable"]
