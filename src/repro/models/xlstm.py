"""xLSTM (Beck et al. 2024) — mLSTM (matrix memory) + sLSTM (scalar memory)
blocks with stabilized exponential gating, arranged 7:1 (mLSTM:sLSTM) as in
the published 1.3B config.  d_ff=0 per the assignment: blocks are
self-contained (no separate FFN).

State per layer (decode is O(1) in context length — this arch runs the
long_500k cell):
  mLSTM: C (B,H,dh,dh), n (B,H,dh), m (B,H)
  sLSTM: c,n,h (B,H,dh), m (B,H)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import MeshCtx, ModelConfig
from .layers import init_norm, rms_norm

GROUP = 8          # 7 mLSTM + 1 sLSTM per group


def _dense(rng, shape, scale, dtype):
    return (jax.random.normal(rng, shape) * scale).astype(dtype)


def init_mlstm(rng, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    s = 1.0 / math.sqrt(d)
    ks = jax.random.split(rng, 7)
    return {"ln": init_norm(d, "rms"),
            "wq": _dense(ks[0], (d, d), s, cfg.dtype),
            "wk": _dense(ks[1], (d, d), s, cfg.dtype),
            "wv": _dense(ks[2], (d, d), s, cfg.dtype),
            "wog": _dense(ks[3], (d, d), s, cfg.dtype),
            "wif": _dense(ks[4], (d, 2 * H), s, jnp.float32),
            "bif": jnp.concatenate([jnp.zeros((H,)), jnp.ones((H,)) * 3.0]
                                   ).astype(jnp.float32),
            "wout": _dense(ks[5], (d, d), s, cfg.dtype)}


def init_slstm(rng, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    s = 1.0 / math.sqrt(d)
    sr = 1.0 / math.sqrt(dh)
    ks = jax.random.split(rng, 9)
    return {"ln": init_norm(d, "rms"),
            "wz": _dense(ks[0], (d, d), s, cfg.dtype),
            "wi": _dense(ks[1], (d, H), s, jnp.float32),
            "wf": _dense(ks[2], (d, H), s, jnp.float32),
            "wo": _dense(ks[3], (d, d), s, cfg.dtype),
            "rz": _dense(ks[4], (H, dh, dh), sr, cfg.dtype),
            "ri": _dense(ks[5], (H, dh, 1), sr, jnp.float32),
            "rf": _dense(ks[6], (H, dh, 1), sr, jnp.float32),
            "bf": jnp.ones((H,), jnp.float32) * 3.0,
            "wout": _dense(ks[7], (d, d), s, cfg.dtype)}


def mlstm_state(cfg: ModelConfig, B: int):
    H = cfg.n_heads
    dh = cfg.d_model // H
    return {"C": jnp.zeros((B, H, dh, dh), jnp.float32),
            "n": jnp.zeros((B, H, dh), jnp.float32),
            "m": jnp.full((B, H), -1e30, jnp.float32)}


def slstm_state(cfg: ModelConfig, B: int):
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = lambda: jnp.zeros((B, H, dh), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((B, H), -1e30, jnp.float32)}


def _mlstm_step(state, q, k, v, ipre, fpre):
    """One recurrence step. q/k/v: (B,H,dh); ipre/fpre: (B,H)."""
    C, n, m = state["C"], state["n"], state["m"]
    logf = -jax.nn.softplus(-fpre)                 # log sigmoid(f)
    m_new = jnp.maximum(logf + m, ipre)
    i_g = jnp.exp(ipre - m_new)
    f_g = jnp.exp(logf + m - m_new)
    C_new = f_g[..., None, None] * C + \
        i_g[..., None, None] * (v[..., :, None] * k[..., None, :])
    n_new = f_g[..., None] * n + i_g[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)),
                        jnp.exp(-m_new))
    h = jnp.einsum("bhde,bhe->bhd", C_new, q) / denom[..., None]
    return {"C": C_new, "n": n_new, "m": m_new}, h


def mlstm_chunkwise(q, k, v, ipre, fpre, s0, *, chunk: int):
    """Chunkwise-parallel mLSTM (beyond-paper perf: the sequential form
    saves a (B,H,dh,dh) state per TOKEN for the backward pass — ~TB-scale
    HBM traffic at T=4096; this form saves one state per CHUNK and turns
    the intra-chunk work into MXU matmuls, mathematically equivalent to
    the stabilized recurrence).

    q/k/v: (B,T,H,dh) f32;  ipre/fpre: (B,T,H) f32;  s0: {C,n,m}.
    Returns (h (B,T,H,dh), final state).
    """
    B, T, H, dh = q.shape
    L = min(chunk, T)
    assert T % L == 0, (T, L)
    nc = T // L

    def to_chunks(x):                      # (B,T,...) -> (nc, B, H, L, ...)
        x = x.reshape(B, nc, L, *x.shape[2:])
        if x.ndim == 5:                    # (B,nc,L,H,dh)
            return x.transpose(1, 0, 3, 2, 4)
        return x.transpose(1, 0, 3, 2)     # gates (B,nc,L,H)->(nc,B,H,L)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    ic, fc = to_chunks(ipre), to_chunks(fpre)
    causal = jnp.tril(jnp.ones((L, L), bool))

    def body(s, xs):
        qb, kb, vb, ib, fb = xs            # (B,H,L,dh) / (B,H,L)
        C_in, n_in, m_in = s["C"], s["n"], s["m"]
        logf = -jax.nn.softplus(-fb)       # log sigmoid(f)
        F = jnp.cumsum(logf, axis=-1)      # (B,H,L) inclusive
        g = ib - F
        M = jnp.maximum(m_in[..., None],
                        jax.lax.cummax(g, axis=2))       # (B,H,L)
        inter_w = jnp.exp(m_in[..., None] - M)           # (B,H,L)
        D = jnp.exp(g[..., None, :] - M[..., :, None])   # (B,H,L_q,L_s)
        D = jnp.where(causal, D, 0.0)
        scores = jnp.einsum("bhld,bhsd->bhls", qb, kb,
                            preferred_element_type=jnp.float32)
        intra = jnp.einsum("bhls,bhsd->bhld", scores * D, vb,
                           preferred_element_type=jnp.float32)
        h_num = inter_w[..., None] * jnp.einsum(
            "bhde,bhle->bhld", C_in, qb,
            preferred_element_type=jnp.float32) + intra
        n_j = inter_w[..., None] * n_in[:, :, None, :] + \
            jnp.einsum("bhls,bhsd->bhld", D, kb,
                       preferred_element_type=jnp.float32)
        m_j = F + M
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bhld,bhld->bhl", qb.astype(jnp.float32),
                               n_j)), jnp.exp(-m_j))
        h = h_num / denom[..., None]
        # ---- chunk-end state (one saved carry per chunk) ----------------
        M_L = M[..., -1]
        F_L = F[..., -1]
        w = jnp.exp(g - M_L[..., None])                  # (B,H,L)
        decay = jnp.exp(m_in - M_L)
        C_out = decay[..., None, None] * C_in + \
            jnp.einsum("bhs,bhsd,bhse->bhde", w, vb, kb,
                       preferred_element_type=jnp.float32)
        n_out = decay[..., None] * n_in + \
            jnp.einsum("bhs,bhsd->bhd", w, kb,
                       preferred_element_type=jnp.float32)
        m_out = F_L + M_L
        return {"C": C_out, "n": n_out, "m": m_out}, h

    s_fin, hs = jax.lax.scan(body, s0, (qc, kc, vc, ic, fc))
    # (nc,B,H,L,dh) -> (B,T,H,dh)
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, T, H, dh)
    return h, s_fin


def mlstm_apply(x, p, cfg: ModelConfig, state=None, chunk: int = 128):
    """x: (B,T,D) -> (B,T,D).  When state is given (decode, T==1) the
    recurrence continues from it and the new state is returned.  T>1 uses
    the chunkwise-parallel form (see mlstm_chunkwise)."""
    B, T, D = x.shape
    H = cfg.n_heads
    dh = D // H
    xn = rms_norm(x, p["ln"]["scale"])
    # q/k/v/og stay in model dtype (bf16): the chunkwise matmuls accumulate
    # in f32 (preferred_element_type) and only the gate math needs f32 —
    # keeping (B,T,D)-sized tensors at 2 bytes halves the layer's HBM term
    scale = 1.0 / math.sqrt(dh)
    q = (xn @ p["wq"]).reshape(B, T, H, dh) * jnp.asarray(scale, cfg.dtype)
    k = (xn @ p["wk"]).reshape(B, T, H, dh) * jnp.asarray(scale, cfg.dtype)
    v = (xn @ p["wv"]).reshape(B, T, H, dh)
    og = jax.nn.sigmoid((xn @ p["wog"]).astype(jnp.float32)).astype(cfg.dtype)
    gates = (xn.astype(jnp.float32) @ p["wif"]) + p["bif"]
    ipre, fpre = gates[..., :H], gates[..., H:]
    s0 = state if state is not None else mlstm_state(cfg, B)

    if T > 1 and T % min(chunk, T) == 0:
        hq, s_fin = mlstm_chunkwise(q, k, v, ipre, fpre, s0,
                                    chunk=min(chunk, T))
        h = hq.reshape(B, T, D)
    else:
        def step(s, xs):
            return _mlstm_step(s, *xs)

        xs = (q.astype(jnp.float32).transpose(1, 0, 2, 3),
              k.astype(jnp.float32).transpose(1, 0, 2, 3),
              v.astype(jnp.float32).transpose(1, 0, 2, 3),
              ipre.transpose(1, 0, 2), fpre.transpose(1, 0, 2))
        s_fin, hs = jax.lax.scan(step, s0, xs)
        h = hs.transpose(1, 0, 2, 3).reshape(B, T, D)
    out = ((h.astype(cfg.dtype) * og.reshape(B, T, D))) @ p["wout"]
    return out, s_fin


def slstm_apply(x, p, cfg: ModelConfig, state=None):
    B, T, D = x.shape
    H = cfg.n_heads
    dh = D // H
    xn = rms_norm(x, p["ln"]["scale"])
    z_in = (xn @ p["wz"]).reshape(B, T, H, dh).astype(jnp.float32)
    o_in = (xn @ p["wo"]).reshape(B, T, H, dh).astype(jnp.float32)
    i_in = (xn.astype(jnp.float32) @ p["wi"])
    f_in = (xn.astype(jnp.float32) @ p["wf"]) + p["bf"]
    s0 = state if state is not None else slstm_state(cfg, B)
    rz = p["rz"].astype(jnp.float32)
    ri, rf = p["ri"][..., 0], p["rf"][..., 0]

    def step(s, xs):
        zt, ot, it, ft = xs
        h_prev = s["h"]
        z = jnp.tanh(zt + jnp.einsum("bhd,hde->bhe", h_prev, rz))
        ipre = it + jnp.einsum("bhd,hd->bh", h_prev, ri)
        fpre = ft + jnp.einsum("bhd,hd->bh", h_prev, rf)
        logf = -jax.nn.softplus(-fpre)
        m_new = jnp.maximum(logf + s["m"], ipre)
        i_g = jnp.exp(ipre - m_new)[..., None]
        f_g = jnp.exp(logf + s["m"] - m_new)[..., None]
        c = f_g * s["c"] + i_g * z
        n = f_g * s["n"] + i_g
        h = jax.nn.sigmoid(ot) * (c / jnp.maximum(n, 1e-6))
        return {"c": c, "n": n, "h": h, "m": m_new}, h

    xs = (z_in.transpose(1, 0, 2, 3), o_in.transpose(1, 0, 2, 3),
          i_in.transpose(1, 0, 2), f_in.transpose(1, 0, 2))
    s_fin, hs = jax.lax.scan(step, s0, xs)
    h = hs.transpose(1, 0, 2, 3).reshape(B, T, D)
    return (h.astype(cfg.dtype) @ p["wout"]), s_fin


# ------------------------------------------------------------- full model
def init_xlstm(cfg: ModelConfig, rng):
    assert cfg.n_layers % GROUP == 0
    G = cfg.n_layers // GROUP
    ks = jax.random.split(rng, 4)
    d, V = cfg.d_model, cfg.vocab
    return {
        "embed": _dense(ks[0], (V, d), 1.0 / math.sqrt(d), cfg.dtype),
        "groups": {
            "m": jax.vmap(lambda r: jax.vmap(
                lambda r2: init_mlstm(r2, cfg))(jax.random.split(r, GROUP - 1))
            )(jax.random.split(ks[1], G)),
            "s": jax.vmap(lambda r: init_slstm(r, cfg))(
                jax.random.split(ks[2], G)),
        },
        "final_norm": init_norm(d, "rms"),
        "head": _dense(ks[3], (d, V), 1.0 / math.sqrt(d), cfg.dtype),
    }


def xlstm_states(cfg: ModelConfig, B: int):
    G = cfg.n_layers // GROUP

    def stack(n, mk):
        one = mk(cfg, B)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)

    return {"m": jax.tree.map(lambda a: jnp.broadcast_to(
                a, (G,) + a.shape), stack(GROUP - 1, mlstm_state)),
            "s": stack(G, slstm_state)}


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    return jax.checkpoint(fn)


def xlstm_forward(params, batch, cfg: ModelConfig, ctx: MeshCtx | None):
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)

    def group(h, g):
        def inner(h2, blk):
            out, _ = mlstm_apply(h2, blk, cfg)
            return h2 + out, None
        h, _ = jax.lax.scan(inner, h, g["m"])
        out, _ = slstm_apply(h, g["s"], cfg)
        return h + out, None

    x, _ = jax.lax.scan(_remat(group, cfg), x, params["groups"])
    x = rms_norm(x, params["final_norm"]["scale"])
    return (x @ params["head"]).astype(jnp.float32)


def xlstm_loss(params, batch, cfg, ctx):
    logits = xlstm_forward(params, batch, cfg, ctx)
    t = batch["targets"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def xlstm_prefill(params, batch, cfg: ModelConfig, ctx):
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)

    def group(h, g):
        def inner(h2, blk):
            out, s = mlstm_apply(h2, blk, cfg)
            return h2 + out, s
        h, ms = jax.lax.scan(inner, h, g["m"])
        out, ss = slstm_apply(h, g["s"], cfg)
        return h + out, {"m": ms, "s": ss}

    x, states = jax.lax.scan(_remat(group, cfg), x, params["groups"])
    x = rms_norm(x[:, -1:], params["final_norm"]["scale"])
    logits = (x @ params["head"]).astype(jnp.float32)
    return logits[:, 0], states


def xlstm_decode_step(params, state, token, pos, cfg: ModelConfig, ctx):
    x = jnp.take(params["embed"], token[:, None], axis=0)

    def group(h, xs):
        g, st = xs

        def inner(h2, xs2):
            blk, s = xs2
            out, ns = mlstm_apply(h2, blk, cfg, state=s)
            return h2 + out, ns
        h, nms = jax.lax.scan(inner, h, (g["m"], st["m"]))
        out, nss = slstm_apply(h, g["s"], cfg, state=st["s"])
        return h + out, {"m": nms, "s": nss}

    x, new_state = jax.lax.scan(group, x, (params["groups"], state))
    x = rms_norm(x, params["final_norm"]["scale"])
    logits = (x @ params["head"]).astype(jnp.float32)
    return logits[:, 0], new_state
