"""Model/shape configuration shared across the 10 assigned architectures."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax.numpy as jnp


@dataclass(frozen=True)
class MeshCtx:
    """How model code should see the device mesh (None = single device).

    batch_axes: mesh axes the batch dim is sharded over (may be empty, e.g.
    batch=1 long-context decode).  model_axis: the TP/EP axis name.
    """
    mesh: Any = None
    batch_axes: tuple = ()
    model_axis: str | None = None


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int              # per-expert FFN hidden size
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | encdec | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0          # 0 -> derived d_model // n_heads
    moe: MoECfg | None = None
    qkv_bias: bool = False
    norm: str = "rms"          # rms | ln
    act: str = "swiglu"        # swiglu | gelu
    rope_theta: float = 1e6
    pos: str = "rope"          # rope | sinusoidal | none
    tie_embeddings: bool = False
    # family extras ----------------------------------------------------------
    enc_layers: int = 0        # encdec: encoder depth
    enc_seq: int = 1500        # whisper frame count (stub frontend output)
    cross_every: int = 0       # vlm: a cross-attn layer every Nth layer
    n_img_tokens: int = 1600   # vlm stub patch-embedding count
    attn_window: int = 0       # 0 = full causal; >0 = local sliding window
    block_pattern: tuple[str, ...] = ()   # hybrid/ssm per-group layer kinds
    lru_width: int = 0         # rglru: recurrence width (0 -> d_model)
    # numerics / perf knobs ---------------------------------------------------
    dtype: Any = jnp.bfloat16
    remat: str = "dots"        # none | dots | full
    attn_impl: str = "xla"     # xla (chunked online-softmax) | pallas
    attn_chunk: int = 512      # KV chunk for the XLA flash-pattern attention
    scan_layers: bool = True
    logits_f32: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded state?"""
        return self.family in ("ssm", "hybrid")

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    # --------------------------------------------------------- param counts
    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline bookkeeping)."""
        D, hd = self.d_model, self.hd
        qo = D * self.n_heads * hd * 2
        kv = D * self.n_kv_heads * hd * 2
        if self.family in ("ssm",):
            per_layer = 5 * D * D + 2 * D  # mLSTM-ish (see models/xlstm.py)
            body = self.n_layers * per_layer
        elif self.family == "hybrid":
            R = self.lru_width or D
            rec = 2 * D * R + 2 * R * R + R * D + 4 * R
            attn = qo + kv
            mlp = 3 * D * self.d_ff
            n_attn = sum(1 for i in range(self.n_layers)
                         if self._layer_kind(i) == "attn")
            n_rec = self.n_layers - n_attn
            body = n_rec * (rec + mlp) + n_attn * (attn + mlp)
        else:
            if self.moe:
                mlp = self.moe.n_experts * 3 * D * self.moe.d_expert \
                    + D * self.moe.n_experts
            else:
                mlp = (3 if self.act == "swiglu" else 2) * D * self.d_ff
            per_layer = qo + kv + mlp
            body = self.n_layers * per_layer
            if self.family == "encdec":
                body += self.enc_layers * (qo + kv + 2 * D * self.d_ff)
                body += self.n_layers * (qo + kv)      # decoder cross-attn
            if self.family == "vlm" and self.cross_every:
                n_cross = self.n_layers // self.cross_every
                body += n_cross * (qo + kv)
        embed = self.vocab * D * (1 if self.tie_embeddings else 2)
        return body + embed

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k), for 6·N_active·D."""
        if not self.moe:
            return self.param_count()
        D = self.d_model
        dense_mlp = self.moe.top_k * 3 * D * self.moe.d_expert \
            + D * self.moe.n_experts
        full_mlp = self.moe.n_experts * 3 * D * self.moe.d_expert \
            + D * self.moe.n_experts
        return self.param_count() - self.n_layers * (full_mlp - dense_mlp)

    def _layer_kind(self, i: int) -> str:
        if not self.block_pattern:
            return "attn"
        return self.block_pattern[i % len(self.block_pattern)]


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES: dict[str, ShapeCfg] = {
    "train_4k":    ShapeCfg("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32768, 32),
    "decode_32k":  ShapeCfg("decode_32k", "decode", 32768, 128),
    "long_500k":   ShapeCfg("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeCfg) -> tuple[bool, str]:
    """Which (arch x shape) cells run; mirrors DESIGN.md §Arch-applicability."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k dense KV decode skipped per assignment"
    return True, ""
