"""Transformer families: decoder-only LM (dense & MoE), encoder-decoder
(whisper), and VLM with interleaved cross-attention layers (llama-vision).

All families share: scan-over-layers (stacked params → fast lowering for
94-layer configs), configurable remat, chunked flash-pattern attention, and
KV-cache prefill/decode paths.  Modality frontends are stubs per the
assignment: whisper consumes precomputed frame embeddings, the VLM consumes
precomputed patch embeddings (both arrive via ``input_specs``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .common import MeshCtx, ModelConfig
from .layers import (apply_norm, attn_init, chunked_attention,
                     decode_attention, decode_update_and_attend,
                     init_norm, mlp_apply, mlp_init,
                     moe_apply, moe_init, out_proj, qkv_proj, rope,
                     sharded_attention, sinusoidal_pos)


def constrain(x, ctx: MeshCtx | None, spec: P):
    if ctx is not None and ctx.mesh is not None:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(ctx.mesh, spec))
    return x


def act_spec(ctx: MeshCtx | None) -> P:
    if ctx is None or ctx.mesh is None:
        return P()
    b = ctx.batch_axes if ctx.batch_axes else None
    return P(b, None, None)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


# =========================================================== block def/init
def init_block(rng, cfg: ModelConfig, *, cross: bool = False,
               causal_self: bool = True, with_self: bool = True):
    ks = jax.random.split(rng, 8)
    d, hd = cfg.d_model, cfg.hd
    p = {}
    if with_self:
        p["ln1"] = init_norm(d, cfg.norm)
        p["attn"] = attn_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads, hd,
                              cfg.qkv_bias, cfg.dtype)
    if cross:
        p["lnx"] = init_norm(d, cfg.norm)
        p["xattn"] = attn_init(ks[1], d, cfg.n_heads, cfg.n_kv_heads, hd,
                               False, cfg.dtype)
        p["xgate"] = jnp.zeros((), jnp.float32)   # mllama-style gated cross
    p["ln2"] = init_norm(d, cfg.norm)
    if cfg.moe is not None:
        p["moe"] = moe_init(ks[2], d, cfg.moe, cfg.dtype)
    else:
        p["mlp"] = mlp_init(ks[2], d, cfg.d_ff, cfg.act, cfg.dtype)
    return p


def self_attention(x, p, cfg: ModelConfig, ctx, *, positions, causal=True,
                   window=0, cache=None, cache_pos=None, kv_mask=None):
    """Returns (attn_out, new_cache_slice_or_None).

    cache: dict(k=(B,S,Hkv,hd), v=..., [pos=(B,S)]) for decode;
    when cache is given, x is the single new token (B,1,D).
    """
    q, k, v = qkv_proj(x, p, cfg.n_heads, cfg.n_kv_heads, cfg.hd)
    if cfg.pos == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    new_cache = None
    if cache is not None:
        # scatter the new token's K/V and attend, shard-locally when the
        # cache is S-sharded (see layers.decode_update_and_attend)
        out, ck, cv, cpos = decode_update_and_attend(
            q, cache["k"], cache["v"], cache["pos"], k, v, cache_pos,
            window=window, ctx=ctx, chunk=cfg.attn_chunk, dtype=cfg.dtype)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        return out_proj(out, p), new_cache
    out = sharded_attention(
        q, k, v, q_pos=positions, k_pos=positions, causal=causal,
        window=window, kv_mask=kv_mask, chunk=cfg.attn_chunk, dtype=cfg.dtype,
        ctx=ctx)
    return out_proj(out, p), new_cache


def cross_attention(x, p, cfg: ModelConfig, *, xk, xv, x_mask=None,
                    ctx=None):
    B, T, _ = x.shape
    q = (x @ p["wq"]).reshape(B, T, cfg.n_heads, cfg.hd)
    S = xk.shape[1]
    q_pos = jnp.zeros((B, T), jnp.int32)
    k_pos = jnp.zeros((B, S), jnp.int32)
    out = sharded_attention(q, xk, xv, q_pos=q_pos, k_pos=k_pos, causal=False,
                            kv_mask=x_mask, chunk=cfg.attn_chunk,
                            dtype=cfg.dtype, ctx=ctx)
    return out_proj(out, p)


def cross_kv(enc_out, p, cfg: ModelConfig):
    B, S, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = (enc_out @ p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    return k, v


def block_apply(x, p, cfg: ModelConfig, ctx, *, positions, causal=True,
                window=0, cache=None, cache_pos=None,
                xk=None, xv=None, x_mask=None, with_self=True):
    new_cache = None
    if with_self:
        a, new_cache = self_attention(
            apply_norm(x, p["ln1"], cfg.norm), p["attn"], cfg, ctx,
            positions=positions, causal=causal, window=window, cache=cache,
            cache_pos=cache_pos)
        x = x + a
    if xk is not None:
        g = jnp.tanh(p["xgate"]).astype(x.dtype) if "xgate" in p else 1.0
        c = cross_attention(apply_norm(x, p["lnx"], cfg.norm), p["xattn"],
                            cfg, xk=xk, xv=xv, x_mask=x_mask, ctx=ctx)
        x = x + g * c
    h = apply_norm(x, p["ln2"], cfg.norm)
    if cfg.moe is not None:
        x = x + moe_apply(h, p["moe"], cfg.moe, ctx)
    else:
        x = x + mlp_apply(h, p["mlp"], cfg.act)
    x = constrain(x, ctx, act_spec(ctx))
    return x, new_cache


# ============================================================= LM (decoder)
def init_lm(cfg: ModelConfig, rng):
    ks = jax.random.split(rng, 6)
    d, V = cfg.d_model, cfg.vocab
    params = {
        "embed": (jax.random.normal(ks[0], (V, d)) / math.sqrt(d)
                  ).astype(cfg.dtype),
        "final_norm": init_norm(d, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(ks[1], (d, V)) / math.sqrt(d)
                          ).astype(cfg.dtype)
    if cfg.family == "vlm":
        G = cfg.n_layers // cfg.cross_every
        inner = cfg.cross_every - 1
        params["groups"] = {
            "self": jax.vmap(lambda r: jax.vmap(
                lambda r2: init_block(r2, cfg))(jax.random.split(r, inner)))(
                jax.random.split(ks[2], G)),
            "cross": jax.vmap(lambda r: init_block(r, cfg, cross=True))(
                jax.random.split(ks[3], G)),
        }
    elif cfg.family == "encdec":
        enc_cfg = cfg.with_(act="gelu")
        params["enc_blocks"] = jax.vmap(
            lambda r: init_block(r, enc_cfg))(
            jax.random.split(ks[2], cfg.enc_layers))
        params["enc_norm"] = init_norm(d, cfg.norm)
        params["dec_blocks"] = jax.vmap(
            lambda r: init_block(r, cfg, cross=True))(
            jax.random.split(ks[3], cfg.n_layers))
    else:
        params["blocks"] = jax.vmap(lambda r: init_block(r, cfg))(
            jax.random.split(ks[2], cfg.n_layers))
    return params


def _embed(params, tokens, cfg):
    return jnp.take(params["embed"], tokens, axis=0)


def _unembed(params, x, cfg):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ w
    return logits.astype(jnp.float32) if cfg.logits_f32 else logits


def _encoder_apply(params, frames, cfg: ModelConfig, ctx):
    """Whisper encoder over stub conv-frontend frame embeddings (B,S,D)."""
    B, S, _ = frames.shape
    pos = jnp.arange(S)[None, :].repeat(B, 0)
    x = frames.astype(cfg.dtype) + sinusoidal_pos(pos, cfg.d_model, cfg.dtype)
    enc_cfg = cfg.with_(act="gelu")

    def body(h, blk):
        h, _ = block_apply(h, blk, enc_cfg, ctx, positions=pos, causal=False)
        return h, None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["enc_blocks"])
    return apply_norm(x, params["enc_norm"], cfg.norm)


def lm_forward(params, batch, cfg: ModelConfig, ctx: MeshCtx | None):
    """Full-sequence forward -> logits (B, T, V). batch carries 'tokens' and
    family extras ('frames' for encdec, 'image_embeds' for vlm)."""
    tokens = batch["tokens"]
    B, T = tokens.shape
    positions = jnp.arange(T, dtype=jnp.int32)[None, :].repeat(B, 0)
    x = _embed(params, tokens, cfg)
    if cfg.pos == "sinusoidal":
        x = x + sinusoidal_pos(positions, cfg.d_model, cfg.dtype)
    x = constrain(x, ctx, act_spec(ctx))

    if cfg.family == "encdec":
        enc = _encoder_apply(params, batch["frames"], cfg, ctx)

        def body(h, blk):
            xk, xv = cross_kv(enc, blk["xattn"], cfg)
            h, _ = block_apply(h, blk, cfg, ctx, positions=positions,
                               causal=True, xk=xk, xv=xv)
            return h, None

        x, _ = jax.lax.scan(_remat(body, cfg), x, params["dec_blocks"])
    elif cfg.family == "vlm":
        img = batch["image_embeds"].astype(cfg.dtype)

        def group(h, g):
            def inner(h2, blk):
                h2, _ = block_apply(h2, blk, cfg, ctx, positions=positions)
                return h2, None
            h, _ = jax.lax.scan(inner, h, g["self"])
            xk, xv = cross_kv(img, g["cross"]["xattn"], cfg)
            h, _ = block_apply(h, g["cross"], cfg, ctx, positions=positions,
                               xk=xk, xv=xv)
            return h, None

        x, _ = jax.lax.scan(_remat(group, cfg), x, params["groups"])
    else:
        def body(h, blk):
            h, _ = block_apply(h, blk, cfg, ctx, positions=positions,
                               causal=True, window=cfg.attn_window)
            return h, None

        x, _ = jax.lax.scan(_remat(body, cfg), x, params["blocks"])

    x = apply_norm(x, params["final_norm"], cfg.norm)
    return _unembed(params, x, cfg)


# ------------------------------------------------------------- loss
def lm_loss(params, batch, cfg: ModelConfig, ctx: MeshCtx | None):
    logits = lm_forward(params, batch, cfg, ctx)
    targets = batch["targets"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch.get("loss_mask")
    if mask is None:
        return nll.mean()
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)


# ------------------------------------------------------- prefill / decode
def make_cache(cfg: ModelConfig, B: int, S_max: int, dtype=None):
    dtype = dtype or cfg.dtype
    hd, Hkv = cfg.hd, cfg.n_kv_heads
    S_self = min(S_max, cfg.attn_window) if cfg.attn_window else S_max

    def kv(layers, S):
        return {"k": jnp.zeros((layers, B, S, Hkv, hd), dtype),
                "v": jnp.zeros((layers, B, S, Hkv, hd), dtype),
                "pos": jnp.full((layers, B, S), -1, jnp.int32)}

    if cfg.family == "encdec":
        return {"self": kv(cfg.n_layers, S_self),
                "cross_k": jnp.zeros((cfg.n_layers, B, cfg.enc_seq, Hkv, hd),
                                     dtype),
                "cross_v": jnp.zeros((cfg.n_layers, B, cfg.enc_seq, Hkv, hd),
                                     dtype)}
    if cfg.family == "vlm":
        G = cfg.n_layers // cfg.cross_every
        inner = cfg.cross_every - 1
        return {
            "self": {"k": jnp.zeros((G, inner, B, S_self, Hkv, hd), dtype),
                     "v": jnp.zeros((G, inner, B, S_self, Hkv, hd), dtype),
                     "pos": jnp.full((G, inner, B, S_self), -1, jnp.int32)},
            "cross_self": kv(G, S_self),
            "cross_k": jnp.zeros((G, B, cfg.n_img_tokens, Hkv, hd), dtype),
            "cross_v": jnp.zeros((G, B, cfg.n_img_tokens, Hkv, hd), dtype)}
    return kv(cfg.n_layers, S_self)


def lm_decode_step(params, cache, token, pos, cfg: ModelConfig,
                   ctx: MeshCtx | None):
    """One serve_step: new token (B,), absolute positions pos (B,) ->
    (logits (B, V), updated cache)."""
    B = token.shape[0]
    x = _embed(params, token[:, None], cfg)
    if cfg.pos == "sinusoidal":
        x = x + sinusoidal_pos(pos[:, None], cfg.d_model, cfg.dtype)
    x = constrain(x, ctx, act_spec(ctx))
    positions = pos[:, None]

    if cfg.family == "encdec":
        def body(h, xs):
            blk, ck, cv, csl = xs
            h, new_self = block_apply(
                h, blk, cfg, ctx, positions=positions, causal=True,
                cache=csl, cache_pos=pos, xk=ck, xv=cv)
            return h, new_self
        x, new_self = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["cross_k"],
                      cache["cross_v"], cache["self"]))
        new_cache = dict(cache, self=new_self)
    elif cfg.family == "vlm":
        def group(h, xs):
            g, sc, csc, ck, cv = xs
            def inner(h2, xs2):
                blk, c = xs2
                h2, nc = block_apply(h2, blk, cfg, ctx, positions=positions,
                                     cache=c, cache_pos=pos)
                return h2, nc
            h, nsc = jax.lax.scan(inner, h, (g["self"], sc))
            h, ncsc = block_apply(h, g["cross"], cfg, ctx,
                                  positions=positions, cache=csc,
                                  cache_pos=pos, xk=ck, xv=cv)
            return h, (nsc, ncsc)
        x, (nself, ncross_self) = jax.lax.scan(
            group, x, (params["groups"], cache["self"], cache["cross_self"],
                       cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache, self=nself, cross_self=ncross_self)
    else:
        def body(h, xs):
            blk, c = xs
            h, nc = block_apply(h, blk, cfg, ctx, positions=positions,
                                causal=True, window=cfg.attn_window,
                                cache=c, cache_pos=pos)
            return h, nc
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))

    x = apply_norm(x, params["final_norm"], cfg.norm)
    return _unembed(params, x, cfg)[:, 0], new_cache


def lm_prefill(params, batch, cfg: ModelConfig, ctx: MeshCtx | None,
               s_max: int | None = None):
    """Full-context prefill: returns (last-token logits, populated cache).

    ``s_max`` pads the returned cache with empty (pos=-1) slots so decode
    steps can append new tokens: full-attention caches grow to ``s_max``;
    windowed caches are padded to the full ring size W.
    """
    tokens = batch["tokens"]
    B, T = tokens.shape
    positions = jnp.arange(T, dtype=jnp.int32)[None, :].repeat(B, 0)
    x = _embed(params, tokens, cfg)
    if cfg.pos == "sinusoidal":
        x = x + sinusoidal_pos(positions, cfg.d_model, cfg.dtype)
    x = constrain(x, ctx, act_spec(ctx))
    W = cfg.attn_window
    S_c = min(T, W) if W else T

    def _pad(ck, cv, cp):
        target = (W if W else s_max) if s_max else None
        if target is None or ck.shape[1] >= target:
            return ck, cv, cp
        pad = target - ck.shape[1]
        ck = jnp.pad(ck, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(cv, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cp = jnp.pad(cp, ((0, 0), (0, pad)), constant_values=-1)
        return ck, cv, cp

    def fill_kv(k, v):
        """Store the last S_c kv entries (ring layout for windowed attn)."""
        if W and T > W:
            ks, vs = k[:, -W:], v[:, -W:]
            ps = positions[:, -W:]
            # ring order: slot = pos % W
            order = jnp.argsort(ps[0] % W)
            return (ks[:, order].astype(cfg.dtype),
                    vs[:, order].astype(cfg.dtype), ps[:, order])
        return _pad(k.astype(cfg.dtype), v.astype(cfg.dtype), positions)

    if cfg.family == "encdec":
        enc = _encoder_apply(params, batch["frames"], cfg, ctx)

        def body(h, blk):
            xk, xv = cross_kv(enc, blk["xattn"], cfg)
            hn = apply_norm(h, blk["ln1"], cfg.norm)
            q, k, v = qkv_proj(hn, blk["attn"], cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd)
            if cfg.pos == "rope":
                q = rope(q, positions, cfg.rope_theta)
                k = rope(k, positions, cfg.rope_theta)
            a = sharded_attention(q, k, v, q_pos=positions, k_pos=positions,
                                  causal=True, chunk=cfg.attn_chunk,
                                  dtype=cfg.dtype, ctx=ctx)
            h = h + out_proj(a, blk["attn"])
            c = cross_attention(apply_norm(h, blk["lnx"], cfg.norm),
                                blk["xattn"], cfg, xk=xk, xv=xv, ctx=ctx)
            h = h + jnp.tanh(blk["xgate"]).astype(h.dtype) * c \
                if "xgate" in blk else h + c
            hh = apply_norm(h, blk["ln2"], cfg.norm)
            h = h + mlp_apply(hh, blk["mlp"], cfg.act)
            ck, cv, cp = fill_kv(k, v)
            return h, {"k": ck, "v": cv, "pos": cp, "xk": xk, "xv": xv}

        x, per_layer = jax.lax.scan(_remat(body, cfg), x,
                                    params["dec_blocks"])
        cache = {"self": {"k": per_layer["k"], "v": per_layer["v"],
                          "pos": per_layer["pos"]},
                 "cross_k": per_layer["xk"], "cross_v": per_layer["xv"]}
    elif cfg.family == "vlm":
        img = batch["image_embeds"].astype(cfg.dtype)

        def group(h, g):
            def inner(h2, blk):
                hn = apply_norm(h2, blk["ln1"], cfg.norm)
                q, k, v = qkv_proj(hn, blk["attn"], cfg.n_heads,
                                   cfg.n_kv_heads, cfg.hd)
                if cfg.pos == "rope":
                    q = rope(q, positions, cfg.rope_theta)
                    k = rope(k, positions, cfg.rope_theta)
                a = sharded_attention(q, k, v, q_pos=positions,
                                      k_pos=positions, causal=True,
                                      chunk=cfg.attn_chunk, dtype=cfg.dtype,
                                      ctx=ctx)
                h2 = h2 + out_proj(a, blk["attn"])
                hh = apply_norm(h2, blk["ln2"], cfg.norm)
                h2 = h2 + mlp_apply(hh, blk["mlp"], cfg.act)
                ck, cv, cp = fill_kv(k, v)
                return h2, {"k": ck, "v": cv, "pos": cp}
            h, sc = jax.lax.scan(inner, h, g["self"])
            blk = g["cross"]
            hn = apply_norm(h, blk["ln1"], cfg.norm)
            q, k, v = qkv_proj(hn, blk["attn"], cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd)
            if cfg.pos == "rope":
                q = rope(q, positions, cfg.rope_theta)
                k = rope(k, positions, cfg.rope_theta)
            a = sharded_attention(q, k, v, q_pos=positions, k_pos=positions,
                                  causal=True, chunk=cfg.attn_chunk,
                                  dtype=cfg.dtype, ctx=ctx)
            h = h + out_proj(a, blk["attn"])
            xk, xv = cross_kv(img, blk["xattn"], cfg)
            c = cross_attention(apply_norm(h, blk["lnx"], cfg.norm),
                                blk["xattn"], cfg, xk=xk, xv=xv, ctx=ctx)
            h = h + jnp.tanh(blk["xgate"]).astype(h.dtype) * c
            hh = apply_norm(h, blk["ln2"], cfg.norm)
            h = h + mlp_apply(hh, blk["mlp"], cfg.act)
            ck, cv, cp = fill_kv(k, v)
            return h, (sc, {"k": ck, "v": cv, "pos": cp,
                            "xk": xk, "xv": xv})

        x, (self_c, cross_c) = jax.lax.scan(_remat(group, cfg), x,
                                            params["groups"])
        cache = {"self": self_c,
                 "cross_self": {"k": cross_c["k"], "v": cross_c["v"],
                                "pos": cross_c["pos"]},
                 "cross_k": cross_c["xk"], "cross_v": cross_c["xv"]}
    else:
        def body(h, blk):
            hn = apply_norm(h, blk["ln1"], cfg.norm)
            q, k, v = qkv_proj(hn, blk["attn"], cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd)
            if cfg.pos == "rope":
                q = rope(q, positions, cfg.rope_theta)
                k = rope(k, positions, cfg.rope_theta)
            a = sharded_attention(q, k, v, q_pos=positions, k_pos=positions,
                                  causal=True, window=cfg.attn_window,
                                  chunk=cfg.attn_chunk, dtype=cfg.dtype,
                                  ctx=ctx)
            h = h + out_proj(a, blk["attn"])
            hh = apply_norm(h, blk["ln2"], cfg.norm)
            if cfg.moe is not None:
                h = h + moe_apply(hh, blk["moe"], cfg.moe, ctx)
            else:
                h = h + mlp_apply(hh, blk["mlp"], cfg.act)
            h = constrain(h, ctx, act_spec(ctx))
            ck, cv, cp = fill_kv(k, v)
            return h, {"k": ck, "v": cv, "pos": cp}

        x, cache = jax.lax.scan(_remat(body, cfg), x, params["blocks"])

    x = apply_norm(x[:, -1:], params["final_norm"], cfg.norm)
    return _unembed(params, x, cfg)[:, 0], cache
