"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local sliding-
window attention in a (rec, rec, attn) pattern; 38 layers = 12 scanned groups
of 3 + 2 trailing recurrent layers (12 attn : 26 rec ≈ the 1:2 assignment).

The RG-LRU is a gated linear recurrence
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t),
    a_t = exp(-c · softplus(Λ) ⊙ r_t),  r_t, i_t = σ(linear(x_t))
evaluated with ``jax.lax.associative_scan`` for training/prefill (O(T log T),
fully parallel — the TPU-friendly substitute for the paper's sequential CUDA
scan) and a single fused step for decode.  Decode state is O(1): recurrence
state (B, R) + conv tail (B, 3, R) + a 2048-slot attention ring buffer — this
arch runs the long_500k cell.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ModelConfig
from .layers import (attn_init, chunked_attention, decode_attention,
                     decode_update_and_attend, init_norm, mlp_apply,
                     mlp_init, out_proj, qkv_proj, rms_norm, rope)

PATTERN = ("rec", "rec", "attn")
_C = 8.0                      # RG-LRU gate sharpness constant (Griffin)
CONV_W = 4


def _dense(rng, shape, scale, dtype):
    return (jax.random.normal(rng, shape) * scale).astype(dtype)


def init_rec_mixer(rng, cfg: ModelConfig):
    d = cfg.d_model
    R = cfg.lru_width or d
    ks = jax.random.split(rng, 6)
    s = 1.0 / math.sqrt(d)
    sR = 1.0 / math.sqrt(R)
    return {"ln": init_norm(d, "rms"),
            "w_gate": _dense(ks[0], (d, R), s, cfg.dtype),
            "w_x": _dense(ks[1], (d, R), s, cfg.dtype),
            "conv_w": _dense(ks[2], (CONV_W, R), 0.1, cfg.dtype),
            "conv_b": jnp.zeros((R,), cfg.dtype),
            "w_r": _dense(ks[3], (R, R), sR, cfg.dtype),
            "w_i": _dense(ks[4], (R, R), sR, cfg.dtype),
            "lam": jnp.log(jnp.expm1(       # softplus^-1 of target decay
                -jnp.log(jnp.linspace(0.9, 0.999, R)) / _C)).astype(jnp.float32),
            "w_out": _dense(ks[5], (R, d), sR, cfg.dtype)}


def init_rg_layer(rng, cfg: ModelConfig, kind: str):
    k1, k2 = jax.random.split(rng)
    p = {"ln2": init_norm(cfg.d_model, "rms"),
         "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act, cfg.dtype)}
    if kind == "rec":
        p["rec"] = init_rec_mixer(k1, cfg)
    else:
        p["ln1"] = init_norm(cfg.d_model, "rms")
        p["attn"] = attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                              cfg.hd, False, cfg.dtype)
    return p


def _causal_conv(x, w, b, tail=None):
    """Depthwise causal conv, width 4. x: (B,T,R). tail: (B,3,R) history."""
    if tail is None:
        pad = jnp.zeros_like(x[:, :CONV_W - 1])
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, CONV_W - 1 - j:xp.shape[1] - j if j else None] * w[CONV_W - 1 - j]
              for j in range(CONV_W))
    new_tail = xp[:, -(CONV_W - 1):]
    return out + b, new_tail


def rg_lru(y, p, h0=None):
    """y: (B,T,R) conv output. Returns (out, h_last)."""
    y32 = y.astype(jnp.float32)
    r = jax.nn.sigmoid(y @ p["w_r"]).astype(jnp.float32)
    i = jax.nn.sigmoid(y @ p["w_i"]).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(p["lam"]) * r            # (B,T,R), <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * y32)
    if y.shape[1] == 1 and h0 is not None:                  # decode fast path
        h = a[:, 0] * h0 + gated[:, 0]
        return h[:, None], h
    if h0 is not None:
        # fold carry-in into the first element
        gated = gated.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return hh, hh[:, -1]


def rec_mixer_apply(x, p, cfg: ModelConfig, state=None):
    """state: {'h': (B,R), 'tail': (B,3,R)} or None."""
    xn = rms_norm(x, p["ln"]["scale"])
    gate = jax.nn.gelu((xn @ p["w_gate"]).astype(jnp.float32))
    y = xn @ p["w_x"]
    y, new_tail = _causal_conv(y, p["conv_w"], p["conv_b"],
                               None if state is None else state["tail"])
    h, h_last = rg_lru(y, p, None if state is None else state["h"])
    out = (h * gate).astype(cfg.dtype) @ p["w_out"]
    return out, {"h": h_last, "tail": new_tail.astype(cfg.dtype)}


def attn_mixer_apply(x, p, cfg: ModelConfig, positions, cache=None,
                     cache_pos=None, ctx=None, collect: bool = False):
    xn = rms_norm(x, p["ln1"]["scale"])
    q, k, v = qkv_proj(xn, p["attn"], cfg.n_heads, cfg.n_kv_heads, cfg.hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    W = cfg.attn_window
    new_cache = None
    if cache is not None:
        out, ck, cv, cpos = decode_update_and_attend(
            q, cache["k"], cache["v"], cache["pos"], k, v, cache_pos,
            window=W, ctx=ctx, chunk=cfg.attn_chunk, dtype=cfg.dtype)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    else:
        out = chunked_attention(q, k, v, q_pos=positions, k_pos=positions,
                                causal=True, window=W, chunk=cfg.attn_chunk,
                                dtype=cfg.dtype)
        if W and collect:
            T = x.shape[1]
            S_c = min(T, W)
            ps = positions[:, -S_c:]
            order = jnp.argsort(ps[0] % W) if T >= W else jnp.arange(S_c)
            new_cache = {"k": k[:, -S_c:][:, order].astype(cfg.dtype),
                         "v": v[:, -S_c:][:, order].astype(cfg.dtype),
                         "pos": ps[:, order]}
    return out_proj(out, p["attn"]), new_cache


def rg_layer_apply(x, p, kind, cfg, positions, state=None, cache_pos=None,
                   ctx=None, collect: bool = False):
    if kind == "rec":
        mix, new_state = rec_mixer_apply(x, p["rec"], cfg, state)
        if not collect and state is None:
            new_state = None
    else:
        mix, new_state = attn_mixer_apply(x, p, cfg, positions, state,
                                          cache_pos, ctx=ctx, collect=collect)
    x = x + mix
    x = x + mlp_apply(rms_norm(x, p["ln2"]["scale"]), p["mlp"], cfg.act)
    return x, new_state


# --------------------------------------------------------------- full model
def n_groups(cfg: ModelConfig) -> tuple[int, int]:
    g = cfg.n_layers // len(PATTERN)
    tail = cfg.n_layers - g * len(PATTERN)
    return g, tail


def init_rg(cfg: ModelConfig, rng):
    G, tail = n_groups(cfg)
    ks = jax.random.split(rng, 5 + tail)
    d, V = cfg.d_model, cfg.vocab
    params = {
        "embed": _dense(ks[0], (V, d), 1.0 / math.sqrt(d), cfg.dtype),
        "groups": {
            "rec1": jax.vmap(lambda r: init_rg_layer(r, cfg, "rec"))(
                jax.random.split(ks[1], G)),
            "rec2": jax.vmap(lambda r: init_rg_layer(r, cfg, "rec"))(
                jax.random.split(ks[2], G)),
            "attn": jax.vmap(lambda r: init_rg_layer(r, cfg, "attn"))(
                jax.random.split(ks[3], G)),
        },
        "final_norm": init_norm(d, "rms"),
        "head": _dense(ks[4], (d, V), 1.0 / math.sqrt(d), cfg.dtype),
    }
    for t in range(tail):
        params[f"tail{t}"] = init_rg_layer(ks[5 + t], cfg, "rec")
    return params


def rg_states(cfg: ModelConfig, B: int, dtype=None):
    dtype = dtype or cfg.dtype
    G, tail = n_groups(cfg)
    R = cfg.lru_width or cfg.d_model
    W = cfg.attn_window

    def rec(n=None):
        s = {"h": jnp.zeros((B, R), jnp.float32),
             "tail": jnp.zeros((B, CONV_W - 1, R), dtype)}
        if n is None:
            return s
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), s)

    attn = {"k": jnp.zeros((G, B, W, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((G, B, W, cfg.n_kv_heads, cfg.hd), dtype),
            "pos": jnp.full((G, B, W), -1, jnp.int32)}
    st = {"groups": {"rec1": rec(G), "rec2": rec(G), "attn": attn}}
    for t in range(tail):
        st[f"tail{t}"] = rec()
    return st


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    return jax.checkpoint(fn)


def rg_backbone(params, tokens, cfg, ctx, collect: bool):
    """Returns (final hidden states (B,T,D), states-or-None)."""
    B, T = tokens.shape
    positions = jnp.arange(T, dtype=jnp.int32)[None, :].repeat(B, 0)
    x = jnp.take(params["embed"], tokens, axis=0) * math.sqrt(cfg.d_model)
    x = x.astype(cfg.dtype)

    def group(h, g):
        h, s1 = rg_layer_apply(h, g["rec1"], "rec", cfg, positions,
                               ctx=ctx, collect=collect)
        h, s2 = rg_layer_apply(h, g["rec2"], "rec", cfg, positions,
                               ctx=ctx, collect=collect)
        h, sa = rg_layer_apply(h, g["attn"], "attn", cfg, positions,
                               ctx=ctx, collect=collect)
        if not collect:
            return h, None
        return h, {"rec1": s1, "rec2": s2, "attn": sa}

    x, gstates = jax.lax.scan(_remat(group, cfg), x, params["groups"])
    states = {"groups": gstates} if collect else None
    G, tail = n_groups(cfg)
    for t in range(tail):
        x, st = rg_layer_apply(x, params[f"tail{t}"], "rec", cfg, positions,
                               ctx=ctx, collect=collect)
        if collect:
            states[f"tail{t}"] = st
    return x, states


def rg_forward(params, batch, cfg, ctx):
    x, _ = rg_backbone(params, batch["tokens"], cfg, ctx, False)
    x = rms_norm(x, params["final_norm"]["scale"])
    return (x @ params["head"]).astype(jnp.float32)


def rg_loss(params, batch, cfg, ctx):
    logits = rg_forward(params, batch, cfg, ctx)
    t = batch["targets"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def rg_prefill(params, batch, cfg, ctx):
    x, states = rg_backbone(params, batch["tokens"], cfg, ctx, True)
    x = rms_norm(x[:, -1:], params["final_norm"]["scale"])
    logits = (x @ params["head"]).astype(jnp.float32)
    return logits[:, 0], states


def rg_decode_step(params, state, token, pos, cfg, ctx):
    B = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0) \
        * math.sqrt(cfg.d_model)
    x = x.astype(cfg.dtype)
    positions = pos[:, None]

    def group(h, xs):
        g, st = xs
        h, s1 = rg_layer_apply(h, g["rec1"], "rec", cfg, positions,
                               state=st["rec1"], ctx=ctx)
        h, s2 = rg_layer_apply(h, g["rec2"], "rec", cfg, positions,
                               state=st["rec2"], ctx=ctx)
        h, sa = rg_layer_apply(h, g["attn"], "attn", cfg, positions,
                               state=st["attn"], cache_pos=pos, ctx=ctx)
        return h, {"rec1": s1, "rec2": s2, "attn": sa}

    x, gstates = jax.lax.scan(group, x, (params["groups"], state["groups"]))
    new_state = {"groups": gstates}
    G, tail = n_groups(cfg)
    for t in range(tail):
        x, st = rg_layer_apply(x, params[f"tail{t}"], "rec", cfg, positions,
                               state=state[f"tail{t}"], ctx=ctx)
        new_state[f"tail{t}"] = st
    x = rms_norm(x, params["final_norm"]["scale"])
    logits = (x @ params["head"]).astype(jnp.float32)
    return logits[:, 0], new_state
