"""Deterministic, restartable data pipeline.

Production property this reproduces: after a crash/restart at step k, the
pipeline re-issues *exactly* the batches k, k+1, ... (checkpoint stores only
the step number — no pipeline state files).  Achieved by deriving every
batch from ``fold_in(seed, step)``; multi-host sharding derives per-host
slices from ``fold_in(·, host_id)``.

Two sources:
  * ``SyntheticLM``   — zipf-ish token stream with documents + BOS/EOS
                        packing (shape-faithful stand-in for a tokenized
                        corpus; CPU container has no real corpus).
  * ``MemmapCorpus``  — a flat token memmap (e.g. tokenized The Pile shard)
                        sampled with the same deterministic schedule.

A double-buffering prefetch thread overlaps host batch assembly with device
compute (the data-side analogue of eager eviction: produce ahead, never
stall the consumer).
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticLM:
    """Deterministic synthetic LM batches: (tokens, targets) int32."""

    def __init__(self, vocab: int, seq: int, global_batch: int,
                 seed: int = 0, n_hosts: int = 1, host_id: int = 0) -> None:
        assert global_batch % n_hosts == 0
        self.vocab = vocab
        self.seq = seq
        self.batch = global_batch // n_hosts
        self.seed = seed
        self.host_id = host_id

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.host_id, step]))
        B, T, V = self.batch, self.seq, self.vocab
        # zipf-ish marginal over the vocab (reserve 0/1 for BOS/EOS)
        z = rng.zipf(1.3, size=(B, T + 1)).astype(np.int64)
        toks = 2 + (z % (V - 2))
        # document packing: segment lengths ~ geometric, BOS at starts
        doc_end = rng.random((B, T + 1)) < (1.0 / 256)
        toks = np.where(doc_end, 1, toks)               # EOS
        starts = np.roll(doc_end, 1, axis=1)
        starts[:, 0] = True
        toks = np.where(starts, 0, toks)                # BOS
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :T], "targets": toks[:, 1:T + 1]}


class MemmapCorpus:
    """Flat-token corpus (np.memmap/ndarray) with the same contract."""

    def __init__(self, tokens: np.ndarray, seq: int, global_batch: int,
                 seed: int = 0, n_hosts: int = 1, host_id: int = 0) -> None:
        assert global_batch % n_hosts == 0
        self.tokens = tokens
        self.seq = seq
        self.batch = global_batch // n_hosts
        self.seed = seed
        self.host_id = host_id
        self._n = len(tokens) - seq - 1

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.host_id, step]))
        offs = rng.integers(0, self._n, size=(self.batch,))
        toks = np.stack([self.tokens[o:o + self.seq + 1] for o in offs])
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :self.seq], "targets": toks[:, 1:]}


class Prefetcher:
    """Double-buffered background batch producer."""

    def __init__(self, source, start_step: int = 0, depth: int = 2) -> None:
        self.source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="data-prefetch")
        self._thread.start()

    def _run(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict[str, np.ndarray]]:
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
