"""Shared background-eviction worker pool for multi-shard volumes.

The paper's Caiti gives *each* device its own eviction threads.  On a
volume composed of N shards that wastes cores: a bursty shard starves
while an idle shard's workers spin.  This pool owns the eviction cores
for the whole volume and drains the shards' write-back queues
congestion-aware: workers prefer the shard with the deepest backlog and
fall back to round-robin among ties, so aggregate PMem bandwidth — the
contended resource — is spent where the staging pressure is.
"""
from __future__ import annotations

import threading
from collections import deque


class SharedEvictionPool:
    """N worker threads draining eviction work for many ``CaitiCache`` shards.

    Caches register themselves (``CaitiCache(..., evict_pool=pool)`` does it
    in its constructor); each registered cache gets a private backlog deque.
    ``submit(cache, slot)`` enqueues one slot for background transit; a
    worker later calls the cache's ``_evict_slot``/``_complete_eviction``
    exactly as the cache's private threads would, so per-cache flush
    accounting is unchanged.
    """

    def __init__(self, n_workers: int = 4, name: str = "vol") -> None:
        self.n_workers = n_workers
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queues: list[tuple[object, deque]] = []   # (cache, backlog)
        self._rr = 0
        self._picks = 0
        self._stop = False
        self._pending = 0
        self._workers = [
            threading.Thread(target=self._run, daemon=True,
                             name=f"{name}-evict-{i}")
            for i in range(n_workers)
        ]
        for w in self._workers:
            w.start()

    # ------------------------------------------------------------ interface
    def register(self, cache) -> None:
        with self._lock:
            self._queues.append((cache, deque()))

    def submit(self, cache, slot) -> None:
        with self._cond:
            for c, q in self._queues:
                if c is cache:
                    q.append(slot)
                    self._pending += 1
                    self._cond.notify()
                    return
        raise ValueError("cache not registered with this pool")

    def backlog(self) -> int:
        """Total slots queued across all shards (not yet picked up)."""
        with self._lock:
            return self._pending

    # ------------------------------------------------------------- workers
    def _pick(self):
        """Congestion-aware, starvation-free pick: picks alternate between
        the deepest backlog and plain round-robin over non-empty queues —
        a strictly-deepest rule would let a shard with one queued slot
        wait forever behind busier shards, wedging that shard's flush."""
        best = None
        best_depth = 0
        n = len(self._queues)
        self._picks += 1
        for off in range(n):
            i = (self._rr + off) % n
            depth = len(self._queues[i][1])
            if self._picks % 2 and depth > 0:       # RR turn: first non-empty
                best, best_depth = i, depth
                break
            if depth > best_depth:                  # congestion turn: deepest
                best, best_depth = i, depth
        if best is None:
            return None
        self._rr = (best + 1) % n
        cache, q = self._queues[best]
        self._pending -= 1
        return cache, q.popleft()

    def _run(self) -> None:
        while True:
            with self._cond:
                while self._pending == 0 and not self._stop:
                    self._cond.wait(timeout=0.5)
                if self._stop and self._pending == 0:
                    return
                picked = self._pick()
            if picked is None:
                continue
            cache, slot = picked
            try:
                cache._evict_slot(slot)
            finally:
                cache._complete_eviction()

    def close(self) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        for w in self._workers:
            w.join(timeout=2.0)
