"""Shared background-eviction worker pool for multi-shard volumes.

The paper's Caiti gives *each* device its own eviction threads.  On a
volume composed of N shards that wastes cores: a bursty shard starves
while an idle shard's workers spin.  This pool owns the eviction cores
for the whole volume and drains the shards' write-back queues
congestion-aware: workers prefer the shard with the deepest backlog and
fall back to round-robin among ties, so aggregate PMem bandwidth — the
contended resource — is spent where the staging pressure is.

**Per-socket banks (NUMA placement).**  On a real box each PMem DIMM set
hangs off one socket; an eviction core writing a remote socket's DIMMs
pays the interconnect.  The pool therefore partitions its workers into
``n_sockets`` banks (worker *i* serves socket ``i % n_sockets``) and
participants register with the socket that owns their media
(``register(cache, socket=...)``).  A bank drains its own socket's
queues first and only *steals* cross-socket work when its socket is
idle — locality when busy, work conservation always (a one-slot backlog
on a quiet socket can never wedge that shard's flush).

**Participants.**  Anything exposing the two drain hooks —
``_evict_slot(item)`` / ``_complete_eviction()`` — can register, not
just ``CaitiCache``: the volume's :class:`ReplicaResyncer` drains its
repair queue through the same cores, and ``PagedKVCache`` offloads its
eager page-out DMA here, so background resync and KV-spill traffic are
scheduled (and NUMA-placed) exactly like eviction writebacks.

**Batch draining.**  A worker's pick takes up to ``batch_max`` queued
items from the chosen participant in one go; a participant exposing the
optional ``_evict_slots(items)`` hook gets the whole batch in one call
(one lock acquisition / one fused transit-kernel launch for a burst),
otherwise the worker loops ``_evict_slot`` per item.  Completion
accounting is unchanged: ``_complete_eviction()`` fires once per item.

**Limping-shard steering.**  ``set_limping(participants)`` marks a set
of participants fail-slow (the volume pushes the
:class:`~repro.core.metrics.ShardScorer`'s verdict here): workers drain
every healthy backlog first and touch a limping participant's queue
only when nothing else has work — eviction bandwidth stops feeding the
device that is already 25x slow, but work conservation holds (a limping
shard with the only backlog still drains).  Each deferral is counted
(``steered_picks``) and reported through ``on_steer``.
"""
from __future__ import annotations

import threading
from collections import deque


class SharedEvictionPool:
    """N worker threads draining eviction work for many participants.

    Caches register themselves (``CaitiCache(..., evict_pool=pool)`` does it
    in its constructor); each registered participant gets a private backlog
    deque.  ``submit(cache, item)`` enqueues one work item for background
    processing; a worker later calls the participant's
    ``_evict_slot``/``_complete_eviction`` exactly as a cache's private
    threads would, so per-cache flush accounting is unchanged.
    """

    def __init__(self, n_workers: int = 4, name: str = "vol",
                 n_sockets: int = 1, batch_max: int = 8) -> None:
        assert n_sockets >= 1
        assert batch_max >= 1
        self.n_workers = n_workers
        self.n_sockets = min(n_sockets, max(1, n_workers))
        self.batch_max = batch_max
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        # (participant, backlog, socket)
        self._queues: list[tuple[object, deque, int]] = []
        self._rr = 0
        self._picks = 0
        self._stop = False
        self._pending = 0
        self.drained_by_socket = [0] * self.n_sockets
        self.stolen_picks = 0
        self.batched_drains = 0          # picks that drained > 1 item
        self.batched_items = 0           # items drained via batch picks
        # fail-slow steering: participants whose queues drain LAST
        self._limping: set[int] = set()  # participant ids (id() keys)
        self.on_steer = None             # callback per deferred pick
        self.steered_picks = 0
        self._workers = [
            threading.Thread(target=self._run, args=(i % self.n_sockets,),
                             daemon=True, name=f"{name}-evict-{i}")
            for i in range(n_workers)
        ]
        for w in self._workers:
            w.start()

    # ------------------------------------------------------------ interface
    def register(self, cache, socket: int = 0) -> None:
        with self._lock:
            self._queues.append((cache, deque(), socket % self.n_sockets))

    def unregister(self, cache) -> list:
        """Remove a participant and return its still-queued (never
        picked) items so the caller can settle its own accounting.
        Items a worker is ALREADY executing are not included — they
        complete through the normal ``_complete_eviction`` path."""
        with self._lock:
            for i, (c, q, _s) in enumerate(self._queues):
                if c is cache:
                    del self._queues[i]
                    self._pending -= len(q)
                    return list(q)
        return []

    def assign_socket(self, cache, socket: int) -> None:
        """Re-pin a registered participant to the socket owning its media
        (the volume calls this after building its shards — ``CaitiCache``
        registers itself before the volume knows the shard layout)."""
        with self._lock:
            for i, (c, q, _s) in enumerate(self._queues):
                if c is cache:
                    self._queues[i] = (c, q, socket % self.n_sockets)
                    return
        raise ValueError("cache not registered with this pool")

    def submit(self, cache, slot) -> None:
        with self._cond:
            for c, q, _s in self._queues:
                if c is cache:
                    q.append(slot)
                    self._pending += 1
                    self._cond.notify_all()
                    return
        raise ValueError("cache not registered with this pool")

    def backlog(self) -> int:
        """Total slots queued across all shards (not yet picked up)."""
        with self._lock:
            return self._pending

    def set_limping(self, participants, on_steer=None) -> None:
        """Mark ``participants`` (an iterable of registered caches) as
        fail-slow: their backlogs drain only when no healthy queue has
        work.  Idempotent — the volume's tail-state refresh calls this
        with the scorer's current verdict every pass."""
        with self._lock:
            self._limping = {id(p) for p in participants}
            if on_steer is not None:
                self.on_steer = on_steer

    # ------------------------------------------------------------- workers
    def _pick(self, socket: int):
        """Congestion-aware, starvation-free pick: picks alternate between
        the deepest backlog and plain round-robin over non-empty queues —
        a strictly-deepest rule would let a shard with one queued slot
        wait forever behind busier shards, wedging that shard's flush.
        Home-socket queues are tried first; an idle bank steals.
        Limping participants (``set_limping``) are deferred: their
        queues are eligible only when no healthy queue has work."""
        n = len(self._queues)
        self._picks += 1
        limping = self._limping
        for local_only in (True, False):
            best = None
            best_depth = 0
            deferred = False                        # skipped limping work
            for avoid in ((True, False) if limping else (False,)):
                deferred = False
                for off in range(n):
                    i = (self._rr + off) % n
                    c, q, s = self._queues[i]
                    if local_only and s != socket:
                        continue
                    if avoid and id(c) in limping:
                        if q:
                            deferred = True
                        continue
                    depth = len(q)
                    if self._picks % 2 and depth > 0:   # RR: first non-empty
                        best, best_depth = i, depth
                        break
                    if depth > best_depth:          # congestion turn: deepest
                        best, best_depth = i, depth
                if best is not None:
                    break                           # healthy work found
            if best is not None:
                if deferred:
                    # a limping backlog was passed over for healthy work
                    self.steered_picks += 1
                    if self.on_steer is not None:
                        self.on_steer()
                self._rr = (best + 1) % n
                cache, q, s = self._queues[best]
                # batch drain: one pick takes up to batch_max items from
                # the SAME participant's backlog — one wakeup (and, for
                # participants with the ``_evict_slots`` hook, one lock
                # acquisition / fused DMA) amortized over the burst
                batch = [q.popleft()]
                while q and len(batch) < self.batch_max:
                    batch.append(q.popleft())
                self._pending -= len(batch)
                self.drained_by_socket[socket] += len(batch)
                if not local_only:
                    self.stolen_picks += 1
                if len(batch) > 1:
                    self.batched_drains += 1
                    self.batched_items += len(batch)
                return cache, batch
            if local_only and self.n_sockets == 1:
                break                               # nothing anywhere
        return None

    def _run(self, socket: int) -> None:
        while True:
            with self._cond:
                while self._pending == 0 and not self._stop:
                    self._cond.wait(timeout=0.5)
                if self._stop and self._pending == 0:
                    return
                picked = self._pick(socket)
            if picked is None:
                continue
            cache, batch = picked
            bulk = getattr(cache, "_evict_slots", None)
            try:
                if bulk is not None and len(batch) > 1:
                    bulk(batch)
                else:
                    for slot in batch:
                        cache._evict_slot(slot)
            finally:
                for _ in batch:
                    cache._complete_eviction()

    def close(self) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        for w in self._workers:
            w.join(timeout=2.0)
