"""repro.volume — striped multi-device volume manager over PMem shards.

Generalizes the paper's single-device Caiti mechanism to a logical volume:

    make_volume(...)       — N-shard RAID-0 (optionally replicated) volume
    StripedVolume          — the volume manager itself
    VolumeConfig           — geometry + policy knobs
    SharedEvictionPool     — one background eviction pool drained
                             congestion-aware across all shards, in
                             per-socket (NUMA) worker banks
    VolumeJournal          — chained-tx redo journal: whole-object
                             all-or-nothing crash semantics for logical
                             writes of any size (tail header = commit pt)
    GroupCommitter         — leader/follower fsync coalescing (one drain
                             + superblock pass per concurrent batch)
    AdmissionPolicy        — unified admission: bypass watermark, read-
                             tier fill policy (sequential-scan bypass),
                             tier-aware QoS read pricing
    ScanDetector           — multi-stream sequential-run tracker
    ReadTier               — clean-slot CLOCK DRAM read cache fronting the
                             shards (never journaled)
    ReplicaResyncer        — background repair of divergent replica blocks
    TokenBucket, WFQGate   — per-tenant QoS (rate limits + weighted fair
                             scheduling)
    TenantSpec             — declarative tenant weight/rate description
    AsyncIOEngine, Ticket  — io_uring-style submission/completion
                             frontend (``StripedVolume.submit/poll``):
                             per-tenant SQs, shared completion ring,
                             bounded in-flight backpressure, per-ticket
                             failure isolation, IO_LINK ticket chains
    BufferRegistry         — registered zero-copy buffer pool: pinned
                             payloads instead of staging copies, with
                             copy-on-evict when a slot is reused early
    Controller, Knob       — self-tuning control plane: bounded
                             AIMD-style feedback over commit/log
                             windows, bypass watermark, scan threshold
                             and hedge delay, gated by hysteresis and
                             hard clamps (``attach_autotuner`` /
                             ``make_volume(autotune=True)``)

The read path (layered, new in PR 2)
------------------------------------
The paper's transit cache is write-only by design (§4.3.2: never allocate
a slot on a read miss), so every layer of the read path is stacked in
front of it instead of inside it.  A ``StripedVolume.read(lba)`` walks:

    1. **transit cache** — staged writes not yet evicted (newest data);
    2. **ReadTier** — one shared clean DRAM tier for all shards, keyed
       ``(shard, local_lba)``; populated on read miss and on eviction
       writeback, invalidated (fenced) by writes.  Clean slots only: the
       tier is never journaled and costs nothing at flush/crash time;
    3. **primary shard BTT** — the PMem media read;
    4. **verification** — with ``replicas > 1`` the result is checked
       against the write-time crc ledger; a failing primary falls back to
    5. **replica shard** (degraded read) — the verified replica copy is
       served, read-repaired into the tier under the primary's key, and
       the block is queued to the ``ReplicaResyncer``, which rewrites bad
       copies through atomic BTT writes on the shared eviction cores.

Writes are unchanged from the paper (stage -> eager eviction -> BTT,
conditional bypass under pressure); they only *invalidate* tier entries,
so crash atomicity (redo journal + BTT Flog) is untouched by the tier.
"""
from .admission import AdmissionPolicy, ScanDetector
from .autotune import (Controller, Knob, default_knobs,
                       make_default_controller)
from .aio import (AsyncIOEngine, BackpressureError, BufferRegistry,
                  CancelledError, LinkCancelledError, RegisteredBuf,
                  SubmitError, Ticket, TicketError)
from .evict_pool import SharedEvictionPool
from .journal import GroupCommitter, LogBatcher, LogEntry, VolumeJournal
from .qos import QoSError, TenantSpec, TokenBucket, WFQGate
from .read_tier import ReadTier, ReplicaResyncer
from .volume import StripedVolume, VolumeConfig, make_volume

__all__ = [
    "SharedEvictionPool", "VolumeJournal", "GroupCommitter", "LogBatcher",
    "LogEntry", "TokenBucket", "WFQGate", "TenantSpec", "QoSError",
    "StripedVolume", "VolumeConfig", "make_volume", "ReadTier",
    "ReplicaResyncer", "AdmissionPolicy", "ScanDetector",
    "AsyncIOEngine", "Ticket", "TicketError", "SubmitError",
    "BackpressureError", "CancelledError", "LinkCancelledError",
    "BufferRegistry", "RegisteredBuf",
    "Controller", "Knob", "default_knobs", "make_default_controller",
]
