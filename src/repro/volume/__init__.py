"""repro.volume — striped multi-device volume manager over PMem shards.

Generalizes the paper's single-device Caiti mechanism to a logical volume:

    make_volume(...)       — N-shard RAID-0 (optionally replicated) volume
    StripedVolume          — the volume manager itself
    VolumeConfig           — geometry + policy knobs
    SharedEvictionPool     — one background eviction pool drained
                             congestion-aware across all shards
    VolumeJournal          — redo journal giving multi-shard logical writes
                             all-or-nothing crash semantics
    TokenBucket, WFQGate   — per-tenant QoS (rate limits + weighted fair
                             scheduling)
    TenantSpec             — declarative tenant weight/rate description
"""
from .evict_pool import SharedEvictionPool
from .journal import VolumeJournal
from .qos import QoSError, TenantSpec, TokenBucket, WFQGate
from .volume import StripedVolume, VolumeConfig, make_volume

__all__ = [
    "SharedEvictionPool", "VolumeJournal", "TokenBucket", "WFQGate",
    "TenantSpec", "QoSError", "StripedVolume", "VolumeConfig", "make_volume",
]
