"""Self-tuning control plane: a feedback controller over the knob set.

The stack has ~10 load-bearing knobs (``commit_window``, ``log_window``,
the bypass watermark, ``scan_threshold``, the hedge delay, ...) that
PRs 1-8 froze at hand-picked defaults.  Static tunings lose the moment
the workload shifts: a ``commit_window`` that amortizes four syncing
tenants is pure added latency once the workload turns read-only, and a
``scan_threshold`` tuned for backup scans starves a serving tier whose
working set *is* long sequential runs (NVCache's plug-and-play
adaptivity and the Optane-DBMS "lessons learned" evaluation both make
this argument; PAPERS.md).  This module closes the loop:

  signals (metrics layer)          Controller             applied knobs
  ---------------------------      -----------------      --------------
  fsync rate, coalesce rate   ──>  per-knob decision ──>  commit_window
  log rate, log coalesce      ──>  rules vote +1/-1  ──>  log_window
  stall / bypass rates        ──>  moves gated by    ──>  bypass watermark
  tier hit + scan denials     ──>  HYSTERESIS, step  ──>  scan_threshold
  scrub()["tail"] verdicts    ──>  sizes bounded by  ──>  hedge delay
  per-tenant p99 vs SLO       ──>  hard CLAMPS       ──>  (all of the above)

Control discipline (the safety story, enforced by tests):

  * **bounded AIMD-style steps** — a knob raises by one additive
    ``quantum`` per move and lowers multiplicatively (``decay`` x),
    snapping to its floor once a decrease lands within half a quantum
    of it, so windows really return to 0 instead of asymptoting;
  * **hard clamps** — every knob declares ``[lo, hi]``; a move lands
    inside the range or does not happen.  The controller can NEVER
    push a knob past its clamp, no matter what the signals say;
  * **hysteresis** — a knob moves only after ``hysteresis`` consecutive
    same-direction votes, and a *reversal* (raise after lower or vice
    versa) must clear twice that bar — one noisy window cannot flap a
    knob, and sustained oscillation pressure damps instead of ringing;
  * **per-tenant SLOs** — ``slos={"gold": {"p99_us": 500}}`` (or
    ``"*"`` for a fleet-wide target) turns observed per-tenant p99s
    into a pressure term that biases latency-adding knobs downward
    while the SLO is violated.

The controller is deliberately transport-agnostic: it consumes a flat
``signals`` dict of rates and latencies, so the SAME object drives the
threaded :class:`~repro.volume.volume.StripedVolume`
(``autotune_step()`` computes signal deltas from the live metrics
layer), the :class:`~repro.cluster.cluster.ClusterVolume`, and the
virtual-time ``run_autotune_sim_workload`` in ``core/sim.py`` — the
repo's established idiom of the simulator validating the real policy
object rather than a reimplementation of it.
"""
from __future__ import annotations


class Knob:
    """One tunable with hard clamps, bounded steps and hysteresis.

    ``vote(direction)`` is the only mutator: the controller's decision
    rule votes +1 (raise) / -1 (lower) / 0 (hold) once per control
    tick; the knob moves only after ``hysteresis`` consecutive
    same-direction votes (doubled after a reversal) and every move
    lands inside ``[lo, hi]`` by construction.
    """

    __slots__ = ("name", "value", "lo", "hi", "quantum", "decay",
                 "integer", "hysteresis", "moves", "raises", "lowers",
                 "rail_hits", "_trend", "_last_dir")

    def __init__(self, name: str, value: float, lo: float, hi: float, *,
                 quantum: float, decay: float = 0.5,
                 integer: bool = False, hysteresis: int = 2) -> None:
        assert lo <= hi and quantum > 0 and 0.0 < decay < 1.0
        assert hysteresis >= 1
        self.name = name
        self.lo = lo
        self.hi = hi
        self.quantum = quantum
        self.decay = decay
        self.integer = integer
        self.hysteresis = hysteresis
        self.value = self._clamp(value)
        self.moves = 0
        self.raises = 0
        self.lowers = 0
        self.rail_hits = 0        # votes that found the knob at a rail
        self._trend = 0           # consecutive same-direction votes
        self._last_dir = 0        # direction of the last APPLIED move

    def _clamp(self, v: float) -> float:
        v = min(self.hi, max(self.lo, v))
        return float(round(v)) if self.integer else v

    def set(self, v: float) -> float:
        """Re-seed the knob (e.g. from a live config at attach time);
        clamped, trend reset, not counted as a controller move."""
        self.value = self._clamp(v)
        self._trend = 0
        self._last_dir = 0
        return self.value

    def in_range(self, v: float | None = None) -> bool:
        v = self.value if v is None else v
        return self.lo <= v <= self.hi

    def vote(self, direction: int) -> float | None:
        """One control-tick decision.  Returns the new value iff the
        knob moved, else None (held, gathering hysteresis, or pinned
        at a rail)."""
        if direction == 0:
            self._trend = 0
            return None
        if self._trend * direction < 0:
            self._trend = direction          # vote flip: restart trend
        else:
            self._trend += direction
        need = self.hysteresis
        if self._last_dir and direction == -self._last_dir:
            need *= 2                        # reversal: damp, don't ring
        if abs(self._trend) < need:
            return None
        self._trend = 0
        return self._move(direction)

    def _move(self, direction: int) -> float | None:
        old = self.value
        if direction > 0:
            v = self.value + self.quantum    # additive increase
        else:
            v = self.value * self.decay      # multiplicative decrease
            if v - self.lo < 0.5 * self.quantum:
                v = self.lo                  # snap to the floor
        v = self._clamp(v)
        if self.integer and direction > 0 and v == old and old < self.hi:
            v = self._clamp(old + 1.0)
        if v == old:
            self.rail_hits += 1              # already pinned at a clamp
            return None
        self.value = v
        self.moves += 1
        if direction > 0:
            self.raises += 1
        else:
            self.lowers += 1
        self._last_dir = direction
        return v

    def stats(self) -> dict:
        return {"value": self.value, "lo": self.lo, "hi": self.hi,
                "moves": self.moves, "raises": self.raises,
                "lowers": self.lowers, "rail_hits": self.rail_hits}


def default_knobs(*, hysteresis: int = 2) -> list[Knob]:
    """The five knobs the control plane owns, with their safe clamp
    ranges.  Windows are MICROSECONDS here (the sim's native unit); the
    threaded volume converts to seconds when applying."""
    return [
        Knob("commit_window_us", 0.0, 0.0, 200.0, quantum=20.0,
             hysteresis=hysteresis),
        Knob("log_window_us", 0.0, 0.0, 200.0, quantum=20.0,
             hysteresis=hysteresis),
        Knob("bypass_watermark", 0.9, 0.5, 0.98, quantum=0.04,
             hysteresis=hysteresis),
        Knob("scan_threshold", 64.0, 8.0, 512.0, quantum=32.0,
             integer=True, hysteresis=hysteresis),
        Knob("hedge_delay_us", 1000.0, 50.0, 5000.0, quantum=250.0,
             hysteresis=hysteresis),
    ]


class Controller:
    """Feedback controller: flat signal dict in, knob moves out.

    ``observe(signals)`` runs every knob's decision rule once and
    returns ``{knob_name: new_value}`` for the knobs that actually
    moved this tick (usually empty — hysteresis holds).  Signals are
    window RATES (per-op fractions over the interval since the last
    tick) plus a few absolute latencies; missing keys are neutral, so
    any layer can wire up the subset it can measure:

      ``ops``                window op count (informational)
      ``fsync_rate``         fsyncs per op
      ``coalesce_rate``      fraction of fsyncs that rode a leader
      ``log_rate``           chained-tx log calls per op
      ``log_coalesce_rate``  fraction of chains that rode a batch
      ``stall_rate``         foreground eviction stalls per op
      ``bypass_rate``        writes bypassed straight to PMem, per write
      ``staged_frac``        staged slots / total slots (instantaneous)
      ``read_rate``          reads per op
      ``tier_hit_rate``      DRAM tier hits per read
      ``scan_denial_rate``   tier fills denied as scans, per read
      ``limping``            any shard/node currently limping (bool)
      ``healthy_p99_us``     scorer's healthy-cohort p99 (hedge basis)
      ``pin_rate``           zero-copy pin rate (informational)
      ``wfq_debt_share``     worst tenant's WFQ debt share (info)
      ``per_tenant_p99_us``  {tenant: window p99} — matched to SLOs

    Per-tenant SLOs (``slos={"gold": {"p99_us": 500}, "*": {...}}``)
    produce a *pressure* ratio (worst observed p99 / target); pressure
    above 1 vetoes raises of the latency-adding window knobs and votes
    them down instead.
    """

    #: signal thresholds (class attrs so tests/benches can tighten them)
    FSYNC_HOT = 0.02          # fsyncs/op above which windows matter
    FSYNC_COLD = 0.005        # below: the window is pure latency tax
    COALESCE_TARGET = 0.6     # stop widening once this share coalesces
    LOG_HOT = 0.02
    LOG_COLD = 0.005
    STALL_HOT = 0.005         # stalls/op that justify earlier bypass
    BYPASS_HOT = 0.25         # bypassed-write share worth re-staging
    TIER_COLD = 0.2           # tier hit rate low enough to suspect scans
    SCAN_DENIAL_HOT = 0.2     # denial rate high enough to suspect a
    TIER_HOT = 0.5            # ...hot set misread as a scan
    HEDGE_BAND = 1.5          # deadband ratio around the hedge target
    SLO_BAND = 1.0            # pressure above this biases latency down

    def __init__(self, knobs: list[Knob] | None = None, *,
                 slos: dict[str, dict] | None = None,
                 hysteresis: int = 2) -> None:
        self.knobs: dict[str, Knob] = {
            k.name: k for k in (knobs if knobs is not None
                                else default_knobs(hysteresis=hysteresis))}
        self.slos = dict(slos or {})
        self.ticks = 0
        self.total_moves = 0
        self.history: list[tuple[int, str, float, float]] = []
        self.last_signals: dict = {}
        self.last_pressure = 0.0

    # ------------------------------------------------------------- wiring
    def bind(self, values: dict[str, float]) -> None:
        """Seed knob values from a live config (attach time): the
        controller starts from what the stack is actually running, not
        from its own defaults.  Unknown names are ignored; values are
        clamped into the knob's declared range."""
        for name, v in values.items():
            knob = self.knobs.get(name)
            if knob is not None:
                knob.set(v)

    def value(self, name: str) -> float:
        return self.knobs[name].value

    def values(self) -> dict[str, float]:
        return {name: k.value for name, k in self.knobs.items()}

    def clamp_range(self, name: str) -> tuple[float, float]:
        k = self.knobs[name]
        return (k.lo, k.hi)

    # ------------------------------------------------------------ control
    def slo_pressure(self, signals: dict) -> float:
        """Worst observed-p99 / target-p99 over the tenants with SLOs
        (``"*"`` matches every observed tenant).  0 when nothing to
        compare; > 1 means a violation is in progress."""
        per = signals.get("per_tenant_p99_us") or {}
        press = 0.0
        wild = self.slos.get("*", {}).get("p99_us")
        for tenant, p99 in per.items():
            target = self.slos.get(tenant, {}).get("p99_us", wild)
            if target and target > 0:
                press = max(press, p99 / target)
        if not per and wild and signals.get("p99_us"):
            press = signals["p99_us"] / wild
        return press

    def observe(self, signals: dict) -> dict[str, float]:
        """One control tick: vote every knob, return the applied moves
        (``{name: new_value}``; empty on hold ticks)."""
        self.ticks += 1
        self.last_signals = dict(signals)
        press = self.slo_pressure(signals)
        self.last_pressure = press
        changed: dict[str, float] = {}
        for name, decide in (
                ("commit_window_us", self._decide_commit_window),
                ("log_window_us", self._decide_log_window),
                ("bypass_watermark", self._decide_watermark),
                ("scan_threshold", self._decide_scan_threshold),
                ("hedge_delay_us", self._decide_hedge_delay)):
            knob = self.knobs.get(name)
            if knob is None:
                continue
            old = knob.value
            new = knob.vote(decide(signals, press, knob))
            if new is not None:
                changed[name] = new
                self.total_moves += 1
                self.history.append((self.ticks, name, old, new))
        return changed

    # ------------------------------------------------- per-knob decisions
    def _decide_commit_window(self, s: dict, press: float,
                              knob: Knob) -> int:
        rate = s.get("fsync_rate", 0.0)
        coal = s.get("coalesce_rate", 0.0)
        if rate >= self.FSYNC_HOT and coal < self.COALESCE_TARGET \
                and press <= self.SLO_BAND:
            return +1                 # syncs queueing un-coalesced: widen
        if knob.value > knob.lo and (rate < self.FSYNC_COLD
                                     or press > self.SLO_BAND):
            return -1                 # window is pure latency tax: decay
        return 0

    def _decide_log_window(self, s: dict, press: float,
                           knob: Knob) -> int:
        rate = s.get("log_rate", 0.0)
        coal = s.get("log_coalesce_rate", 0.0)
        if rate >= self.LOG_HOT and coal < self.COALESCE_TARGET \
                and press <= self.SLO_BAND:
            return +1
        if knob.value > knob.lo and (rate < self.LOG_COLD
                                     or press > self.SLO_BAND):
            return -1
        return 0

    def _decide_watermark(self, s: dict, press: float,
                          knob: Knob) -> int:
        stalls = s.get("stall_rate", 0.0)
        bypass = s.get("bypass_rate", 0.0)
        if stalls > self.STALL_HOT:
            return -1                 # evict-on-critical-path: bypass earlier
        if stalls <= self.STALL_HOT / 5 and bypass > self.BYPASS_HOT:
            return +1                 # staging has headroom: use the DRAM
        return 0

    def _decide_scan_threshold(self, s: dict, press: float,
                               knob: Knob) -> int:
        reads = s.get("read_rate", 0.0)
        hits = s.get("tier_hit_rate", 0.0)
        denials = s.get("scan_denial_rate", 0.0)
        if reads > 0.5 and hits < self.TIER_COLD \
                and denials < self.SCAN_DENIAL_HOT / 4:
            return -1                 # undetected scans flushing the tier
        if denials > self.SCAN_DENIAL_HOT and hits > self.TIER_HOT:
            return +1                 # hot working set misread as a scan
        return 0

    def _decide_hedge_delay(self, s: dict, press: float,
                            knob: Knob) -> int:
        if not s.get("limping"):
            return 0                  # healthy fleet: leave the trigger be
        target = s.get("healthy_p99_us", 0.0)
        if target <= 0:
            return 0
        if target > knob.value * self.HEDGE_BAND:
            return +1                 # trigger fires on healthy requests
        if target < knob.value / self.HEDGE_BAND:
            return -1                 # trigger too lazy to save the tail
        return 0

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {"ticks": self.ticks, "total_moves": self.total_moves,
                "last_pressure": round(self.last_pressure, 4),
                "knobs": {n: k.stats() for n, k in self.knobs.items()}}


def make_default_controller(slos: dict[str, dict] | None = None, *,
                            hysteresis: int = 2) -> Controller:
    """The stock control plane: the five default knobs at their declared
    clamps, optional per-tenant SLOs (``{"tenant": {"p99_us": x}}``,
    ``"*"`` wildcard)."""
    return Controller(default_knobs(hysteresis=hysteresis), slos=slos,
                      hysteresis=hysteresis)
