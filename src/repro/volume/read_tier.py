"""DRAM read tier + replica resync — the read half of the layered I/O stack.

The paper's transit cache is deliberately *write-only* (§4.3.2: never
allocate a slot on a read miss — writes are prioritized because PMem
writes are the expensive direction).  That is right for the write path
but leaves read-heavy serving workloads paying a full BTT/PMem round
trip on every access.  NVCache (Dulong et al.) and the PMem I/O
primitives study (van Renen et al.) both show a clean DRAM read tier in
front of NVM pays for itself once reads dominate.

:class:`ReadTier` is that tier: a CLOCK (second-chance) cache over
uniform slots holding only CLEAN data — blocks that are already durable
on the device below.  It therefore needs **no journal interplay** and no
flush handling: losing it costs hits, never data.  Consistency is a
three-rule protocol:

  * **populated** on read miss (the fill) and on transit-eviction
    writeback (the block just left the write cache but is still warm);
  * **invalidated** by every write before the write enters the transit
    cache — the transit cache is probed before the tier, so readers see
    the newest staged copy, and the eviction writeback re-populates the
    tier with the new data;
  * fills are **fenced**: a fill races an invalidate when a reader is
    still copying old data out of the backend while a writer updates the
    block.  ``prepare()`` hands the reader a fence token before it
    touches the backend; ``insert()`` with a stale token is dropped.
    Writeback/repair inserts carry no token (their data is authoritative).

:class:`ReplicaResyncer` is the repair half of degraded reads: when a
replicated volume serves a read from a replica because the primary shard
failed verification, the divergent block is queued here and a background
worker rewrites the bad copies from the good one.  The resyncer plugs
into the volume's :class:`~repro.volume.evict_pool.SharedEvictionPool`
as just another drain participant, so repair traffic shares the eviction
cores (and their per-socket banks) instead of spawning a private pool.
"""
from __future__ import annotations

import threading
from collections import deque

import numpy as np


class ReadTier:
    """CLOCK/second-chance read-mostly cache over uniform clean slots.

    Two storage modes share the one replacement policy:

      * **block mode** (``block_size`` set): a preallocated
        ``(n_slots, block_size)`` uint8 buffer — the volume/device tier;
      * **object mode** (``block_size=None``): slots hold arbitrary
        Python objects (e.g. dequantized KV pages) — the serving tier.

    Keys are opaque hashables; multi-device volumes use ``(shard, lba)``
    so one tier fronts every shard.
    """

    def __init__(self, capacity_bytes: int = 64 << 20,
                 block_size: int | None = 4096, *,
                 n_slots: int | None = None, metrics=None) -> None:
        if n_slots is None:
            assert block_size, "object mode needs an explicit n_slots"
            n_slots = max(1, capacity_bytes // block_size)
        self.block_size = block_size
        self.n_slots = n_slots
        self.metrics = metrics
        # optional AdmissionPolicy: read-miss fills (token path) from
        # sequential scans are dropped so they cannot flush the hot set.
        # The volume installs its unified policy here; direct users of
        # the tier get the same protection as cache-fronted reads.
        self.admission = None
        self._buf = (np.zeros((n_slots, block_size), dtype=np.uint8)
                     if block_size else None)
        self._objs: list = [None] * (0 if block_size else n_slots)
        self._keys: list = [None] * n_slots
        self._ref = bytearray(n_slots)
        self._map: dict = {}                   # key -> slot index
        # fill fences, key -> [epoch, outstanding_fills].  An entry exists
        # ONLY while a prepared fill is in flight (prepare creates it,
        # the matching insert retires it), so memory is bounded by fill
        # concurrency, not by the written address space.  Invalidation
        # with no fill in flight needs no fence: there is nothing racing.
        self._fence: dict = {}
        self._hand = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.fills = 0
        self.invalidations = 0
        self.rejected_fills = 0

    # ------------------------------------------------------------- lookup
    def lookup(self, key, out: np.ndarray | None = None):
        """Return the cached block/object (second chance granted), or None."""
        with self._lock:
            slot = self._map.get(key)
            if slot is None:
                self.misses += 1
                return None
            self._ref[slot] = 1
            self.hits += 1
            if self.metrics is not None:
                self.metrics.bump("read_tier_hits")
            if self.block_size is None:
                return self._objs[slot]
            if out is not None:
                out[:] = self._buf[slot]
                return out
            return self._buf[slot].copy()

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._map

    # -------------------------------------------------------------- fills
    def prepare(self, key) -> int:
        """Fence token for a read-miss fill: grab BEFORE reading the
        backend, pass to insert() so a racing write drops the stale fill.
        Every prepare() MUST be paired with exactly one insert(token=)."""
        with self._lock:
            st = self._fence.get(key)
            if st is None:
                st = self._fence[key] = [0, 0]
            st[1] += 1
            return st[0]

    def insert(self, key, data, token: int | None = None) -> bool:
        """Install ``data`` under ``key``; returns False if fenced off or
        denied by the admission policy (sequential-scan fills).  Writeback
        and repair inserts (no token) are always admitted — their data is
        authoritative and already paid for."""
        with self._lock:
            if token is not None:
                st = self._fence.get(key)
                stale = st is not None and st[0] != token
                if st is not None:            # retire this fill's fence ref
                    st[1] -= 1
                    if st[1] <= 0:
                        del self._fence[key]
                if stale:
                    self.rejected_fills += 1
                    return False
                if self.admission is not None \
                        and not self.admission.admit_key_fill(key):
                    self.rejected_fills += 1
                    return False
            slot = self._map.get(key)
            if slot is None:
                slot = self._clock_victim()
                old = self._keys[slot]
                if old is not None:
                    del self._map[old]
                self._keys[slot] = key
                self._map[key] = slot
            self._ref[slot] = 1
            if self.block_size is None:
                self._objs[slot] = data
            else:
                src = np.frombuffer(bytes(data), dtype=np.uint8) \
                    if not isinstance(data, np.ndarray) else data
                self._buf[slot, :src.size] = src.reshape(-1)[:self.block_size]
            self.fills += 1
            if self.metrics is not None:
                self.metrics.bump("read_tier_fills")
            return True

    def _clock_victim(self) -> int:
        """Second chance: sweep the hand, clearing ref bits, until a slot
        with a clear bit comes up (bounded by two sweeps)."""
        for _ in range(2 * self.n_slots):
            slot = self._hand
            self._hand = (self._hand + 1) % self.n_slots
            if self._keys[slot] is None or not self._ref[slot]:
                return slot
            self._ref[slot] = 0
        return self._hand                       # pragma: no cover

    # ------------------------------------------------------- invalidation
    def invalidate(self, key) -> None:
        """Drop ``key``; advance its fence if a fill is in flight."""
        with self._lock:
            st = self._fence.get(key)
            if st is not None:
                st[0] += 1
            slot = self._map.pop(key, None)
            if slot is not None:
                self._keys[slot] = None
                self._ref[slot] = 0
                if self.block_size is None:
                    self._objs[slot] = None
                self.invalidations += 1

    def clear(self) -> None:
        with self._lock:
            self._map.clear()
            self._fence.clear()
            self._keys = [None] * self.n_slots
            self._ref = bytearray(self.n_slots)
            if self.block_size is None:
                self._objs = [None] * self.n_slots

    # --------------------------------------------------------------- stats
    def __len__(self) -> int:
        with self._lock:
            return len(self._map)

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "fills": self.fills, "invalidations": self.invalidations,
                "rejected_fills": self.rejected_fills,
                "resident": len(self), "n_slots": self.n_slots,
                "hit_rate": self.hit_rate()}


class ReplicaResyncer:
    """Background repair of divergent replica blocks.

    Foreground degraded reads (and ``resync()`` sweeps) enqueue logical
    lbas; repair work is drained either by the volume's shared eviction
    pool (``pool`` given — the resyncer registers as one more pool
    participant, optionally pinned to a NUMA ``socket`` bank) or by a
    private daemon thread.  Repair of one lba:

      1. read every copy straight from the shard BTTs (below the caches);
      2. pick the good copy — the volume's write-crc ledger arbitrates;
         with no ledger entry, majority vote, then primary, wins;
      3. rewrite the divergent copies via atomic BTT block writes and
         refresh/invalidate the read tier so later reads see the repair.

    Foreground I/O is never blocked: repairs touch the BTTs directly
    (block-atomic) and take NO volume locks — a pool worker must never
    wait on ``_txlock`` while ``fsync`` holds it waiting for the pool to
    drain (deadlock).  A foreground write racing a repair is detected by
    re-checking the crc ledger right before each rewrite; the residual
    window (write lands between recheck and rewrite) leaves one stale
    *replica* copy, which is exactly the divergence this machinery
    detects and repairs — reads stay correct (verification degrades
    around the stale copy) and the next scrub/resync converges it.
    """

    def __init__(self, volume, pool=None, *, socket: int = 0) -> None:
        self.vol = volume
        self.pool = pool
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._queued: set[int] = set()         # dedup: lba -> at most one job
        self._inflight = 0
        self.repaired_blocks = 0
        self.clean_rechecks = 0
        self._stop = False
        self._work: deque[int] = deque()
        if pool is not None:
            pool.register(self, socket=socket)
            self._thread = None
        else:
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name="replica-resync")
            self._thread.start()

    # ----------------------------------------------------------- requests
    def request(self, lba: int) -> bool:
        """Queue one logical block for repair (deduplicated)."""
        with self._cond:
            if self._stop or lba in self._queued:
                return False
            self._queued.add(lba)
            self._inflight += 1
            if self.pool is not None:
                self.pool.submit(self, lba)
            else:
                self._work.append(lba)
                self._cond.notify()
        return True

    def resync(self, sample_every: int = 1) -> int:
        """Scrub-and-queue sweep: every divergent (shard, lba) pair found
        by the volume scrub becomes one repair request; returns how many
        lbas were queued."""
        n = 0
        for lba in {lba for lba, _r, _s, _l
                    in self.vol.scrub_replicas_detail(sample_every)}:
            if self.request(lba):
                n += 1
        return n

    def wait_idle(self, timeout: float = 30.0) -> bool:
        """Block until every queued repair completed (tests/sweeps)."""
        with self._cond:
            return self._cond.wait_for(lambda: self._inflight == 0,
                                       timeout=timeout)

    # ----------------------------------------- pool-participant interface
    # The shared pool drains participants through the same two hooks a
    # CaitiCache exposes, so repairs ride the eviction cores unchanged.
    def _evict_slot(self, lba: int) -> None:
        try:
            self._repair(lba)
        finally:
            with self._cond:
                self._queued.discard(lba)

    def _complete_eviction(self, n: int = 1) -> None:
        with self._cond:
            self._inflight -= n
            self._cond.notify_all()

    # ------------------------------------------------------------- repair
    def _repair(self, lba: int) -> None:
        vol = self.vol
        copies = []
        for r in range(vol.cfg.replicas):
            shard, local = vol._map(lba, r)
            copies.append((r, shard, local,
                           bytes(vol.shards[shard].impl.btt.read(local))))
        good = vol._pick_good_copy(lba, [c[3] for c in copies])
        if good is None:
            return                              # nothing trustworthy: leave it
        dirty = [c for c in copies if c[3] != good]
        if not dirty:
            self.clean_rechecks += 1
            return
        buf = np.frombuffer(good, dtype=np.uint8)
        for _r, shard, local, _data in dirty:
            # lock-free recheck: a foreground write that landed after our
            # reads owns the block now (its ledger crc no longer matches
            # our snapshot) — skip, the write made every copy consistent
            if vol._ledger_disagrees(lba, good):
                break
            vol.shards[shard].impl.btt.write(local, buf)
            tier = vol.read_tier
            if tier is not None:
                tier.invalidate((shard, local))
            self.repaired_blocks += 1
            if vol.metrics is not None:
                vol.metrics.bump("resync_repairs")

    # ----------------------------------------------------- private worker
    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._work and not self._stop:
                    self._cond.wait(timeout=0.5)
                if self._stop and not self._work:
                    return
                lba = self._work.popleft()
            try:
                self._evict_slot(lba)
            finally:
                self._complete_eviction()

    def close(self) -> None:
        """Stop accepting repairs, drain what is already queued, and
        UNREGISTER from the shared pool — the volume closes its shard
        devices right after this, and a pool worker must never touch a
        closed device's mmap (even if the drain wait timed out)."""
        with self._cond:
            self._stop = True
            self._cond.notify_all()
            self._cond.wait_for(lambda: self._inflight == 0, timeout=10.0)
        if self.pool is not None:
            dropped = self.pool.unregister(self)
            if dropped:                  # never picked: settle accounting
                self._complete_eviction(len(dropped))
            with self._cond:             # stragglers already on a worker
                self._cond.wait_for(lambda: self._inflight == 0, timeout=2.0)
        if self._thread is not None:
            self._thread.join(timeout=2.0)
