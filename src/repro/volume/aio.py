"""Asynchronous submission/completion I/O frontend for the striped volume.

Every entry point the stack had so far — ``CaitiCache.write``,
``StripedVolume.write_multi`` / ``fsync`` / ``read`` — is a *blocking*
call: the submitting thread rides the whole stack down to the media and
back, so callers serialize exactly the PMem stalls the paper's transit
cache exists to hide.  :class:`AsyncIOEngine` is the io_uring-style
front end that decouples submission from completion:

  * **per-tenant submission queues** — ``submit(op, ...)`` appends a
    :class:`Ticket` to the caller's tenant SQ and returns immediately;
    dispatch merges the SQs in global submission order (per-tenant FIFO,
    oldest seq first), so one tenant's burst cannot reorder another's
    ops;
  * **shared completion ring** — finished tickets land on one CQ;
    ``poll()`` drains it (oldest first), ``wait(ticket)`` blocks for one
    ticket.  ``Ticket.result()`` returns the op's value or re-raises its
    error;
  * **backpressure at submit time** — each tenant has a bounded
    in-flight window (``max_inflight_per_tenant``, the submit-side
    analogue of ``WFQGate``'s dispatch window).  A submit that would
    exceed the bound FAILS ITS TICKET with :class:`SubmitError` instead
    of blocking the caller or deadlocking the ring; deeper WFQ pricing
    still happens on the execution path (ops run through the volume's
    normal ``tenant=`` admission: token bucket + tier-aware SFQ tags);
  * **async fsync barriers** — an ``op='fsync'`` ticket dispatches only
    once every earlier-submitted ticket has completed (io_uring's
    IO_DRAIN), then rides the volume's existing
    :class:`~repro.volume.journal.GroupCommitter`: concurrent async
    fsyncs from several engine workers elect ONE leader for the batch.
    Chained ``write_multi`` tickets likewise coalesce behind the
    :class:`~repro.volume.journal.LogBatcher` leader when workers
    overlap;
  * **eviction-drain completion callbacks** — an ``op='flush'`` ticket
    (the WBQ-drain barrier) does not park a worker in
    ``CaitiCache.flush``: it registers a one-shot drain waiter on every
    shard cache (``CaitiCache.add_drain_waiter``) and completes from the
    eviction pool's completion path when the last in-flight writeback
    lands;
  * **per-ticket failures** — an injected device error (or a journal
    ring overflow, a cancelled ticket, a submit after close) surfaces on
    THAT ticket's ``error``, never as a stack-wide exception tearing
    down the ring.  Only :class:`~repro.core.SimulatedCrash` is fatal:
    it models power loss, so the engine marks itself dead, fails every
    queued ticket, and (in deterministic mode) re-raises so crash
    harnesses observe the loss exactly like the synchronous sweeps do;
  * **registered buffer pools** (io_uring ``register_buffers``) — a
    :class:`BufferRegistry` of pre-pinned arrays.  A write whose payload
    is a :class:`RegisteredBuf` is PINNED, not snapshotted: the engine
    holds the caller's array until the op completes and releases it back
    to the pool from the completion (or cancel — see below) path.  An
    UNREGISTERED mutable payload (ndarray / bytearray / memoryview) gets
    a defensive staging copy at submit — the caller may reuse it
    immediately, which is exactly the copy tax registration removes
    (``bytes`` payloads are immutable and ride for free either way).  A
    caller that re-``acquire()``\\ s from an exhausted pool steals the
    oldest still-QUEUED pinned buffer: the engine snapshots it at THAT
    moment (copy-on-evict — the only copy left, and only when the
    caller reuses a slot before durability).  Reads accept ``out=`` and
    land directly in the caller's (registered) array — the completion
    hands back the caller's own buffer, no post-poll copy;
  * **linked SQEs** (io_uring ``IO_LINK``) — ``submit(...,
    link_to=parent)`` makes a ticket chain: the dependent dispatches
    only after its parent completes OK, IN-ENGINE, so write→fsync,
    write→read-back-verify and restore read→scatter sequences need one
    ``wait`` on the chain tail instead of one poll round trip per hop.
    A failed (or cancelled) link fails every transitive dependent with
    :class:`LinkCancelledError` ("ECANCELED") on the completion ring —
    dependents are cancelled, never silently dropped, and unrelated
    tickets are untouched (per-ticket isolation).  Cancelling a
    mid-chain ticket likewise cancels its dependents AND releases every
    registered buffer the chain had pinned back to the pool.

Two execution modes share all of the above:

  * ``n_workers >= 1`` (default): background worker threads drain the
    SQs — real overlap for the threaded volume;
  * ``n_workers == 0`` (**deterministic mode**, used by the
    crash/fault-injection harness in ``tests/aio_harness.py``): nothing
    runs until ``poll()`` / ``wait()`` executes queued ops inline, one
    at a time, in submission order — every interleaving of
    submit/poll/crash is replayable from a seed.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque

import numpy as np

from repro.core.pmem import SimulatedCrash

# ticket states
QUEUED, RUNNING, DONE = range(3)

_BARRIER_OPS = ("fsync", "flush")
_OPS = ("write", "write_multi", "read", "fsync", "flush")
_PENDING = object()          # sentinel: op completes via callback later


class TicketError(RuntimeError):
    """Base class for engine-side (not device-side) ticket failures."""


class SubmitError(TicketError):
    """The submit itself was refused (closed engine / unknown op)."""


class BackpressureError(SubmitError):
    """The submit was refused because the tenant is at its in-flight
    bound — the retryable refusal: settle a completion and resubmit."""


class CancelledError(TicketError):
    """The ticket was cancelled before dispatch."""


class LinkCancelledError(CancelledError):
    """ECANCELED: an earlier ticket in this SQE chain failed (or was
    cancelled), so this dependent never dispatched.  The chain's root
    cause rides on the PARENT ticket's ``error``."""


class RegisteredBuf:
    """One buffer of a :class:`BufferRegistry` pool.  ``data`` is the
    caller-visible uint8 array; fill it and pass the handle as a write's
    ``data=`` (or a read's ``out=``) to pin it instead of copying."""

    __slots__ = ("idx", "data", "_registry")

    def __init__(self, idx: int, data, registry) -> None:
        self.idx = idx
        self.data = data
        self._registry = registry

    @property
    def nbytes(self) -> int:
        return self.data.nbytes

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"RegisteredBuf({self.idx}, {self.data.nbytes}B)"


class BufferRegistry:
    """Registered buffer pool (io_uring ``register_buffers``): a fixed
    set of pre-allocated arrays the engine pins instead of copying.

    Lifecycle: ``acquire()`` hands out a free buffer; submitting it pins
    it to that ticket; the ticket's completion (success, failure, cancel
    — including an ECANCELED chain cascade) releases it back to the
    free list.  ``acquire()`` on an exhausted pool performs
    **copy-on-evict**: the oldest pinned buffer whose ticket is still
    QUEUED is snapshotted into the ticket (the payload stays correct)
    and the slot is reused — the only remaining copy, paid only when
    the caller reuses a slot before durability.  If nothing is
    stealable (every pinned ticket already dispatched), a transient
    unpooled buffer is handed out instead of blocking the caller."""

    def __init__(self, engine: "AsyncIOEngine", n_buffers: int,
                 buf_bytes: int) -> None:
        assert n_buffers >= 1 and buf_bytes >= 1
        self._engine = engine
        self.buf_bytes = buf_bytes
        self._bufs = [RegisteredBuf(i, np.zeros(buf_bytes, np.uint8), self)
                      for i in range(n_buffers)]
        self._free = list(range(n_buffers - 1, -1, -1))
        self._pins: dict[int, Ticket] = {}      # buf idx -> pinning ticket
        self.copy_on_evict = 0
        self.overflow_allocs = 0

    def __len__(self) -> int:
        return len(self._bufs)

    def free_count(self) -> int:
        with self._engine._cond:
            return len(self._free)

    def acquire(self) -> RegisteredBuf:
        eng = self._engine
        with eng._cond:
            if self._free:
                return self._bufs[self._free.pop()]
            # copy-on-evict: steal the oldest pinned buffer whose ticket
            # has not dispatched yet (its payload snapshots into the
            # ticket, so the in-flight write stays correct)
            for idx in sorted(self._pins,
                              key=lambda i: self._pins[i].seq):
                if self._steal_locked(idx):
                    return self._bufs[idx]
            self.overflow_allocs += 1
            return RegisteredBuf(-1, np.zeros(self.buf_bytes, np.uint8),
                                 self)

    def release(self, buf: RegisteredBuf) -> None:
        """Return an acquired-but-never-submitted buffer to the pool."""
        with self._engine._cond:
            if buf.idx >= 0 and buf.idx not in self._pins \
                    and buf.idx not in self._free:
                self._free.append(buf.idx)

    # engine-internal (all called under the engine lock) ------------------
    def _steal_locked(self, idx: int) -> bool:
        t = self._pins[idx]
        if t.state != QUEUED:
            return False                   # already on its way to media
        buf = self._bufs[idx]
        if t.out is buf:
            return False                   # a read landing target cannot
        data, blocks = t.value \
            if isinstance(t.value, tuple) else (None, None)
        snap = bytes(memoryview(buf.data))
        if data is buf:
            t.value = (snap, blocks)
        elif isinstance(blocks, (list, tuple)) and \
                any(b is buf for b in blocks):
            t.value = (data, [snap if b is buf else b for b in blocks])
        else:                              # pragma: no cover - defensive
            return False
        t._bufs.remove(buf)
        del self._pins[idx]
        self.copy_on_evict += 1
        eng = self._engine
        eng.staging_copies += 1
        eng.staging_copy_bytes += len(snap)
        eng._bump("staging_copies")
        eng._bump("staging_copy_bytes", len(snap))
        return True

    def _pin_locked(self, buf: RegisteredBuf, t: "Ticket") -> None:
        if buf.idx >= 0:
            self._pins[buf.idx] = t
        t._bufs.append(buf)

    def _release_ticket_locked(self, t: "Ticket") -> None:
        for buf in t._bufs:
            if buf.idx >= 0 and self._pins.get(buf.idx) is t:
                del self._pins[buf.idx]
                self._free.append(buf.idx)
        t._bufs = []

    def stats(self) -> dict:
        with self._engine._cond:
            return {
                "n_buffers": len(self._bufs),
                "buf_bytes": self.buf_bytes,
                "free": len(self._free),
                "pinned": len(self._pins),
                "copy_on_evict": self.copy_on_evict,
                "overflow_allocs": self.overflow_allocs,
            }


class Ticket:
    """One asynchronous I/O: handle returned by ``submit``, delivered on
    the completion ring.  ``value`` holds a read's data; ``error`` holds
    the per-ticket failure (device error, journal overflow, cancel,
    refused submit)."""

    __slots__ = ("tid", "seq", "op", "lba", "tenant", "state", "value",
                 "error", "link_to", "link_depth", "out", "replica",
                 "_bufs", "_discard", "_engine")

    def __init__(self, tid: int, seq: int, op: str, lba: int,
                 tenant, engine) -> None:
        self.tid = tid
        self.seq = seq
        self.op = op
        self.lba = lba
        self.tenant = tenant
        self.state = QUEUED
        self.value = None
        self.error: BaseException | None = None
        self.link_to: "Ticket | None" = None   # SQE chain parent
        self.link_depth = 0                    # hops from the chain head
        self.out = None                        # read landing buffer
        self.replica = 0                       # hedge: which copy to read
        self._bufs: list = []                  # pinned registered buffers
        self._discard = False                  # cancelled while RUNNING
        self._engine = engine

    @property
    def done(self) -> bool:
        return self.state == DONE

    @property
    def ok(self) -> bool:
        return self.state == DONE and self.error is None

    def result(self, timeout: float | None = None):
        """Block until complete; return the op's value or re-raise the
        ticket's error."""
        self._engine.wait(self, timeout=timeout)
        if self.error is not None:
            raise self.error
        return self.value

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        st = ("queued", "running", "done")[self.state]
        return (f"Ticket({self.tid}, {self.op}@{self.lba}, "
                f"tenant={self.tenant}, {st}"
                f"{', err=' + repr(self.error) if self.error else ''})")


class AsyncIOEngine:
    """io_uring-style submit/poll front end over a :class:`StripedVolume`
    (anything speaking write/write_multi/read/fsync/flush works).

    ``n_workers`` — background dispatch threads (0 = deterministic
    inline mode: ops execute during ``poll``/``wait``).
    ``max_inflight_per_tenant`` — submit-side backpressure window; a
    tenant over its bound gets a failed ticket, never a blocked submit.
    """

    def __init__(self, volume, *, n_workers: int = 2,
                 max_inflight_per_tenant: int = 32) -> None:
        assert n_workers >= 0 and max_inflight_per_tenant >= 1
        self.vol = volume
        self.max_inflight_per_tenant = max_inflight_per_tenant
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._sqs: dict[object, deque[Ticket]] = {}   # tenant -> SQ
        self._cq: deque[Ticket] = deque()             # shared completion ring
        self._open: dict[int, Ticket] = {}            # seq -> live ticket
        self._inflight: dict[object, int] = {}        # per-tenant live count
        self._deps: dict[int, list[Ticket]] = {}      # parent seq -> linked
        self._tids = itertools.count(1)
        self._seqs = itertools.count(1)
        self._closed = False
        self._dead: BaseException | None = None
        self.registry: BufferRegistry | None = None
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.cancelled = 0
        # zero-copy data plane accounting
        self.copies_avoided = 0       # pinned writes + out= read landings
        self.bytes_pinned = 0         # cumulative payload bytes pinned
        self.staging_copies = 0       # defensive snapshots (+ steals)
        self.staging_copy_bytes = 0
        self.links_submitted = 0      # tickets carrying link_to
        self.link_cancelled = 0       # dependents failed with ECANCELED
        self.link_depth_max = 0       # deepest chain seen
        self._workers = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"aio-{i}")
            for i in range(n_workers)
        ]
        for w in self._workers:
            w.start()

    @property
    def inline(self) -> bool:
        return not self._workers

    # ------------------------------------------------------- registered bufs
    def register_buffers(self, n_buffers: int,
                         buf_bytes: int) -> BufferRegistry:
        """Create (once) the engine's registered buffer pool.  Payloads
        submitted as :class:`RegisteredBuf` handles are pinned, not
        copied; reads with a registered ``out=`` land in place."""
        with self._cond:
            if self.registry is None:
                self.registry = BufferRegistry(self, n_buffers, buf_bytes)
            else:
                assert len(self.registry) == n_buffers \
                    and self.registry.buf_bytes == buf_bytes, \
                    "buffer pool already registered with a different shape"
            return self.registry

    # ------------------------------------------------------------ submission
    def submit(self, op: str, lba: int = 0, data=None, blocks=None,
               tenant=None, block: bool = False, link_to: Ticket | None = None,
               out=None, replica: int = 0) -> Ticket:
        """Queue one op; returns its ticket immediately.  NEVER raises
        for per-op conditions: a refused submit (closed engine, tenant
        over its in-flight bound, unknown op) comes back as an
        already-failed ticket in the caller's hand — with no completion
        event, like io_uring's -EAGAIN.

        ``block=True`` turns the in-flight bound from a refusal into
        BLOCKING backpressure: the submit waits for the tenant's window
        (executing queued ops itself in deterministic mode) instead of
        failing the ticket — what batch producers (blockstore puts, the
        request log) want.  Other refusals still fail the ticket.

        ``link_to=parent`` chains this ticket behind ``parent``
        (IO_LINK): it dispatches only after the parent completes OK and
        fails with :class:`LinkCancelledError` if the parent fails.
        ``out=`` (reads) lands the data directly in the caller's array /
        :class:`RegisteredBuf` — the completion value IS that buffer.
        ``replica=`` (reads) routes the op to that copy of the block —
        the hedge path reads the replica while the primary is in
        flight."""
        while True:
            t = self._submit_once(op, lba, data, blocks, tenant,
                                  count_refusal=not block,
                                  link_to=link_to, out=out, replica=replica)
            if not (block and t.state == DONE
                    and isinstance(t.error, BackpressureError)):
                return t
            if self.inline:
                if self._run_inline(1) == 0:
                    time.sleep(0.001)    # head blocked on a drain
            else:                        # callback: let the pool run
                with self._cond:
                    if self._inflight.get(tenant, 0) \
                            >= self.max_inflight_per_tenant:
                        self._cond.wait(timeout=0.05)

    def try_submit(self, op: str, lba: int = 0, data=None, blocks=None,
                   tenant=None, link_to: Ticket | None = None,
                   out=None, replica: int = 0) -> Ticket | None:
        """Non-blocking window probe: returns None — without counting a
        failure — when the tenant is at its in-flight bound, the ticket
        otherwise.  Flow-control probes (the blockstore's restore pump)
        must not pollute the per-ticket failure stats."""
        t = self._submit_once(op, lba, data, blocks, tenant,
                              count_refusal=False, link_to=link_to, out=out,
                              replica=replica)
        if t.state == DONE and isinstance(t.error, BackpressureError):
            return None
        return t

    def _bump(self, event: str, n: int = 1) -> None:
        """Mirror a zero-copy counter onto the volume's Metrics (leaf
        lock — safe under the engine lock) so ``Metrics.zerocopy_path()``
        and ``scrub`` see the same numbers as ``stats()``."""
        m = getattr(self.vol, "metrics", None)
        if m is not None:
            m.bump(event, n)

    def _snapshot_locked(self, payload):
        """Defensive staging copy of an UNREGISTERED mutable payload:
        the caller may reuse its buffer the moment submit returns, so a
        mutable array must not ride the ticket by reference.  This is
        the per-op copy tax that :class:`BufferRegistry` pinning
        removes.  ``bytes`` (immutable) payloads pass through."""
        if isinstance(payload, (bytearray, memoryview, np.ndarray)):
            snap = bytes(memoryview(np.ascontiguousarray(payload)
                                    if isinstance(payload, np.ndarray)
                                    else payload))
            self.staging_copies += 1
            self.staging_copy_bytes += len(snap)
            self._bump("staging_copies")
            self._bump("staging_copy_bytes", len(snap))
            return snap
        return payload

    def _pin_or_snapshot_locked(self, payload, t: Ticket):
        if isinstance(payload, RegisteredBuf):
            assert payload._registry is self.registry, \
                "buffer registered with a different engine"
            self.registry._pin_locked(payload, t)
            self.copies_avoided += 1
            self.bytes_pinned += payload.nbytes
            self._bump("copies_avoided")
            self._bump("bytes_pinned", payload.nbytes)
            return payload
        return self._snapshot_locked(payload)

    def _submit_once(self, op, lba, data, blocks, tenant,
                     count_refusal: bool = True, link_to=None,
                     out=None, replica: int = 0) -> Ticket:
        with self._cond:
            t = Ticket(next(self._tids), next(self._seqs), op, lba,
                       tenant, self)
            t.replica = replica
            err = None
            if op not in _OPS:
                err = SubmitError(f"unknown op {op!r}")
            elif self._closed:
                err = SubmitError("submit after close")
            elif self._dead is not None:
                err = SubmitError(f"engine dead: {self._dead!r}")
            elif self._inflight.get(tenant, 0) \
                    >= self.max_inflight_per_tenant:
                err = BackpressureError(
                    f"tenant {tenant!r} over its in-flight bound "
                    f"({self.max_inflight_per_tenant})")
            if err is not None:
                # refused submissions complete in the caller's hand and
                # generate NO completion event (io_uring's -EAGAIN): a
                # retry loop must not litter the ring, and a blocking
                # submit's wait attempts stay counter-invisible
                t.state = DONE
                t.error = err
                if count_refusal or not isinstance(err, BackpressureError):
                    self.submitted += 1
                    self.failed += 1
                return t
            if link_to is not None:
                assert link_to._engine is self, \
                    "link parent belongs to a different engine"
                self.links_submitted += 1
                self._bump("links_submitted")
                t.link_depth = link_to.link_depth + 1
                if t.link_depth > self.link_depth_max:
                    # Metrics only counts up: keep its link_depth_max
                    # equal to the high-water mark by bumping the delta
                    self._bump("link_depth_max",
                               t.link_depth - self.link_depth_max)
                    self.link_depth_max = t.link_depth
                if link_to.state == DONE and link_to.error is not None:
                    # chained behind an already-failed parent: the
                    # dependent lands on the RING as ECANCELED (a real
                    # CQE, unlike a refused submit — the chain is
                    # cancelled, never silently dropped)
                    t.link_to = link_to     # root cause stays reachable
                    t.state = DONE
                    t.error = LinkCancelledError(
                        f"ECANCELED: link parent ticket {link_to.tid} "
                        f"failed: {link_to.error!r}")
                    self.submitted += 1
                    self.cancelled += 1
                    self.link_cancelled += 1
                    self._bump("link_cancelled")
                    self._cq.append(t)
                    self._cond.notify_all()
                    return t
                if link_to.state != DONE:   # parent done-OK needs no gate
                    t.link_to = link_to
                    self._deps.setdefault(link_to.seq, []).append(t)
            self.submitted += 1
            if data is not None:
                data = self._pin_or_snapshot_locked(data, t)
            if blocks is not None:
                blocks = [self._pin_or_snapshot_locked(b, t)
                          for b in blocks]
            if out is not None:
                assert op == "read", "out= is only meaningful for reads"
                t.out = out
                if isinstance(out, RegisteredBuf):
                    self.registry._pin_locked(out, t)
                    self.bytes_pinned += out.nbytes
                    self._bump("bytes_pinned", out.nbytes)
                self.copies_avoided += 1    # no post-poll landing copy
                self._bump("copies_avoided")
            t.value = (data, blocks)          # op args ride the ticket
            self._sqs.setdefault(tenant, deque()).append(t)
            self._open[t.seq] = t
            self._inflight[tenant] = self._inflight.get(tenant, 0) + 1
            self._cond.notify_all()
            return t

    def cancel(self, ticket: Ticket) -> bool:
        """Cancel a still-queued ticket: it completes on the ring with
        :class:`CancelledError`.  Returns False once dispatched (an op
        already on its way to the media cannot be recalled) — EXCEPT a
        dispatched READ, which is side-effect-free: cancelling a RUNNING
        read marks it discarded, its result is dropped (an ``out=``
        landing target is never written — the landing copy happens under
        the engine lock at completion and checks the discard flag, so a
        cancelled read can never leave partial data in the caller's
        array), and it still completes on the ring exactly once, with
        :class:`CancelledError`.  This is the hedge-loser path: the
        slow replica's read is recalled whether or not it has already
        reached the media.

        A cancelled mid-chain ticket cascades: every linked dependent
        completes with :class:`LinkCancelledError`, and ALL registered
        buffers the ticket (and its dependents) had pinned go back to
        the pool from the same completion path — a cancel landing
        between submit and poll can never leak a pinned buffer."""
        with self._cond:
            if ticket.state == RUNNING and ticket.op == "read" \
                    and ticket.seq in self._open:
                ticket._discard = True      # _finish_locked converts the
                return True                 # completion to CancelledError
            if ticket.state != QUEUED or ticket.seq not in self._open:
                return False
            sq = self._sqs.get(ticket.tenant)
            try:
                sq.remove(ticket)
            except (ValueError, AttributeError):
                return False
            self._finish_locked(ticket, error=CancelledError("cancelled"))
            return True

    # ------------------------------------------------------------ completion
    def poll(self, max_ops: int | None = None) -> list[Ticket]:
        """Drain the completion ring (oldest first).  In deterministic
        mode this FIRST executes up to ``max_ops`` queued ops inline in
        submission order (all eligible ops when ``None``), so
        ``submit(); poll()`` is a replayable schedule."""
        if self.inline:
            self._run_inline(max_ops)
        with self._cond:
            out = list(self._cq)
            self._cq.clear()
            return out

    def wait(self, ticket: Ticket, timeout: float | None = None) -> Ticket:
        """Block until ``ticket`` completes.  Waiting CONSUMES the
        completion — the ticket will not show up on a later ``poll`` —
        so wait()-only consumers (blockstore, request log) never grow
        the ring.  In deterministic mode this executes queued ops ONE at
        a time, stopping the moment the ticket completes: ops submitted
        after it stay queued (the replayable schedule does not advance
        past the caller's intent)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._cond:
                if ticket.state == DONE:
                    try:
                        self._cq.remove(ticket)
                    except ValueError:
                        pass             # already polled
                    return ticket
            # never oversleep the caller's deadline: a hedge delay is
            # routinely far below the 50 ms poll granularity
            step = 0.05 if deadline is None \
                else max(1e-4, min(0.05, deadline - time.monotonic()))
            if self.inline:
                if self._run_inline(1) == 0:
                    with self._cond:     # head blocked on a drain
                        if ticket.state != DONE:    # callback: let the
                            self._cond.wait(timeout=step)   # pool run
            else:
                with self._cond:
                    if ticket.state != DONE:
                        self._cond.wait(timeout=step)
            if deadline is not None and time.monotonic() >= deadline:
                with self._cond:
                    if ticket.state == DONE:     # completed AT the
                        try:                     # deadline: not a timeout
                            self._cq.remove(ticket)
                        except ValueError:
                            pass
                        return ticket
                    raise TimeoutError(
                        f"ticket {ticket.tid} still "
                        f"{('queued', 'running', 'done')[ticket.state]}")

    def wait_any(self, tickets, timeout: float | None = None) -> Ticket:
        """Block until ANY of ``tickets`` completes; returns the first
        one found DONE (consuming its CQE, like ``wait``).  This is the
        hedged-read race: wait on {primary, hedge}, take the winner,
        cancel the loser.  In deterministic mode queued ops execute one
        at a time in submission order, so the primary (older seq) always
        races first — replayable like every other inline schedule."""
        tickets = list(tickets)
        assert tickets, "wait_any needs at least one ticket"
        deadline = None if timeout is None else time.monotonic() + timeout

        def first_done_locked():
            for t in tickets:
                if t.state == DONE:
                    try:
                        self._cq.remove(t)
                    except ValueError:
                        pass         # already polled
                    return t
            return None

        while True:
            with self._cond:
                t = first_done_locked()
                if t is not None:
                    return t
            step = 0.05 if deadline is None \
                else max(1e-4, min(0.05, deadline - time.monotonic()))
            if self.inline:
                if self._run_inline(1) == 0:
                    with self._cond:
                        t = first_done_locked()
                        if t is not None:
                            return t
                        self._cond.wait(timeout=step)
            else:
                with self._cond:
                    if all(t.state != DONE for t in tickets):
                        self._cond.wait(timeout=step)
            if deadline is not None and time.monotonic() >= deadline:
                with self._cond:
                    t = first_done_locked()
                    if t is not None:
                        return t
                    raise TimeoutError(
                        f"none of {len(tickets)} tickets completed")

    def drain(self, timeout: float | None = None) -> None:
        """Wait for every submitted ticket to complete."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self.inline:
                self._run_inline(None)
            with self._cond:
                if not self._open:
                    return
                if self._dead is not None:
                    raise self._dead
                self._cond.wait(timeout=0.05)
            if deadline is not None and time.monotonic() >= deadline:
                raise TimeoutError(f"{len(self._open)} tickets open")

    # -------------------------------------------------------------- dispatch
    def _pick_locked(self):
        """(ticket, blocked): the eligible queued ticket with the oldest
        seq across every SQ.  Barriers are not ready while any earlier
        ticket is still open (IO_DRAIN: nothing later than a pending
        barrier dispatches either).  A link-gated head (parent still in
        flight) blocks only ITS chain: younger heads of other SQs run —
        per-tenant FIFO holds, cross-tenant overlap survives."""
        heads = sorted((sq[0] for sq in self._sqs.values() if sq),
                       key=lambda t: t.seq)
        if not heads:
            return None, False
        for t in heads:
            if t.op in _BARRIER_OPS and min(self._open) < t.seq:
                return t, True
            p = t.link_to
            if p is not None and p.state != DONE:
                continue             # parent in flight: try another SQ
            return t, False
        return heads[0], True        # every head link-gated: wait

    def _pop_locked(self, ticket: Ticket) -> None:
        self._sqs[ticket.tenant].popleft()
        ticket.state = RUNNING

    def _run_inline(self, max_ops: int | None) -> int:
        n = 0
        while max_ops is None or n < max_ops:
            with self._cond:
                t, blocked = self._pick_locked()
                if t is None or blocked:
                    # a blocked barrier waits on callback-completed
                    # tickets (eviction drains) — the pool threads will
                    # finish them; the caller polls again
                    return n
                self._pop_locked(t)
            self._execute(t)
            n += 1
        return n

    def _worker(self) -> None:
        while True:
            with self._cond:
                while True:
                    if self._dead is not None:
                        self._fail_queued_locked()
                    t, blocked = self._pick_locked()
                    if t is not None and not blocked:
                        self._pop_locked(t)
                        break
                    if self._closed and t is None:
                        return
                    self._cond.wait(timeout=0.2)
            self._execute(t)

    def _execute(self, t: Ticket) -> None:
        data, blocks = t.value if isinstance(t.value, tuple) else (None, None)
        t.value = None
        t0 = time.perf_counter_ns()
        try:
            val = self._run_op(t, data, blocks)
        except SimulatedCrash as e:
            # power loss: the whole ring dies with the machine
            self._fatal(e, t)
            if self.inline:
                raise
            return
        except Exception as e:       # injected device error, journal
            self._observe_svc(t, t0)            # overflow, ... — per-ticket
            self._complete(t, error=e)
            return
        self._observe_svc(t, t0)
        if val is _PENDING:
            return                   # completes via drain callback
        self._complete(t, value=val)

    def _observe_svc(self, t: Ticket, t0: int) -> None:
        """Per-op service-time EWMA on the volume's metrics (fail-slow
        groundwork: ``Metrics.per_node()`` keys ``aio::<op>``)."""
        m = getattr(self.vol, "metrics", None)
        if m is not None:
            m.observe(f"svc::aio::{t.op}", time.perf_counter_ns() - t0)

    @staticmethod
    def _payload(data):
        """A pinned RegisteredBuf rides the ticket as the handle; the
        device stack consumes the underlying array via the buffer
        protocol (``np.frombuffer`` — no intermediate copy)."""
        return data.data if isinstance(data, RegisteredBuf) else data

    def _run_op(self, t: Ticket, data, blocks):
        vol = self.vol
        if t.op == "write":
            return vol.write(t.lba, self._payload(data), tenant=t.tenant)
        if t.op == "write_multi":
            return vol.write_multi(t.lba, [self._payload(b) for b in blocks],
                                   tenant=t.tenant)
        if t.op == "read":
            # hedge routing: replica=N reads the Nth copy (striped
            # volume) / starts the chain walk at position N (cluster)
            kw = {"tenant": t.tenant}
            if t.replica:
                kw["replica"] = t.replica
            if t.out is None:
                return vol.read(t.lba, **kw)
            # zero-copy landing: the device stack fills an engine-held
            # scratch in place, then ONE landing memcpy into the
            # CALLER's array happens under the engine lock at the end of
            # the op and checks the discard flag first — a read
            # cancelled in flight (a hedge loser) can never leave
            # partial data in the caller's buffer, and the completion
            # value is still the caller's own buffer (no post-poll copy)
            arr = self._payload(t.out)
            bs = getattr(vol, "block_size", None)
            if isinstance(arr, np.ndarray) and arr.size == bs:
                scratch = np.empty_like(arr)
                try:
                    vol.read(t.lba, out=scratch, **kw)
                    return self._land_out_locked_copy(t, arr, scratch)
                except TypeError:    # volume without out= plumbing
                    pass
            val = vol.read(t.lba, **kw)
            src = val.view(np.uint8).reshape(-1) \
                if isinstance(val, np.ndarray) \
                else np.frombuffer(memoryview(val), dtype=np.uint8)
            return self._land_out_locked_copy(t, arr, src)
        if t.op == "fsync":
            return vol.fsync()       # rides the GroupCommitter leader
        assert t.op == "flush"
        return self._flush_async(t)

    def _land_out_locked_copy(self, t: Ticket, arr, src):
        """Atomic ``out=`` landing: the caller's array is written in one
        memcpy under the engine lock, and ONLY if the ticket has not
        been discarded — cancel() takes the same lock, so the caller
        observes either the full block or an untouched buffer, never a
        torn landing."""
        with self._cond:
            if not t._discard:
                n = min(arr.size, src.size)
                arr[:n] = src[:n]
        return t.out

    def _flush_async(self, t: Ticket):
        """WBQ-drain barrier without parking a worker: register one-shot
        drain waiters on every shard cache; the ticket completes from
        the eviction pool's completion path."""
        caches = [c for c in getattr(self.vol, "_caches", [])
                  if hasattr(c, "add_drain_waiter")]
        if not caches:
            self.vol.flush()
            return None
        for c in caches:
            if hasattr(c, "kick_drain"):
                c.kick_drain()       # staging configs enqueue their WBQs
        state = {"left": 1}          # sentinel guards registration phase
        slock = threading.Lock()

        def child_done() -> None:
            with slock:
                state["left"] -= 1
                fire = state["left"] == 0
            if fire:
                self._complete(t, value=None)

        for c in caches:
            with slock:
                state["left"] += 1
            if not c.add_drain_waiter(child_done):
                child_done()         # already drained
        child_done()                 # drop the sentinel
        return _PENDING

    # ------------------------------------------------------------ accounting
    def _finish_locked(self, t: Ticket, value=None, error=None) -> None:
        if t._discard and not isinstance(error, CancelledError):
            # cancelled while RUNNING (hedge loser): the result — value
            # OR device error — is dropped and the one CQE says cancelled
            value, error = None, CancelledError(
                "cancelled in flight (discarded result)")
        t.value = value
        t.error = error
        t.state = DONE
        self._open.pop(t.seq, None)
        n = self._inflight.get(t.tenant, 0)
        if n:
            self._inflight[t.tenant] = n - 1
        if error is None:
            self.completed += 1
        elif isinstance(error, CancelledError):
            self.cancelled += 1          # cancels are not failures
        else:
            self.failed += 1
        # EVERY completion path — success, device error, cancel, chain
        # cascade, engine death — releases the ticket's pinned buffers;
        # this is the one place, so no path can leak a registered buffer
        if t._bufs and self.registry is not None:
            self.registry._release_ticket_locked(t)
        self._cq.append(t)
        self._cond.notify_all()
        # linked-SQE cascade: a failed/cancelled parent fails every
        # still-queued transitive dependent with ECANCELED ON THE RING
        # (cancelled, never silently dropped); a successful parent just
        # ungates them (``_pick_locked`` reads parent.state)
        deps = self._deps.pop(t.seq, None)
        if deps and error is not None:
            for d in deps:
                if d.state != QUEUED or d.seq not in self._open:
                    continue
                sq = self._sqs.get(d.tenant)
                try:
                    sq.remove(d)
                except (ValueError, AttributeError):
                    continue             # pragma: no cover - defensive
                self.link_cancelled += 1
                self._bump("link_cancelled")
                self._finish_locked(d, error=LinkCancelledError(
                    f"ECANCELED: link parent ticket {t.tid} failed: "
                    f"{error!r}"))

    def _complete(self, t: Ticket, value=None, error=None) -> None:
        with self._cond:
            self._finish_locked(t, value=value, error=error)

    def _fail_queued_locked(self) -> None:
        err = self._dead
        for sq in self._sqs.values():
            while sq:
                self._finish_locked(sq.popleft(), error=SubmitError(
                    f"engine dead: {err!r}"))

    def _fatal(self, err: BaseException, t: Ticket) -> None:
        with self._cond:
            self._dead = err
            self._finish_locked(t, error=err)
            self._fail_queued_locked()

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        with self._lock:
            out = {
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "cancelled": self.cancelled,
                "open": len(self._open),
                "cq_depth": len(self._cq),
                "inflight": {k: v for k, v in self._inflight.items() if v},
                "workers": len(self._workers),
                "copies_avoided": self.copies_avoided,
                "bytes_pinned": self.bytes_pinned,
                "staging_copies": self.staging_copies,
                "staging_copy_bytes": self.staging_copy_bytes,
                "links_submitted": self.links_submitted,
                "link_cancelled": self.link_cancelled,
                "link_depth_max": self.link_depth_max,
            }
        if self.registry is not None:
            out["registry"] = self.registry.stats()
        return out

    def close(self, drain: bool = True) -> None:
        if drain and self._dead is None:
            try:
                self.drain(timeout=30.0)
            except (TimeoutError, SimulatedCrash):
                pass
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        for w in self._workers:
            w.join(timeout=5.0)


def hedged_read(vol, lba: int, *, delay_s: float, out=None, tenant=None,
                replica: int = 1):
    """Tail-tolerant replicated read over ``vol``'s async engine (shared
    by ``StripedVolume.hedged_read`` and ``ClusterVolume.hedged_read``):
    submit the primary read, wait ``delay_s``; if it has not completed,
    fire the SAME read against copy ``replica`` and take the first
    completion.  The loser is cancelled through the per-ticket cancel
    path — a QUEUED loser never dispatches, a RUNNING loser is
    discarded (its ``out=`` landing suppressed), and either way its
    pinned registered buffers go back to the pool from the completion
    path.  A winner that FAILED (fail-stop, not fail-slow) settles the
    other leg and serves it instead, so hedging subsumes failover.

    Counter contract (``Metrics.tail_path()``): every fired hedge
    retires as exactly ONE of ``hedges_won`` (the hedge's result was
    served) or ``hedges_cancelled`` (recalled, raced out by the primary,
    or failed) — ``hedges_fired == hedges_won + hedges_cancelled``."""
    eng = vol.aio_engine()
    m = vol.metrics
    m.bump("hedged_reads")
    primary = eng.submit("read", lba, tenant=tenant, out=out)
    try:
        eng.wait(primary, timeout=delay_s)
    except TimeoutError:
        pass
    if primary.done and primary.error is None:
        return primary.value          # fast path: no hedge fired
    hedge = eng.submit("read", lba, tenant=tenant, replica=replica)
    m.bump("hedges_fired")
    winner = eng.wait_any([primary, hedge])
    loser = hedge if winner is primary else primary
    if winner.error is not None:
        # the winner leg failed outright — settle the other leg and
        # serve it (fail-stop failover riding the hedge machinery)
        eng.wait(loser)
        winner, loser = loser, winner
    elif not eng.cancel(loser):
        # both-complete race: the loser finished before the cancel
        # reached it — consume its one CQE (never a double completion)
        eng.wait(loser)
    else:
        if loser is primary:
            m.bump("primaries_cancelled")
        if loser.done:
            # QUEUED-cancel completes immediately: consume the CQE so
            # the shared ring is not littered.  A RUNNING (discarded)
            # loser completes later — its one CancelledError CQE drains
            # on a normal poll; we never block on the slow leg
            eng.wait(loser)
    m.bump("hedges_won" if winner is hedge else "hedges_cancelled")
    if winner.error is not None:
        raise winner.error
    if winner is hedge and out is not None:
        # the hedge leg is submitted WITHOUT out= (two tickets must
        # never land the same caller array); a hedge win copies once
        # here — the cancelled primary's discard flag guarantees it
        # cannot touch the buffer afterwards
        arr = out.data if isinstance(out, RegisteredBuf) else out
        src = winner.value
        src = src.view(np.uint8).reshape(-1) \
            if isinstance(src, np.ndarray) \
            else np.frombuffer(memoryview(src), dtype=np.uint8)
        n = min(arr.size, src.size)
        arr[:n] = src[:n]
        return out
    return winner.value
