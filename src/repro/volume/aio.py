"""Asynchronous submission/completion I/O frontend for the striped volume.

Every entry point the stack had so far — ``CaitiCache.write``,
``StripedVolume.write_multi`` / ``fsync`` / ``read`` — is a *blocking*
call: the submitting thread rides the whole stack down to the media and
back, so callers serialize exactly the PMem stalls the paper's transit
cache exists to hide.  :class:`AsyncIOEngine` is the io_uring-style
front end that decouples submission from completion:

  * **per-tenant submission queues** — ``submit(op, ...)`` appends a
    :class:`Ticket` to the caller's tenant SQ and returns immediately;
    dispatch merges the SQs in global submission order (per-tenant FIFO,
    oldest seq first), so one tenant's burst cannot reorder another's
    ops;
  * **shared completion ring** — finished tickets land on one CQ;
    ``poll()`` drains it (oldest first), ``wait(ticket)`` blocks for one
    ticket.  ``Ticket.result()`` returns the op's value or re-raises its
    error;
  * **backpressure at submit time** — each tenant has a bounded
    in-flight window (``max_inflight_per_tenant``, the submit-side
    analogue of ``WFQGate``'s dispatch window).  A submit that would
    exceed the bound FAILS ITS TICKET with :class:`SubmitError` instead
    of blocking the caller or deadlocking the ring; deeper WFQ pricing
    still happens on the execution path (ops run through the volume's
    normal ``tenant=`` admission: token bucket + tier-aware SFQ tags);
  * **async fsync barriers** — an ``op='fsync'`` ticket dispatches only
    once every earlier-submitted ticket has completed (io_uring's
    IO_DRAIN), then rides the volume's existing
    :class:`~repro.volume.journal.GroupCommitter`: concurrent async
    fsyncs from several engine workers elect ONE leader for the batch.
    Chained ``write_multi`` tickets likewise coalesce behind the
    :class:`~repro.volume.journal.LogBatcher` leader when workers
    overlap;
  * **eviction-drain completion callbacks** — an ``op='flush'`` ticket
    (the WBQ-drain barrier) does not park a worker in
    ``CaitiCache.flush``: it registers a one-shot drain waiter on every
    shard cache (``CaitiCache.add_drain_waiter``) and completes from the
    eviction pool's completion path when the last in-flight writeback
    lands;
  * **per-ticket failures** — an injected device error (or a journal
    ring overflow, a cancelled ticket, a submit after close) surfaces on
    THAT ticket's ``error``, never as a stack-wide exception tearing
    down the ring.  Only :class:`~repro.core.SimulatedCrash` is fatal:
    it models power loss, so the engine marks itself dead, fails every
    queued ticket, and (in deterministic mode) re-raises so crash
    harnesses observe the loss exactly like the synchronous sweeps do.

Two execution modes share all of the above:

  * ``n_workers >= 1`` (default): background worker threads drain the
    SQs — real overlap for the threaded volume;
  * ``n_workers == 0`` (**deterministic mode**, used by the
    crash/fault-injection harness in ``tests/aio_harness.py``): nothing
    runs until ``poll()`` / ``wait()`` executes queued ops inline, one
    at a time, in submission order — every interleaving of
    submit/poll/crash is replayable from a seed.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque

from repro.core.pmem import SimulatedCrash

# ticket states
QUEUED, RUNNING, DONE = range(3)

_BARRIER_OPS = ("fsync", "flush")
_OPS = ("write", "write_multi", "read", "fsync", "flush")
_PENDING = object()          # sentinel: op completes via callback later


class TicketError(RuntimeError):
    """Base class for engine-side (not device-side) ticket failures."""


class SubmitError(TicketError):
    """The submit itself was refused (closed engine / unknown op)."""


class BackpressureError(SubmitError):
    """The submit was refused because the tenant is at its in-flight
    bound — the retryable refusal: settle a completion and resubmit."""


class CancelledError(TicketError):
    """The ticket was cancelled before dispatch."""


class Ticket:
    """One asynchronous I/O: handle returned by ``submit``, delivered on
    the completion ring.  ``value`` holds a read's data; ``error`` holds
    the per-ticket failure (device error, journal overflow, cancel,
    refused submit)."""

    __slots__ = ("tid", "seq", "op", "lba", "tenant", "state", "value",
                 "error", "_engine")

    def __init__(self, tid: int, seq: int, op: str, lba: int,
                 tenant, engine) -> None:
        self.tid = tid
        self.seq = seq
        self.op = op
        self.lba = lba
        self.tenant = tenant
        self.state = QUEUED
        self.value = None
        self.error: BaseException | None = None
        self._engine = engine

    @property
    def done(self) -> bool:
        return self.state == DONE

    @property
    def ok(self) -> bool:
        return self.state == DONE and self.error is None

    def result(self, timeout: float | None = None):
        """Block until complete; return the op's value or re-raise the
        ticket's error."""
        self._engine.wait(self, timeout=timeout)
        if self.error is not None:
            raise self.error
        return self.value

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        st = ("queued", "running", "done")[self.state]
        return (f"Ticket({self.tid}, {self.op}@{self.lba}, "
                f"tenant={self.tenant}, {st}"
                f"{', err=' + repr(self.error) if self.error else ''})")


class AsyncIOEngine:
    """io_uring-style submit/poll front end over a :class:`StripedVolume`
    (anything speaking write/write_multi/read/fsync/flush works).

    ``n_workers`` — background dispatch threads (0 = deterministic
    inline mode: ops execute during ``poll``/``wait``).
    ``max_inflight_per_tenant`` — submit-side backpressure window; a
    tenant over its bound gets a failed ticket, never a blocked submit.
    """

    def __init__(self, volume, *, n_workers: int = 2,
                 max_inflight_per_tenant: int = 32) -> None:
        assert n_workers >= 0 and max_inflight_per_tenant >= 1
        self.vol = volume
        self.max_inflight_per_tenant = max_inflight_per_tenant
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._sqs: dict[object, deque[Ticket]] = {}   # tenant -> SQ
        self._cq: deque[Ticket] = deque()             # shared completion ring
        self._open: dict[int, Ticket] = {}            # seq -> live ticket
        self._inflight: dict[object, int] = {}        # per-tenant live count
        self._tids = itertools.count(1)
        self._seqs = itertools.count(1)
        self._closed = False
        self._dead: BaseException | None = None
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.cancelled = 0
        self._workers = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"aio-{i}")
            for i in range(n_workers)
        ]
        for w in self._workers:
            w.start()

    @property
    def inline(self) -> bool:
        return not self._workers

    # ------------------------------------------------------------ submission
    def submit(self, op: str, lba: int = 0, data=None, blocks=None,
               tenant=None, block: bool = False) -> Ticket:
        """Queue one op; returns its ticket immediately.  NEVER raises
        for per-op conditions: a refused submit (closed engine, tenant
        over its in-flight bound, unknown op) comes back as an
        already-failed ticket in the caller's hand — with no completion
        event, like io_uring's -EAGAIN.

        ``block=True`` turns the in-flight bound from a refusal into
        BLOCKING backpressure: the submit waits for the tenant's window
        (executing queued ops itself in deterministic mode) instead of
        failing the ticket — what batch producers (blockstore puts, the
        request log) want.  Other refusals still fail the ticket."""
        while True:
            t = self._submit_once(op, lba, data, blocks, tenant,
                                  count_refusal=not block)
            if not (block and t.state == DONE
                    and isinstance(t.error, BackpressureError)):
                return t
            if self.inline:
                if self._run_inline(1) == 0:
                    time.sleep(0.001)    # head blocked on a drain
            else:                        # callback: let the pool run
                with self._cond:
                    if self._inflight.get(tenant, 0) \
                            >= self.max_inflight_per_tenant:
                        self._cond.wait(timeout=0.05)

    def try_submit(self, op: str, lba: int = 0, data=None, blocks=None,
                   tenant=None) -> Ticket | None:
        """Non-blocking window probe: returns None — without counting a
        failure — when the tenant is at its in-flight bound, the ticket
        otherwise.  Flow-control probes (the blockstore's restore pump)
        must not pollute the per-ticket failure stats."""
        t = self._submit_once(op, lba, data, blocks, tenant,
                              count_refusal=False)
        if t.state == DONE and isinstance(t.error, BackpressureError):
            return None
        return t

    def _submit_once(self, op, lba, data, blocks, tenant,
                     count_refusal: bool = True) -> Ticket:
        with self._cond:
            t = Ticket(next(self._tids), next(self._seqs), op, lba,
                       tenant, self)
            err = None
            if op not in _OPS:
                err = SubmitError(f"unknown op {op!r}")
            elif self._closed:
                err = SubmitError("submit after close")
            elif self._dead is not None:
                err = SubmitError(f"engine dead: {self._dead!r}")
            elif self._inflight.get(tenant, 0) \
                    >= self.max_inflight_per_tenant:
                err = BackpressureError(
                    f"tenant {tenant!r} over its in-flight bound "
                    f"({self.max_inflight_per_tenant})")
            if err is not None:
                # refused submissions complete in the caller's hand and
                # generate NO completion event (io_uring's -EAGAIN): a
                # retry loop must not litter the ring, and a blocking
                # submit's wait attempts stay counter-invisible
                t.state = DONE
                t.error = err
                if count_refusal or not isinstance(err, BackpressureError):
                    self.submitted += 1
                    self.failed += 1
                return t
            self.submitted += 1
            t.value = (data, blocks)          # op args ride the ticket
            self._sqs.setdefault(tenant, deque()).append(t)
            self._open[t.seq] = t
            self._inflight[tenant] = self._inflight.get(tenant, 0) + 1
            self._cond.notify_all()
            return t

    def cancel(self, ticket: Ticket) -> bool:
        """Cancel a still-queued ticket: it completes on the ring with
        :class:`CancelledError`.  Returns False once dispatched (an op
        already on its way to the media cannot be recalled)."""
        with self._cond:
            if ticket.state != QUEUED or ticket.seq not in self._open:
                return False
            sq = self._sqs.get(ticket.tenant)
            try:
                sq.remove(ticket)
            except (ValueError, AttributeError):
                return False
            self._finish_locked(ticket, error=CancelledError("cancelled"))
            return True

    # ------------------------------------------------------------ completion
    def poll(self, max_ops: int | None = None) -> list[Ticket]:
        """Drain the completion ring (oldest first).  In deterministic
        mode this FIRST executes up to ``max_ops`` queued ops inline in
        submission order (all eligible ops when ``None``), so
        ``submit(); poll()`` is a replayable schedule."""
        if self.inline:
            self._run_inline(max_ops)
        with self._cond:
            out = list(self._cq)
            self._cq.clear()
            return out

    def wait(self, ticket: Ticket, timeout: float | None = None) -> Ticket:
        """Block until ``ticket`` completes.  Waiting CONSUMES the
        completion — the ticket will not show up on a later ``poll`` —
        so wait()-only consumers (blockstore, request log) never grow
        the ring.  In deterministic mode this executes queued ops ONE at
        a time, stopping the moment the ticket completes: ops submitted
        after it stay queued (the replayable schedule does not advance
        past the caller's intent)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._cond:
                if ticket.state == DONE:
                    try:
                        self._cq.remove(ticket)
                    except ValueError:
                        pass             # already polled
                    return ticket
            if self.inline:
                if self._run_inline(1) == 0:
                    with self._cond:     # head blocked on a drain
                        if ticket.state != DONE:    # callback: let the
                            self._cond.wait(timeout=0.05)   # pool run
            else:
                with self._cond:
                    if ticket.state != DONE:
                        self._cond.wait(timeout=0.05)
            if deadline is not None and time.monotonic() >= deadline:
                with self._cond:
                    if ticket.state == DONE:     # completed AT the
                        try:                     # deadline: not a timeout
                            self._cq.remove(ticket)
                        except ValueError:
                            pass
                        return ticket
                    raise TimeoutError(
                        f"ticket {ticket.tid} still "
                        f"{('queued', 'running', 'done')[ticket.state]}")

    def drain(self, timeout: float | None = None) -> None:
        """Wait for every submitted ticket to complete."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self.inline:
                self._run_inline(None)
            with self._cond:
                if not self._open:
                    return
                if self._dead is not None:
                    raise self._dead
                self._cond.wait(timeout=0.05)
            if deadline is not None and time.monotonic() >= deadline:
                raise TimeoutError(f"{len(self._open)} tickets open")

    # -------------------------------------------------------------- dispatch
    def _pick_locked(self):
        """(ticket, barrier_blocked): the queued ticket with the oldest
        seq across every SQ; barriers are not ready while any earlier
        ticket is still open."""
        best = None
        for sq in self._sqs.values():
            if sq and (best is None or sq[0].seq < best.seq):
                best = sq[0]
        if best is None:
            return None, False
        if best.op in _BARRIER_OPS and min(self._open) < best.seq:
            return best, True
        return best, False

    def _pop_locked(self, ticket: Ticket) -> None:
        self._sqs[ticket.tenant].popleft()
        ticket.state = RUNNING

    def _run_inline(self, max_ops: int | None) -> int:
        n = 0
        while max_ops is None or n < max_ops:
            with self._cond:
                t, blocked = self._pick_locked()
                if t is None or blocked:
                    # a blocked barrier waits on callback-completed
                    # tickets (eviction drains) — the pool threads will
                    # finish them; the caller polls again
                    return n
                self._pop_locked(t)
            self._execute(t)
            n += 1
        return n

    def _worker(self) -> None:
        while True:
            with self._cond:
                while True:
                    if self._dead is not None:
                        self._fail_queued_locked()
                    t, blocked = self._pick_locked()
                    if t is not None and not blocked:
                        self._pop_locked(t)
                        break
                    if self._closed and t is None:
                        return
                    self._cond.wait(timeout=0.2)
            self._execute(t)

    def _execute(self, t: Ticket) -> None:
        data, blocks = t.value if isinstance(t.value, tuple) else (None, None)
        t.value = None
        t0 = time.perf_counter_ns()
        try:
            val = self._run_op(t, data, blocks)
        except SimulatedCrash as e:
            # power loss: the whole ring dies with the machine
            self._fatal(e, t)
            if self.inline:
                raise
            return
        except Exception as e:       # injected device error, journal
            self._observe_svc(t, t0)            # overflow, ... — per-ticket
            self._complete(t, error=e)
            return
        self._observe_svc(t, t0)
        if val is _PENDING:
            return                   # completes via drain callback
        self._complete(t, value=val)

    def _observe_svc(self, t: Ticket, t0: int) -> None:
        """Per-op service-time EWMA on the volume's metrics (fail-slow
        groundwork: ``Metrics.per_node()`` keys ``aio::<op>``)."""
        m = getattr(self.vol, "metrics", None)
        if m is not None:
            m.observe(f"svc::aio::{t.op}", time.perf_counter_ns() - t0)

    def _run_op(self, t: Ticket, data, blocks):
        vol = self.vol
        if t.op == "write":
            return vol.write(t.lba, data, tenant=t.tenant)
        if t.op == "write_multi":
            return vol.write_multi(t.lba, blocks, tenant=t.tenant)
        if t.op == "read":
            return vol.read(t.lba, tenant=t.tenant)
        if t.op == "fsync":
            return vol.fsync()       # rides the GroupCommitter leader
        assert t.op == "flush"
        return self._flush_async(t)

    def _flush_async(self, t: Ticket):
        """WBQ-drain barrier without parking a worker: register one-shot
        drain waiters on every shard cache; the ticket completes from
        the eviction pool's completion path."""
        caches = [c for c in getattr(self.vol, "_caches", [])
                  if hasattr(c, "add_drain_waiter")]
        if not caches:
            self.vol.flush()
            return None
        for c in caches:
            if hasattr(c, "kick_drain"):
                c.kick_drain()       # staging configs enqueue their WBQs
        state = {"left": 1}          # sentinel guards registration phase
        slock = threading.Lock()

        def child_done() -> None:
            with slock:
                state["left"] -= 1
                fire = state["left"] == 0
            if fire:
                self._complete(t, value=None)

        for c in caches:
            with slock:
                state["left"] += 1
            if not c.add_drain_waiter(child_done):
                child_done()         # already drained
        child_done()                 # drop the sentinel
        return _PENDING

    # ------------------------------------------------------------ accounting
    def _finish_locked(self, t: Ticket, value=None, error=None) -> None:
        t.value = value
        t.error = error
        t.state = DONE
        self._open.pop(t.seq, None)
        n = self._inflight.get(t.tenant, 0)
        if n:
            self._inflight[t.tenant] = n - 1
        if error is None:
            self.completed += 1
        elif isinstance(error, CancelledError):
            self.cancelled += 1          # cancels are not failures
        else:
            self.failed += 1
        self._cq.append(t)
        self._cond.notify_all()

    def _complete(self, t: Ticket, value=None, error=None) -> None:
        with self._cond:
            self._finish_locked(t, value=value, error=error)

    def _fail_queued_locked(self) -> None:
        err = self._dead
        for sq in self._sqs.values():
            while sq:
                self._finish_locked(sq.popleft(), error=SubmitError(
                    f"engine dead: {err!r}"))

    def _fatal(self, err: BaseException, t: Ticket) -> None:
        with self._cond:
            self._dead = err
            self._finish_locked(t, error=err)
            self._fail_queued_locked()

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        with self._lock:
            return {
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "cancelled": self.cancelled,
                "open": len(self._open),
                "cq_depth": len(self._cq),
                "inflight": {k: v for k, v in self._inflight.items() if v},
                "workers": len(self._workers),
            }

    def close(self, drain: bool = True) -> None:
        if drain and self._dead is None:
            try:
                self.drain(timeout=30.0)
            except (TimeoutError, SimulatedCrash):
                pass
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        for w in self._workers:
            w.join(timeout=5.0)
