"""Redo journal for cross-shard write atomicity.

A shard's BTT makes each *single-block* write atomic (CoW + Flog), but a
logical write that spans shards has no such guarantee: a crash between the
per-shard writes leaves a torn multi-block write.  The volume closes the
gap with physical redo journaling, the same discipline ext4's data journal
and md's write journal use, built out of the atomicity primitive we
already have — one BTT block write:

  1. the payload blocks are written into a journal slot (direct to the
     slot shard's BTT, bypassing any staging cache);
  2. the header block — {magic, txid, logical lba, n_blocks, payload crc}
     — is written LAST via one atomic BTT write.  That is the commit
     point: a valid header proves the whole payload is on media;
  3. only then do the in-place data writes start (through the shards'
     transit caches, eagerly evicted in the background).

Recovery replays every journal slot whose header is valid and whose txid
is newer than the checkpointed ``applied`` txid, in txid order — torn
in-place writes are rolled forward to the complete image, and a tx whose
header never landed is invisible (old data intact on every shard).
``fsync`` checkpoints: after the caches drain, all journaled txids are
durable in place, so the applied mark advances and old slots are skipped
at recovery (a later un-journaled overwrite can no longer be clobbered by
a stale replay).

Slots are striped round-robin across shards so journal bandwidth scales
with the volume.
"""
from __future__ import annotations

import struct
import threading
import zlib

import numpy as np

_JMAGIC = 0x10CA171          # "IO CAITI" journal
_HDR_FMT = "<QQQQQ"          # magic, txid, lba, n_blocks, crc


class VolumeJournal:
    """Ring of ``n_slots`` redo slots striped over the shard BTTs.

    ``btts``      — one BTT per shard (journal I/O bypasses caches).
    ``base_lba``  — first shard-local lba of the journal region (the same
                    on every shard; the volume reserves the region).
    ``span``      — max payload blocks per transaction (slot size - 1).
    """

    def __init__(self, btts, *, base_lba: int, n_slots: int = 64,
                 span: int = 8, block_size: int = 4096) -> None:
        self.btts = list(btts)
        self.n_shards = len(self.btts)
        self.base_lba = base_lba
        self.n_slots = n_slots
        self.span = span
        self.block_size = block_size
        self.slot_blocks = 1 + span                    # header + payload
        self._lock = threading.Lock()
        self.next_txid = 1          # 0 means "nothing applied yet"
        self.applied_txid = 0       # persisted by the volume superblock

    # ------------------------------------------------------------ geometry
    def blocks_per_shard(self) -> int:
        slots_here = (self.n_slots + self.n_shards - 1) // self.n_shards
        return slots_here * self.slot_blocks

    def _slot_home(self, slot: int) -> tuple[int, int]:
        """(shard, shard-local lba of the slot's header block)."""
        shard = slot % self.n_shards
        local = slot // self.n_shards
        return shard, self.base_lba + local * self.slot_blocks

    # ------------------------------------------------------------- logging
    def log(self, lba: int, blocks: list[bytes],
            checkpoint_cb=None) -> int:
        """Persist one redo record; returns the committed txid.

        ``checkpoint_cb`` is invoked (outside no locks we need re-entrant)
        when the ring wraps onto a slot whose previous occupant has not
        been checkpointed yet — the volume drains its caches and advances
        ``applied_txid`` so the slot is safe to reuse.
        """
        assert 0 < len(blocks) <= self.span, \
            f"tx of {len(blocks)} blocks exceeds journal span {self.span}"
        with self._lock:
            txid = self.next_txid
            self.next_txid += 1
            need_ckpt = txid - self.n_slots > self.applied_txid \
                and txid > self.n_slots
        if need_ckpt and checkpoint_cb is not None:
            # checkpoint strictly BELOW this txid: the current tx has not
            # written in place yet, so marking it applied would let a
            # crash skip its replay and surface a torn write
            checkpoint_cb(txid - 1)
        slot = txid % self.n_slots
        shard, hdr_lba = self._slot_home(slot)
        btt = self.btts[shard]
        payload = b"".join(bytes(b) for b in blocks)
        crc = zlib.crc32(payload)
        for i, blk in enumerate(blocks):
            btt.write(hdr_lba + 1 + i, np.frombuffer(bytes(blk), np.uint8))
        hdr = struct.pack(_HDR_FMT, _JMAGIC, txid, lba, len(blocks), crc)
        hdr = hdr + b"\x00" * (self.block_size - len(hdr))
        # the commit point: one atomic BTT block write
        btt.write(hdr_lba, np.frombuffer(hdr, np.uint8))
        return txid

    def mark_applied(self, txid: int) -> None:
        with self._lock:
            self.applied_txid = max(self.applied_txid, txid)

    def last_txid(self) -> int:
        with self._lock:
            return self.next_txid - 1

    # ------------------------------------------------------------ recovery
    def scan(self) -> list[tuple[int, int, list[bytes]]]:
        """All valid records newer than ``applied_txid``: (txid, lba, blocks),
        sorted ascending by txid."""
        found = []
        hdr_len = struct.calcsize(_HDR_FMT)
        for slot in range(self.n_slots):
            shard, hdr_lba = self._slot_home(slot)
            btt = self.btts[shard]
            raw = bytes(btt.read(hdr_lba)[:hdr_len])
            magic, txid, lba, n_blocks, crc = struct.unpack(_HDR_FMT, raw)
            if magic != _JMAGIC or txid <= self.applied_txid:
                continue
            if not 0 < n_blocks <= self.span:
                continue
            blocks = [bytes(btt.read(hdr_lba + 1 + i))
                      for i in range(n_blocks)]
            if zlib.crc32(b"".join(blocks)) != crc:
                continue                     # torn journal write: not committed
            found.append((txid, lba, blocks))
        found.sort(key=lambda r: r[0])
        return found
