"""Chained-transaction redo journal with group-committed checkpoints.

A shard's BTT makes each *single-block* write atomic (CoW + Flog), but a
logical write that spans shards has no such guarantee: a crash between the
per-shard writes leaves a torn multi-block write.  The volume closes the
gap with physical redo journaling built out of the atomicity primitive we
already have — one BTT block write.

Commit records (one journal slot each)
--------------------------------------
Every record header carries ``{magic, txid, lba, n_blocks, crc, chain_id,
seq, flags}``.  A logical write of up to ``span`` blocks is ONE record; a
larger write becomes a **chain** of records sharing a ``chain_id`` (the
chain's first txid) with consecutive ``seq`` numbers, the last one flagged
``CHAIN_TAIL``.  The commit protocol for a chain is:

  1. every link's payload blocks are written into its journal slot
     (direct to the slot shard's BTT, bypassing any staging cache);
  2. the non-tail headers are written next, grouped by slot shard so a
     multi-link chain costs one header pass per shard;
  3. the TAIL header is written LAST via one atomic BTT write.  That
     single block write is the commit point for the *whole chain*: a
     valid tail proves every earlier link is on media (headers are
     ordered), so recovery replays the chain whole — and a crash before
     the tail leaves the chain invisible (old object intact on every
     shard), because in-place writes only start after the tail lands.

This gives **whole-object atomicity** for arbitrarily large logical
writes (bounded by the ring: a chain may not exceed ``n_slots`` links)
without a blockstore-style root flip and without per-transaction-only
guarantees.  Legacy records written before chaining existed carry
``chain_id == 0`` and replay standalone, so old volumes reopen cleanly.

Recovery (:meth:`VolumeJournal.scan`) keeps a record iff its header is
valid, its txid is newer than the checkpointed ``applied`` txid, and its
chain is *complete* — all links present with the tail flagged.  Torn
in-place writes are rolled forward to the complete image; a chain whose
tail never landed is invisible.

Batched log pipeline
--------------------
``log_batch`` persists MANY chains in shared slot-shard passes — one txid
reservation, all payloads, all non-tail headers grouped per shard, then
every tail header in one final pass (each tail is still its own chain's
atomic commit point, so a crash inside the tail pass commits whole
members only, never a partial member chain).  :class:`LogBatcher` feeds
it: concurrent ``log()``/``write_multi`` callers elect a leader that
flushes the whole pending list under ONE volume ``_txlock`` acquisition
(``log_window`` gathers followers, mirroring ``commit_window``) — the
NVCache-style shared log that absorbs small-write bursts without
per-I/O journal stalls.

Checkpoints and group commit
----------------------------
``fsync`` checkpoints: after the caches drain, all journaled txids are
durable in place, so the applied mark advances and old slots are skipped
at recovery.  The volume wraps that checkpoint in a
:class:`GroupCommitter`: concurrent ``fsync`` callers elect one leader
that performs a single drain + one applied-mark superblock pass for the
whole batch (optionally waiting ``commit_window`` seconds to gather more
followers) — N tenants syncing together pay one header-write round trip
instead of N, the NVCache/van-Renen group-commit argument.

Slots are striped round-robin across shards so journal bandwidth scales
with the volume.
"""
from __future__ import annotations

import struct
import threading
import time
import zlib

import numpy as np

_JMAGIC = 0x10CA171          # "IO CAITI" journal
# magic, txid, lba, n_blocks, crc, chain_id, seq, flags
_HDR_FMT = "<QQQQQQQQ"
CHAIN_TAIL = 1               # flags bit: last link of its chain


class VolumeJournal:
    """Ring of ``n_slots`` redo slots striped over the shard BTTs.

    ``btts``      — one BTT per shard (journal I/O bypasses caches).
    ``base_lba``  — first shard-local lba of the journal region (the same
                    on every shard; the volume reserves the region).
    ``span``      — max payload blocks per record (slot size - 1); larger
                    logical writes chain multiple records.
    """

    def __init__(self, btts, *, base_lba: int, n_slots: int = 64,
                 span: int = 8, block_size: int = 4096) -> None:
        self.btts = list(btts)
        self.n_shards = len(self.btts)
        self.base_lba = base_lba
        self.n_slots = n_slots
        self.span = span
        self.block_size = block_size
        self.slot_blocks = 1 + span                    # header + payload
        self._lock = threading.Lock()
        self.next_txid = 1          # 0 means "nothing applied yet"
        self.applied_txid = 0       # persisted by the volume superblock
        self.chains_logged = 0

    # ------------------------------------------------------------ geometry
    def blocks_per_shard(self) -> int:
        slots_here = (self.n_slots + self.n_shards - 1) // self.n_shards
        return slots_here * self.slot_blocks

    def _slot_home(self, slot: int) -> tuple[int, int]:
        """(shard, shard-local lba of the slot's header block)."""
        shard = slot % self.n_shards
        local = slot // self.n_shards
        return shard, self.base_lba + local * self.slot_blocks

    def max_chain_blocks(self) -> int:
        """Largest logical write one chain can cover (ring bound)."""
        return self.n_slots * self.span

    # ------------------------------------------------------------- logging
    def _write_payload(self, txid: int, blocks) -> tuple[int, int, int]:
        """Write one record's payload into its slot; returns
        (shard, header lba, payload crc)."""
        slot = txid % self.n_slots
        shard, hdr_lba = self._slot_home(slot)
        btt = self.btts[shard]
        payload = b"".join(bytes(b) for b in blocks)
        crc = zlib.crc32(payload)
        for i, blk in enumerate(blocks):
            btt.write(hdr_lba + 1 + i, np.frombuffer(bytes(blk), np.uint8))
        return shard, hdr_lba, crc

    def _write_header(self, shard: int, hdr_lba: int, txid: int, lba: int,
                      n_blocks: int, crc: int, chain_id: int, seq: int,
                      flags: int) -> None:
        hdr = struct.pack(_HDR_FMT, _JMAGIC, txid, lba, n_blocks, crc,
                          chain_id, seq, flags)
        hdr = hdr + b"\x00" * (self.block_size - len(hdr))
        # one atomic BTT block write
        self.btts[shard].write(hdr_lba, np.frombuffer(hdr, np.uint8))

    def log(self, lba: int, blocks: list[bytes],
            checkpoint_cb=None) -> int:
        """Persist one single-record transaction; returns the committed
        txid.  Equivalent to a chain of length 1 (the header is flagged
        ``CHAIN_TAIL`` immediately, so it is the commit point)."""
        return self.log_chain(lba, blocks, checkpoint_cb=checkpoint_cb)[-1]

    def log_chain(self, lba: int, blocks, checkpoint_cb=None) -> list[int]:
        """Persist one logical write as a chain of records; returns the
        txids, tail last.  The write is committed — recovery will roll the
        WHOLE image forward — only once this returns (tail header landed);
        any earlier crash leaves it invisible.  A batch of one: see
        :meth:`log_batch` for the checkpoint-callback contract."""
        return self.log_batch([(lba, blocks)], checkpoint_cb=checkpoint_cb)[0]

    @staticmethod
    def _chunk_links(blocks, span: int) -> list[list[bytes]]:
        return [blocks[off:off + span] for off in range(0, len(blocks), span)]

    def log_batch(self, entries, checkpoint_cb=None,
                  apply_cb=None) -> list[list[int]]:
        """Persist MANY logical writes as batched slot-shard passes;
        returns one txid list (tail last) per entry, in entry order.

        ``entries`` is a sequence of ``(lba, blocks)`` pairs.  Each entry
        is its own chain (its own ``chain_id`` and its own tail commit
        point) but the batch shares the passes:

          1. ONE txid reservation under the journal lock for the whole
             group (instead of one per call);
          2. every entry's payload blocks into their slots;
          3. ALL non-tail headers of the batch, one pass per slot shard;
          4. ALL tail headers, one final pass per slot shard — written
             strictly after every non-tail header of the batch, so each
             member chain is complete on media before ANY member commits.

        Crash semantics per member are unchanged from :meth:`log_chain`:
        a member whose tail landed replays whole; a member whose tail
        did not land is invisible (its old image intact).  A crash inside
        the tail pass commits some members and not others — but NEVER a
        partial member chain, because phase 3 ordered all of its links
        onto media first.

        A batch whose total links exceed the ring is split into
        consecutive sub-groups that fit (each group <= ``n_slots`` links;
        a single oversized entry still asserts, as ``log_chain`` did).
        ``apply_cb(entry_index, txids)`` is invoked for every member of a
        group as soon as that group's tails are on media and BEFORE the
        next group journals: a later group may reuse the earlier group's
        slots (and its ring-wrap checkpoint will mark them applied), so
        the earlier members' in-place writes must already be issued —
        exactly the ordering sequential ``log_chain`` calls had.  The
        caller that applies AFTER ``log_batch`` returns (no ``apply_cb``)
        must only pass batches that fit one group.

        ``checkpoint_cb(upto)`` is invoked when the ring wraps onto slots
        whose previous occupants have not been checkpointed yet — the
        volume drains its caches and advances ``applied_txid``.  The
        callback receives an upper bound strictly below the group's first
        txid: marking any chain of the CURRENT group applied before its
        in-place writes happen would let a crash skip the replay and
        surface a torn object (earlier groups are already applied via
        ``apply_cb``).
        """
        ents = []
        for lba, blocks in entries:
            blocks = [bytes(b) for b in blocks]
            assert blocks, "empty transaction"
            links = self._chunk_links(blocks, self.span)
            assert len(links) <= self.n_slots, \
                f"chain of {len(links)} links exceeds the {self.n_slots}-" \
                f"slot ring (max {self.max_chain_blocks()} blocks per " \
                f"logical write)"
            ents.append((lba, links))
        results: list[list[int] | None] = [None] * len(ents)
        i = 0
        while i < len(ents):
            group, total = [], 0
            while i < len(ents) and (not group
                                     or total + len(ents[i][1])
                                     <= self.n_slots):
                group.append(i)
                total += len(ents[i][1])
                i += 1
            self._log_group([ents[g] for g in group], group, results,
                            checkpoint_cb)
            if apply_cb is not None:
                for g in group:
                    apply_cb(g, results[g])
        return results

    def _log_group(self, group, idxs, results, checkpoint_cb) -> None:
        """One batched slot-shard pass for a group of chains whose links
        fit the ring together."""
        n_links = sum(len(links) for _, links in group)
        with self._lock:
            first = self.next_txid
            self.next_txid += n_links
            last = first + n_links - 1
            # slots for txids (last - n_slots, last] are about to be
            # reused; everything at or below last - n_slots must be
            # checkpointed first.  The checkpoint drains every cache, so
            # marking applied up to first - 1 is safe — but never the
            # group itself (its in-place writes have not happened yet)
            need_ckpt = last > self.n_slots \
                and last - self.n_slots > self.applied_txid
        if need_ckpt and checkpoint_cb is not None:
            checkpoint_cb(first - 1)
        # phase 1: all payloads, every entry of the batch
        txid = first
        per_entry = []          # [(txid, lba, n, shard, hdr_lba, crc,
        for lba, links in group:                        # chain_id, seq)]
            chain_id = txid
            homes = []
            off = 0
            for seq, link in enumerate(links):
                shard, hdr_lba, crc = self._write_payload(txid, link)
                homes.append((txid, lba + off, len(link), shard, hdr_lba,
                              crc, chain_id, seq))
                off += len(link)
                txid += 1
            per_entry.append(homes)
        # phase 2: non-tail headers of the WHOLE batch, one pass per shard
        body = [h for homes in per_entry for h in homes[:-1]]
        for shard in sorted({h[3] for h in body}):
            for (txid, blk, n, s, hdr_lba, crc, chain_id, seq) in body:
                if s == shard:
                    self._write_header(s, hdr_lba, txid, blk, n, crc,
                                       chain_id, seq, 0)
        # phase 3: the commit points — every tail header, one final pass
        # per slot shard, written after all of phase 2 (each member chain
        # is wholly on media before any member becomes committed)
        tails = [homes[-1] for homes in per_entry]
        for shard in sorted({h[3] for h in tails}):
            for (txid, blk, n, s, hdr_lba, crc, chain_id, seq) in tails:
                if s == shard:
                    self._write_header(s, hdr_lba, txid, blk, n, crc,
                                       chain_id, seq, CHAIN_TAIL)
        with self._lock:
            self.chains_logged += len(group)
        for k, homes in zip(idxs, per_entry):
            results[k] = [h[0] for h in homes]

    def mark_applied(self, txid: int) -> None:
        with self._lock:
            self.applied_txid = max(self.applied_txid, txid)

    def last_txid(self) -> int:
        with self._lock:
            return self.next_txid - 1

    # ------------------------------------------------------------ recovery
    def scan(self) -> list[tuple[int, int, list[bytes]]]:
        """All committed records newer than ``applied_txid``:
        (txid, lba, blocks), sorted ascending by txid.

        A record is committed iff its header+payload are valid AND its
        chain is complete: every link (seq 0..tail) present under the
        same ``chain_id`` with the tail flagged.  Legacy records
        (``chain_id == 0``, written before chaining) replay standalone.
        """
        hdr_len = struct.calcsize(_HDR_FMT)
        records = []                 # (txid, lba, blocks, chain_id, seq, fl)
        for slot in range(self.n_slots):
            shard, hdr_lba = self._slot_home(slot)
            btt = self.btts[shard]
            raw = bytes(btt.read(hdr_lba)[:hdr_len])
            magic, txid, lba, n_blocks, crc, chain_id, seq, flags = \
                struct.unpack(_HDR_FMT, raw)
            if magic != _JMAGIC or txid <= self.applied_txid:
                continue
            if not 0 < n_blocks <= self.span:
                continue
            blocks = [bytes(btt.read(hdr_lba + 1 + i))
                      for i in range(n_blocks)]
            if zlib.crc32(b"".join(blocks)) != crc:
                continue                 # torn journal write: not committed
            records.append((txid, lba, blocks, chain_id, seq, flags))
        # chain completeness: keep standalone/legacy records; keep chain
        # links only when the whole chain made it (tail header landed)
        by_chain: dict[int, list] = {}
        for rec in records:
            by_chain.setdefault(rec[3], []).append(rec)
        found = []
        for chain_id, recs in by_chain.items():
            if chain_id == 0:            # legacy: each record standalone
                found.extend(recs)
                continue
            recs.sort(key=lambda r: r[4])
            tail = recs[-1]
            complete = (tail[5] & CHAIN_TAIL) \
                and [r[4] for r in recs] == list(range(len(recs))) \
                and [r[0] for r in recs] == [chain_id + i
                                             for i in range(len(recs))]
            if complete:
                found.extend(recs)
        found.sort(key=lambda r: r[0])
        return [(txid, lba, blocks) for txid, lba, blocks, *_ in found]


class GroupCommitter:
    """Leader/follower coalescing for ``fsync``-style commits.

    ``sync()`` guarantees that one full commit (``commit_fn``) starts
    after the call and completes before it returns — but N concurrent
    callers share ONE commit: the first becomes leader, optionally waits
    ``window`` seconds for more followers, then runs ``commit_fn`` once
    for the whole batch.  Followers whose request predates the commit's
    start simply wait for it.  With ``window == 0`` there is no added
    latency and purely-concurrent callers still coalesce.
    """

    def __init__(self, commit_fn, window: float = 0.0) -> None:
        self._commit_fn = commit_fn
        self.window = window
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._seq = 0                # requests issued
        self._completed = 0          # highest request covered by a commit
        self._leader = False
        # failed batches as (low, high, err) request ranges: an error is
        # delivered ONLY to the callers whose requests that commit
        # covered, never leaked to a later batch's waiters
        self._failed: list[tuple[int, int, BaseException]] = []
        self.commits = 0             # commit_fn invocations
        self.calls = 0               # sync() invocations

    def _batch_error(self, req: int) -> BaseException | None:
        for low, high, err in self._failed:
            if low <= req <= high:
                return err
        return None

    def sync(self) -> bool:
        """Returns True when this caller led the commit, False when it
        coalesced onto another caller's."""
        with self._cond:
            self.calls += 1
            self._seq += 1
            my_req = self._seq
            while True:
                if self._completed >= my_req:
                    err = self._batch_error(my_req)
                    if err is not None:
                        raise err
                    return False
                if not self._leader:
                    self._leader = True
                    break
                self._cond.wait(timeout=0.5)
        # ---- leader: gather, commit once for everyone <= batch_high
        err = None
        try:
            if self.window > 0:
                time.sleep(self.window)
            with self._lock:
                batch_high = self._seq
            try:
                self._commit_fn()
            except BaseException as e:      # propagate to the whole batch
                err = e
            with self._cond:
                self.commits += 1
                if err is not None:
                    self._failed.append(
                        (self._completed + 1, batch_high, err))
                    if len(self._failed) > 64:     # bound the history
                        self._failed.pop(0)
                self._completed = max(self._completed, batch_high)
        finally:
            with self._cond:
                self._leader = False
                self._cond.notify_all()
        if err is not None:
            raise err
        return True

    def stats(self) -> dict:
        with self._lock:
            return {"calls": self.calls, "commits": self.commits,
                    "coalesced": self.calls - self.commits}


class LogEntry:
    """One logical write riding a :class:`LogBatcher` batch."""

    __slots__ = ("lba", "blocks", "tenant", "txids", "error", "done")

    def __init__(self, lba: int, blocks, tenant=None) -> None:
        self.lba = lba
        self.blocks = blocks
        self.tenant = tenant
        self.txids: list[int] | None = None
        self.error: BaseException | None = None
        self.done = False

    @property
    def nbytes(self) -> int:
        return sum(len(b) for b in self.blocks)


class LogBatcher:
    """Leader/follower coalescing for chained-tx ``log()`` payload writes.

    The group committer (above) coalesces *fsyncs*; this coalesces the
    **log writes themselves**.  Without it every ``write_multi`` chain
    serializes its own slot-shard pass under the volume ``_txlock`` —
    N concurrent small logged writes pay N lock acquisitions, N header
    passes and N tail fences.  With it, concurrent ``submit()`` callers
    elect a leader that (optionally after gathering ``window`` seconds,
    the ``log_window`` knob mirroring ``commit_window``) hands the WHOLE
    pending list to ``flush_fn`` in one go: one ``_txlock`` acquisition,
    headers grouped per slot shard across the batch, one tail pass per
    batch (see :meth:`VolumeJournal.log_batch`) — the NVCache-style
    shared-log batching of small durable writes.

    ``flush_fn(entries)`` journals + applies every entry (setting
    ``entry.txids``); an exception it raises is delivered to exactly the
    callers whose entries were in that batch, never leaked to a later
    batch.  ``submit()`` returns the entry's txids once its batch has
    fully committed AND applied in place — same post-condition as a
    direct ``log_chain`` + in-place pass.
    """

    def __init__(self, flush_fn, window: float = 0.0) -> None:
        self._flush_fn = flush_fn
        self.window = window
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._pending: list[LogEntry] = []
        self._leader = False
        self.calls = 0               # submit() invocations
        self.batches = 0             # flush_fn invocations
        self.batched_entries = 0     # entries flushed (== calls, eventually)
        self.max_batch = 0

    def submit(self, lba: int, blocks, tenant=None) -> list[int]:
        entry = LogEntry(lba, blocks, tenant)
        with self._cond:
            self.calls += 1
            self._pending.append(entry)
            while True:
                if entry.done:
                    if entry.error is not None:
                        raise entry.error
                    return entry.txids
                if not self._leader:
                    self._leader = True
                    break
                self._cond.wait(timeout=0.5)
        # ---- leader: gather, flush the whole pending list in one pass
        try:
            if self.window > 0:
                time.sleep(self.window)
            with self._lock:
                batch, self._pending = self._pending, []
            err = None
            try:
                self._flush_fn(batch)
            except BaseException as e:   # delivered to THIS batch only
                err = e
            with self._cond:
                self.batches += 1
                self.batched_entries += len(batch)
                self.max_batch = max(self.max_batch, len(batch))
                for b in batch:
                    b.error = err
                    b.done = True
        finally:
            with self._cond:
                self._leader = False
                self._cond.notify_all()
        if entry.error is not None:
            raise entry.error
        return entry.txids

    def stats(self) -> dict:
        with self._lock:
            return {"calls": self.calls, "batches": self.batches,
                    "coalesced": self.batched_entries - self.batches,
                    "max_batch": self.max_batch}
