"""Striped multi-device volume manager (RAID-0, optional replication).

Composes N ``BlockDevice`` shards — each the paper's full stack (transit
cache over BTT over PMem) — into one logical LBA space:

  * **striping**: logical stripe ``st = lba // stripe_blocks`` lives on
    shard ``st % n_shards``; consecutive stripes rotate shards so a
    sequential writer spreads over all PMem DIMM sets;
  * **shared eviction pool**: one :class:`SharedEvictionPool` drains every
    shard's write-back queue congestion-aware instead of per-device
    thread pools;
  * **global conditional bypass**: a write miss transits straight to BTT
    when its shard's buffer is full (the paper's per-device rule) OR when
    the volume's aggregate staged bytes cross ``bypass_watermark`` — under
    volume-wide pressure the staging detour stops paying for itself
    before any single shard is full;
  * **per-tenant QoS**: token-bucket rate caps and weighted fair (SFQ)
    admission, so many clients share one volume predictably;
  * **crash recovery**: per-shard BTT Flog replay (device open) plus the
    chained-tx redo journal (:class:`VolumeJournal`) replayed in txid
    order — a logical write of ANY size (up to the journal ring) is
    whole-object all-or-nothing: ``write_multi`` journals it as a chain
    of records whose tail header is the single commit point;
  * **group commit**: concurrent ``fsync`` callers coalesce behind a
    :class:`~repro.volume.journal.GroupCommitter` leader — one drain +
    one applied-mark superblock pass per batch (``commit_window``
    gathers followers), amortizing the sync round trip across tenants;
  * **batched log pipeline**: concurrent ``write_multi`` chains coalesce
    behind a :class:`~repro.volume.journal.LogBatcher` leader into ONE
    slot-shard journal pass — one ``_txlock`` acquisition, headers
    grouped per shard, one tail pass per batch (``log_window`` gathers
    followers) — so small-write-heavy tenants stop paying a serialized
    journal pass per ``log()``;
  * **tier-aware WFQ**: tenant reads pass the gate tagged with their
    probed serving tier and are charged virtual time at
    ``tier_hit_cost_frac`` for DRAM service; batched log writes are
    charged once per batch to their constituent tenants
    (``WFQGate.charge_batch``) — one coherent fairness story across
    reads, writes and journal traffic;
  * **unified admission** (:class:`~repro.volume.AdmissionPolicy`): the
    bypass watermark, the read-tier fill policy (sequential-scan bypass)
    and tier-aware QoS read pricing live behind one object consulted by
    the shard caches, the tier and this volume;
  * **layered read path** (``read_tier_bytes > 0``): one clean DRAM
    :class:`~repro.volume.read_tier.ReadTier` fronts every shard
    (tier -> transit cache -> BTT), populated on read miss and on
    eviction writeback, invalidated by writes — never journaled;
  * **degraded reads + resync** (``replicas > 1``): every read is
    verified against a write-time crc ledger; a primary-shard copy that
    fails verification is served from a replica instead, and the
    divergent block is queued to the background
    :class:`~repro.volume.read_tier.ReplicaResyncer` for repair.

Crash semantics: like any write-back device, writes are durable at
``fsync``.  After a crash, a journaled multi-block write is either fully
visible or fully invisible — whole-object, even when it spans many
journal records (the chain replays only if its tail header landed);
un-fsynced single-block writes that landed *after* a journaled write to
the same blocks may be rolled back to the journaled image when that
journal record replays.  With ``persist_ledger`` (default when reads
are verified) the write-crc ledger summary is persisted at every
checkpoint, so a REOPENED volume verifies reads — and can degrade to a
replica — before the first overwrite instead of starting blind.
"""
from __future__ import annotations

import json
import os
import struct
import threading
import time
import zlib

import numpy as np

from repro.core import make_device
from repro.core.metrics import Metrics, ShardScorer
from repro.core.pmem import LatencyModel

from .admission import AdmissionPolicy
from .aio import AsyncIOEngine, RegisteredBuf, hedged_read as _hedged_read


from .autotune import Controller
from .evict_pool import SharedEvictionPool
from .journal import GroupCommitter, LogBatcher, VolumeJournal
from .qos import TenantSpec, TokenBucket, WFQGate
from .read_tier import ReadTier, ReplicaResyncer


def _unwrap(payload):
    """A :class:`RegisteredBuf` handle's backing array — the sync write
    surface accepts the same handles the async engine pins, so a caller
    holding a registered pool never needs two code paths."""
    return payload.data if isinstance(payload, RegisteredBuf) else payload

_SB_MAGIC = "caiti-volume-v1"
_LEDGER_ENTRY = "<QI"        # lba, crc32
_LEDGER_ENTRY_SIZE = struct.calcsize(_LEDGER_ENTRY)


class VolumeConfig:
    """Geometry + policy for a striped volume (kept explicit for the
    superblock round-trip; all sizes in 4K blocks unless noted)."""

    def __init__(self, *, n_lbas: int, n_shards: int = 4,
                 stripe_blocks: int = 64, replicas: int = 1,
                 policy: str = "caiti", block_size: int = 4096,
                 cache_bytes: int = 64 << 20, shared_workers: int = 4,
                 bypass_watermark: float = 0.9, journal_slots: int = 64,
                 journal_span: int = 8, max_inflight: int = 16,
                 read_tier_bytes: int = 0, n_sockets: int = 1,
                 verify_reads: bool | None = None,
                 commit_window: float = 0.0,
                 log_window: float = 0.0,
                 scan_threshold: int = 64,
                 tier_hit_cost_frac: float = 0.125,
                 persist_ledger: bool = True,
                 aio_workers: int = 2,
                 hedge_delay_us: float = 0.0) -> None:
        assert n_shards >= 1 and stripe_blocks >= 1
        assert 1 <= replicas <= n_shards
        assert policy not in ("raw", "dax"), \
            "volume shards need BTT atomicity (journal + recovery)"
        self.n_lbas = n_lbas
        self.n_shards = n_shards
        self.stripe_blocks = stripe_blocks
        self.replicas = replicas
        self.policy = policy
        self.block_size = block_size
        self.cache_bytes = cache_bytes
        self.shared_workers = shared_workers
        self.bypass_watermark = bypass_watermark
        self.journal_slots = journal_slots
        self.journal_span = journal_span
        self.max_inflight = max_inflight
        self.read_tier_bytes = read_tier_bytes
        self.n_sockets = n_sockets
        self.commit_window = commit_window
        self.log_window = log_window
        self.scan_threshold = scan_threshold
        self.tier_hit_cost_frac = tier_hit_cost_frac
        # async frontend: dispatch threads for the lazily-created
        # AsyncIOEngine (0 = deterministic inline mode)
        self.aio_workers = aio_workers
        # hedged replicated reads: wait this long on the primary before
        # firing the replica (0 = auto: the ShardScorer's healthy-cohort
        # median p99)
        self.hedge_delay_us = hedge_delay_us
        # reads are verified (and can degrade to a replica) only when a
        # replica exists to fall back to — single-copy volumes pay nothing
        self.verify_reads = (replicas > 1 if verify_reads is None
                             else verify_reads)
        # write-crc ledger region: persisted at checkpoint so a reopened
        # volume verifies reads before its first overwrite
        self.persist_ledger = persist_ledger and self.verify_reads

    # derived geometry -------------------------------------------------------
    @property
    def n_stripes(self) -> int:
        return -(-self.n_lbas // self.stripe_blocks)

    @property
    def rows_per_shard(self) -> int:
        return -(-self.n_stripes // self.n_shards)

    @property
    def data_per_shard(self) -> int:
        return self.rows_per_shard * self.stripe_blocks

    def journal_blocks_per_shard(self) -> int:
        slots_here = -(-self.journal_slots // self.n_shards)
        return slots_here * (1 + self.journal_span)

    @property
    def ledger_blocks_per_shard(self) -> int:
        if not self.persist_ledger:
            return 0
        total = -(-self.n_lbas * _LEDGER_ENTRY_SIZE // self.block_size)
        return -(-total // self.n_shards)

    @property
    def meta_blocks(self) -> int:
        # superblock + crc-ledger region + journal region
        return (1 + self.ledger_blocks_per_shard
                + self.journal_blocks_per_shard())

    @property
    def shard_n_lbas(self) -> int:
        return self.meta_blocks + self.data_per_shard * self.replicas

    def to_sb(self, shard: int, uuid: str, applied_txid: int = 0) -> dict:
        return {"magic": _SB_MAGIC, "uuid": uuid, "shard": shard,
                "n_shards": self.n_shards, "n_lbas": self.n_lbas,
                "stripe_blocks": self.stripe_blocks,
                "replicas": self.replicas,
                "journal_slots": self.journal_slots,
                "journal_span": self.journal_span,
                "ledger_blocks": self.ledger_blocks_per_shard,
                "applied_txid": applied_txid}


class StripedVolume:
    """The logical device: bio-free convenience API (write/read/flush/fsync)
    mirroring ``BlockDevice`` plus ``write_multi`` (atomic) and tenants."""

    #: ``write_multi`` is whole-object atomic (chained-tx journal), so
    #: clients like the checkpoint blockstore can commit large objects in
    #: one logical write instead of a double-write + root-flip protocol
    supports_chained_tx = True

    def __init__(self, shards, cfg: VolumeConfig, *, uuid: str,
                 evict_pool: SharedEvictionPool | None = None,
                 read_tier: ReadTier | None = None) -> None:
        self.shards = list(shards)
        self.cfg = cfg
        self.uuid = uuid
        self.block_size = cfg.block_size
        self.n_lbas = cfg.n_lbas
        self.pool = evict_pool
        self.metrics = Metrics()          # volume-level (degraded/resync)
        # fail-slow scoring: per-shard p50/p99 digests over the
        # svc::shard{i} sample rings feed the healthy/limping/dead
        # verdicts that hedging and steering consume
        self.scorer = ShardScorer(self.metrics, family="shard")
        self.read_tier = read_tier
        # write-time crc ledger: arbitrates primary-vs-replica divergence
        # (in-DRAM only — after reopen unknown lbas are simply not verified)
        self._crcs: dict[int, int] = {}
        self._txlock = threading.Lock()
        self._caches = [d.impl for d in self.shards
                        if hasattr(d.impl, "bypass_hook")]
        self._total_cache_slots = sum(len(c._slots) for c in self._caches)
        watermark_slots = max(1, int(
            cfg.bypass_watermark * self._total_cache_slots)) \
            if self._caches else 0
        # one AdmissionPolicy unifies bypass watermark, tier-fill (scan)
        # policy and QoS read pricing for every layer of the stack
        self.admission = AdmissionPolicy(
            staged_slots_fn=self._staged_slots,
            watermark_slots=watermark_slots,
            scan_threshold=cfg.scan_threshold,
            tier_hit_cost_frac=cfg.tier_hit_cost_frac)
        for c in self._caches:
            c.bypass_hook = self.admission.should_bypass_write
            c.admission = self.admission
        if read_tier is not None:
            read_tier.admission = self.admission
        self.journal = VolumeJournal(
            [d.impl.btt for d in self.shards],
            base_lba=1 + cfg.ledger_blocks_per_shard,
            n_slots=cfg.journal_slots, span=cfg.journal_span,
            block_size=cfg.block_size)
        # group commit: concurrent fsync callers share one drain +
        # applied-mark superblock pass (window gathers followers)
        self._committer = GroupCommitter(self._commit_group,
                                         window=cfg.commit_window)
        # batched log pipeline: concurrent write_multi chains coalesce
        # behind a leader into ONE slot-shard journal pass under one
        # _txlock acquisition (log_window gathers followers)
        self._log_batcher = LogBatcher(self._flush_log_batch,
                                       window=cfg.log_window)
        self._ledger_count = 0
        self._ledger_crc = 0
        # QoS (lazy: volumes without tenants pay nothing)
        self._gate: WFQGate | None = None
        self._buckets: dict[str, TokenBucket] = {}
        self.read_debits: dict[str, int] = {}
        self.recovery_stats: dict = {}
        # async submission/completion frontend (lazy: blocking-only
        # callers pay nothing; first submit() builds the engine)
        self._aio: AsyncIOEngine | None = None
        # self-tuning control plane (attach_autotuner): None = every
        # knob frozen at its configured value (zero-overhead passthrough)
        self.autotuner: Controller | None = None
        self._autotune_prev: dict | None = None
        # background replica repair rides the shared eviction pool (its
        # own daemon thread when the policy has no pool, e.g. plain btt)
        self.resyncer = (ReplicaResyncer(self, pool=evict_pool)
                         if cfg.replicas > 1 else None)

    # -------------------------------------------------------------- mapping
    def _map(self, lba: int, replica: int = 0) -> tuple[int, int]:
        assert 0 <= lba < self.n_lbas, f"lba {lba} out of volume range"
        cfg = self.cfg
        st, within = divmod(lba, cfg.stripe_blocks)
        row, shard = divmod(st, cfg.n_shards)
        shard = (shard + replica) % cfg.n_shards
        local = (cfg.meta_blocks + cfg.data_per_shard * replica
                 + row * cfg.stripe_blocks + within)
        return shard, local

    def _staged_slots(self) -> int:
        return sum(c.staged_slots() for c in self._caches)

    # ------------------------------------------------------------------ QoS
    def add_tenant(self, name: str, weight: float = 1.0,
                   rate_mbps: float = 0.0, burst_bytes: int = 4 << 20) -> None:
        if self._gate is None:
            # the unified AdmissionPolicy prices the gate's virtual time
            # (tier-aware reads, batched log charges)
            self._gate = WFQGate(max_inflight=self.cfg.max_inflight,
                                 policy=self.admission)
        self._gate.set_tenant(name, weight)
        if rate_mbps > 0:
            self._buckets[name] = TokenBucket(rate_mbps * 1e6,
                                              burst_bytes=burst_bytes)

    def _admit(self, tenant: str | None, nbytes: int, op: str = "write",
               tier: str | None = None, shard: int | None = None):
        if tenant is None or self._gate is None:
            return None
        if op == "write":
            # reads settle their token-bucket debit post-service
            # (_debit_read: DRAM hits never sleep on the PMem budget)
            bucket = self._buckets.get(tenant)
            if bucket is not None:
                bucket.acquire(nbytes)
        # shard= tags the op's target device: work headed for a limping
        # shard is priced UP by the scorer's penalty (fail-slow steering)
        cost = self.admission.op_charge(nbytes, op, tier, shard=shard)
        self.metrics.bump(f"wfq_vbytes::{tenant}", int(cost))
        if shard is not None and self.admission.shard_penalty(shard) > 1.0:
            self.metrics.bump("steered_charges")
        return self._gate.admit(tenant, nbytes, op=op, tier=tier,
                                shard=shard)

    def _release(self, ticket) -> None:
        if ticket is not None:
            self._gate.done(ticket)

    # ------------------------------------------------------------------ I/O
    @staticmethod
    def _crc(data) -> int:
        if isinstance(data, (bytes, bytearray, memoryview)):
            return zlib.crc32(data)
        return zlib.crc32(np.ascontiguousarray(data, dtype=np.uint8))

    def _write_block(self, lba: int, data) -> None:
        if self.cfg.verify_reads:
            self._crcs[lba] = self._crc(data)
        for r in range(self.cfg.replicas):
            shard, local = self._map(lba, r)
            t0 = time.perf_counter_ns()
            self.shards[shard].write(local, data)
            self.metrics.observe(f"svc::shard{shard}",
                                 time.perf_counter_ns() - t0)

    def _pick_good_copy(self, lba: int, candidates: list[bytes]):
        """The copy to trust among divergent replicas: the write-crc
        ledger decides; with no ledger entry (reopened volume — the
        ledger is DRAM-only), a strict majority (>= 2 matching copies)
        decides.  A 1-vs-1 tie with no ledger is UNDECIDABLE: return
        None so the resyncer leaves the divergence flagged instead of
        possibly overwriting the last good copy with the corrupt one."""
        want = self._crcs.get(lba)
        if want is not None:
            for c in candidates:
                if self._crc(c) == want:
                    return c
            return None
        best, best_n = None, 0
        for c in candidates:
            n = candidates.count(c)
            if n > best_n:
                best, best_n = c, n
        return best if best_n >= 2 else None

    def _ledger_disagrees(self, lba: int, data) -> bool:
        """True iff the write-crc ledger has an entry for ``lba`` that
        does NOT match ``data`` (the resyncer's pre-rewrite recheck: a
        foreground write that landed mid-repair owns the block)."""
        want = self._crcs.get(lba)
        return want is not None and self._crc(data) != want

    def write(self, lba: int, data, tenant: str | None = None) -> int:
        """One-block write: atomic per shard BTT, no journaling needed."""
        data = _unwrap(data)
        ticket = self._admit(tenant, self.block_size,
                             shard=self._map(lba, 0)[0])
        try:
            self._write_block(lba, data)
            return 0
        finally:
            self._release(ticket)

    def write_multi(self, lba: int, blocks, tenant: str | None = None) -> int:
        """Multi-block logical write with WHOLE-OBJECT all-or-nothing
        crash semantics: the write is journaled as one chained
        transaction (``journal_span`` blocks per link, tail header as the
        single commit point), so a crash anywhere surfaces either the
        complete new object or the complete old one — never a torn mix.
        Bounded by the journal ring (``journal.max_chain_blocks()``).

        Chains ride the batched log pipeline: concurrent callers coalesce
        behind a :class:`~repro.volume.journal.LogBatcher` leader into
        one slot-shard journal pass (``log_window`` gathers followers).
        The token bucket still caps each caller's rate up front, and the
        chain occupies a WFQ in-flight slot (``op='log'``) so chained
        writes stay ``max_inflight``-bounded and SFQ-ordered against the
        tenant's accumulated virtual time — but the admit itself prices
        ~nothing (one clamped byte): the actual bytes are charged once
        per BATCH to the constituent tenants at flush
        (``WFQGate.charge_batch``), so a small-write-heavy tenant no
        longer pays a full gate-pricing pass per ``log()``."""
        blocks = [_unwrap(b) for b in blocks]
        if len(blocks) == 1:
            ticket = self._admit(tenant, self.block_size)
            try:
                self._write_block(lba, blocks[0])
                return 0
            finally:
                self._release(ticket)
        if tenant is not None:
            bucket = self._buckets.get(tenant)
            if bucket is not None:
                bucket.acquire(self.block_size * len(blocks))
        ticket = None
        if tenant is not None and self._gate is not None:
            ticket = self._gate.admit(tenant, 0, op="log")
        try:
            self._write_tx(lba, blocks, tenant)
            return 0
        finally:
            self._release(ticket)

    def _write_tx(self, lba: int, blocks, tenant: str | None = None) -> None:
        self._log_batcher.submit(lba, blocks, tenant)

    def _flush_log_batch(self, entries) -> None:
        """LogBatcher flush: ONE ``_txlock`` acquisition journals every
        entry of the batch in shared slot-shard passes and applies the
        in-place writes group by group (``apply_cb``): a member's tails
        land (phase 3) before its in-place writes, so recovery rolls it
        forward whole if anything tears — and every member is applied
        before a later sub-group can reuse its journal slots or mark it
        checkpointed (the multi-group ring-wrap hazard)."""
        with self._txlock:
            def apply_entry(k: int, txids: list[int]) -> None:
                e = entries[k]
                e.txids = txids
                for i, blk in enumerate(e.blocks):
                    self._write_block(e.lba + i, blk)

            txid_lists = self.journal.log_batch(
                [(e.lba, e.blocks) for e in entries],
                checkpoint_cb=self._checkpoint_locked,
                apply_cb=apply_entry)
            n_links = 0
            per_tenant: dict[str, int] = {}
            for e, txids in zip(entries, txid_lists):
                n_links += len(txids)
                if e.tenant is not None:
                    per_tenant[e.tenant] = (per_tenant.get(e.tenant, 0)
                                            + e.nbytes)
            self.metrics.bump("chain_txs", n_links)
            self.metrics.bump("log_batches")
            self.metrics.bump("log_batch_links", n_links)
            if len(entries) > 1:
                self.metrics.bump("log_batch_coalesced", len(entries) - 1)
            # tier-aware WFQ: the whole batch's log traffic is charged to
            # its constituent tenants in one gate pass
            if self._gate is not None and per_tenant:
                for t, cost in self._gate.charge_batch(per_tenant,
                                                       op="log").items():
                    self.metrics.bump(f"wfq_vbytes::{t}", int(cost))

    def _shard_read(self, shard: int, local: int,
                    out: np.ndarray | None = None):
        """(data, source) from one shard: 'transit' | 'tier' | 'backend'."""
        impl = self.shards[shard].impl
        t0 = time.perf_counter_ns()
        if hasattr(impl, "read_ex"):
            res = impl.read_ex(local, out=out)
        else:
            res = impl.read(local, out=out), "backend"
        self.metrics.observe(f"svc::shard{shard}",
                             time.perf_counter_ns() - t0)
        return res

    def _debit_read(self, tenant: str | None, source: str,
                    pre_tier: str | None = None) -> None:
        """Tier-aware QoS accounting: a DRAM-served read (transit or
        tier hit) is charged a fraction of the PMem price, so a tier-hot
        tenant is not throttled like a PMem-bound one.  Both disciplines
        settle post-service: the token bucket via ``charge`` debt, the
        WFQ gate via ``WFQGate.charge`` for the remainder a read that
        served WORSE than its probed admission tag (``pre_tier``) turned
        out to owe — one-sided, so a probe raced by a fill keeps its
        conservative price."""
        if tenant is None:
            return
        cost = self.admission.read_charge(self.block_size, source)
        self.read_debits[tenant] = self.read_debits.get(tenant, 0) + cost
        if self._gate is not None:
            pre = self.admission.op_charge(self.block_size, "read", pre_tier)
            if cost > pre:
                extra = self._gate.charge(tenant, cost - pre, op="read",
                                          tier="backend")
                self.metrics.bump(f"wfq_vbytes::{tenant}", int(extra))
        bucket = self._buckets.get(tenant)
        if bucket is None or cost <= 0:
            return
        if source == "backend":
            bucket.acquire(cost)       # PMem reads are rate-enforced
        else:
            bucket.charge(cost)        # DRAM hits never sleep: debt only

    def _probe_read_tier(self, shard: int, local: int) -> str | None:
        """Cheap non-mutating guess of a read's serving tier ('transit'
        | 'tier' | None) so WFQ admission can price it before the stack
        is walked."""
        impl = self.shards[shard].impl
        probe = getattr(impl, "probe", None)
        return probe(local) if probe is not None else None

    def read(self, lba: int, out: np.ndarray | None = None,
             tenant: str | None = None, replica: int = 0) -> np.ndarray:
        """Layered read: tier -> primary shard (transit cache -> BTT) ->
        replica (degraded).  The tier probe happens inside the shard's
        cache; this level verifies the result and falls back.  Tenant
        reads pass the WFQ gate tagged ``op='read'`` with the probed
        tier — ``tier_hit_cost_frac`` price when the probe found the
        block DRAM-resident, full PMem price otherwise (ROADMAP: gate
        tags no longer charge reads nothing).  ``replica=`` serves the
        read from that copy instead of the primary (the hedge path's
        backup leg); verification and degraded fallback are unchanged."""
        replica = replica % self.cfg.replicas if replica else 0
        shard, local = self._map(lba, replica)
        ticket = None
        pre_tier = None
        if tenant is not None and self._gate is not None:
            pre_tier = self._probe_read_tier(shard, local)
            ticket = self._admit(tenant, self.block_size, op="read",
                                 tier=pre_tier, shard=shard)
        try:
            return self._read_verified(lba, shard, local, out, tenant,
                                       pre_tier)
        finally:
            self._release(ticket)

    def _read_verified(self, lba: int, shard: int, local: int,
                       out: np.ndarray | None, tenant: str | None,
                       pre_tier: str | None = None):
        data, source = self._shard_read(shard, local, out=out)
        if not self.cfg.verify_reads:
            self._debit_read(tenant, source, pre_tier)
            return data
        want = self._crcs.get(lba)
        if want is None or self._crc(data) == want:
            self._debit_read(tenant, source, pre_tier)
            return data
        # a read racing a write can see the new ledger entry before the
        # staged block is visible — one primary re-read (through the
        # transit cache, which serves staged data) settles that race
        # without a replica detour
        data, source = self._shard_read(shard, local, out=out)
        want = self._crcs.get(lba)
        if want is None or self._crc(data) == want:
            self._debit_read(tenant, source, pre_tier)
            return data
        self.metrics.bump("verify_failures")
        self._debit_read(tenant, "backend", pre_tier)  # detours: PMem price
        last_alt = None
        for r in range(1, self.cfg.replicas):
            s2, l2 = self._map(lba, r)
            alt = self.shards[s2].read(l2)
            if self._crc(alt) != want:
                last_alt = alt
                continue
            # degraded read: replica copy verified — serve it, read-repair
            # the tier under the PRIMARY key (later reads hit good data
            # even before the background resync lands), queue the repair
            self.metrics.bump("degraded_reads")
            tier = self.read_tier
            if tier is not None:
                tier.invalidate((shard, local))
                tier.insert((shard, local), alt)
            if self.resyncer is not None:
                self.resyncer.request(lba)
            if out is not None:
                out[:] = alt
                return out
            return alt
        if last_alt is not None and bytes(last_alt) == bytes(data):
            # every copy agrees, only the ledger disagrees: a mid-flight
            # write (or stale ledger), not corruption — serve it quietly
            self.metrics.bump("verify_races")
            return data
        # no copy matches the ledger: surface the primary (scrub/resync
        # will keep flagging it) rather than invent data
        self.metrics.bump("unrecoverable_reads")
        return data

    # ----------------------------------------------------------- tail latency
    def refresh_tail_state(self) -> dict:
        """One tail-state pass: recompute the :class:`ShardScorer`'s
        healthy/limping/dead verdicts from the per-shard service-time
        digests and push the penalties into every steering hook — WFQ
        ``op_charge`` pricing (limping shards cost more virtual time)
        and the shared eviction pool's drain order (limping backlogs
        drain last).  Returns the per-shard state map.  Called from
        ``scrub()``; operators and benches may call it on their own
        cadence."""
        states = self.scorer.states()
        pens: dict[int, float] = {}
        for member in states:
            if member.startswith("shard"):
                try:
                    idx = int(member[5:])
                except ValueError:
                    continue
                pens[idx] = self.scorer.penalty(member)
        self.admission.set_shard_penalties(pens)
        if self.pool is not None:
            limp = [self.shards[i].impl for i, p in pens.items()
                    if p > 1.0 and i < len(self.shards)
                    and hasattr(self.shards[i].impl, "_evict_slot")]
            self.pool.set_limping(
                limp,
                on_steer=lambda: self.metrics.bump("steered_evictions"))
        return states

    def hedge_delay(self) -> float:
        """Seconds to wait on the primary before firing the hedge leg:
        the configured ``hedge_delay_us``, or (when 0 = auto) the
        scorer's healthy-cohort median p99 — 1 ms until the digests
        warm up."""
        us = self.cfg.hedge_delay_us
        if us <= 0:
            us = self.scorer.hedge_delay_us(default_us=1000.0)
        return max(us, 1.0) / 1e6

    def hedged_read(self, lba: int, out=None, tenant: str | None = None,
                    delay_s: float | None = None):
        """Tail-tolerant replicated read: submit the primary, wait one
        hedge delay, and if it has not completed fire the SAME read
        against the replica — first completion wins, the loser is
        cancelled through the engine's per-ticket cancel path (releasing
        any pinned registered buffers).  Unreplicated volumes fall back
        to a plain :meth:`read`.  Counters (``hedges_fired`` ==
        ``hedges_won`` + ``hedges_cancelled``) surface in
        ``Metrics.tail_path()``."""
        if self.cfg.replicas < 2:
            return self.read(lba, out=out, tenant=tenant)
        delay = self.hedge_delay() if delay_s is None else delay_s
        return _hedged_read(self, lba, delay_s=delay, out=out,
                            tenant=tenant)

    # ----------------------------------------------------- control plane
    def attach_autotuner(self, controller: Controller | None = None) \
            -> Controller:
        """Attach a self-tuning :class:`~repro.volume.autotune.Controller`
        (a stock one when None).  The controller is seeded from the LIVE
        config — it tunes from where the operator left the knobs, and
        every subsequent :meth:`autotune_step` observes the metrics
        layer and applies bounded, clamped knob moves online.  Without
        an attached controller ``autotune_step`` is a no-op and every
        knob stays frozen at its configured value."""
        if controller is None:
            from .autotune import make_default_controller
            controller = make_default_controller()
        seed = {"commit_window_us": self.cfg.commit_window * 1e6,
                "log_window_us": self.cfg.log_window * 1e6,
                "bypass_watermark": self.cfg.bypass_watermark,
                "scan_threshold": float(self.cfg.scan_threshold)}
        if self.cfg.hedge_delay_us > 0:     # 0 = scorer auto: keep the
            seed["hedge_delay_us"] = self.cfg.hedge_delay_us  # default
        controller.bind(seed)
        self.autotuner = controller
        self._autotune_prev = None
        return controller

    def _autotune_counters(self) -> dict:
        """Cumulative counter snapshot the signal window diffs against."""
        out: dict[str, float] = {}
        for k in ("read_hits", "read_misses", "read_tier_hits",
                  "tier_fill_bypassed", "bypass_writes", "bg_evictions"):
            out[k] = 0
        for d in self.shards:
            snap = d.metrics.snapshot()["count"]
            for k in out:
                out[k] += snap.get(k, 0)
        vol = self.metrics.snapshot()["count"]
        for k in ("group_commits", "group_commit_waiters", "log_batches",
                  "log_batch_coalesced"):
            out[k] = vol.get(k, 0)
        return out

    def autotune_signals(self) -> dict:
        """One signal window for the controller: per-op rates computed
        from the metrics layer's counter DELTAS since the previous call,
        plus the instantaneous occupancy/tail/zero-copy state.  Also the
        operator-facing view of what the control plane sees."""
        cur = self._autotune_counters()
        prev = self._autotune_prev or {k: 0 for k in cur}
        self._autotune_prev = cur
        d = {k: cur[k] - prev.get(k, 0) for k in cur}
        reads = d["read_hits"] + d["read_misses"] + d["read_tier_hits"]
        writes = d["bypass_writes"] + d["bg_evictions"]
        fsyncs = d["group_commits"] + d["group_commit_waiters"]
        logs = d["log_batches"] + d["log_batch_coalesced"]
        ops = max(1, reads + writes + logs)
        sig = {
            "ops": reads + writes + logs,
            "fsync_rate": fsyncs / ops,
            "coalesce_rate": (d["group_commit_waiters"] / fsyncs
                              if fsyncs else 0.0),
            "log_rate": logs / ops,
            "log_coalesce_rate": (d["log_batch_coalesced"] / logs
                                  if logs else 0.0),
            "stall_rate": 0.0,      # caiti shards bypass instead of stall
            "bypass_rate": (d["bypass_writes"] / writes if writes else 0.0),
            "staged_frac": (self._staged_slots() / self._total_cache_slots
                            if self._total_cache_slots else 0.0),
            "read_rate": reads / ops,
            "tier_hit_rate": ((d["read_hits"] + d["read_tier_hits"]) / reads
                              if reads else 0.0),
            "scan_denial_rate": (d["tier_fill_bypassed"] / reads
                                 if reads else 0.0),
        }
        states = self.scorer.states()
        sig["limping"] = any(s != "healthy" for s in states.values())
        sig["healthy_p99_us"] = self.scorer.hedge_delay_us(default_us=0.0)
        shard_digest = self.metrics.digest()
        p99s = [row["p99_us"] for k, row in shard_digest.items()
                if k.startswith("shard")]
        if p99s:
            sig["p99_us"] = max(p99s)
        if self._aio is not None:
            sig["pin_rate"] = self.metrics.zerocopy_path()["pin_rate"]
        debts = self.metrics.per_tenant("wfq_vbytes")
        total_debt = sum(debts.values())
        if total_debt:
            sig["wfq_debt_share"] = max(debts.values()) / total_debt
        return sig

    def autotune_step(self) -> dict:
        """One control tick: collect the signal window, let the attached
        controller vote, and apply whatever knobs it moved — group/log
        windows, the bypass watermark (converted to aggregate slots for
        the admission layer), the scan threshold, and the hedge delay.
        Returns the applied moves (``{}`` with no controller attached —
        the frozen-knob passthrough)."""
        if self.autotuner is None:
            return {}
        changes = self.autotuner.observe(self.autotune_signals())
        if changes:
            self._apply_knobs(changes)
            self.metrics.bump("autotune_moves", len(changes))
            for name in changes:
                self.metrics.bump(f"autotune_moves::{name}")
        self.metrics.bump("autotune_ticks")
        return changes

    def _apply_knobs(self, changes: dict) -> None:
        cfg = self.cfg
        if "commit_window_us" in changes:
            cfg.commit_window = changes["commit_window_us"] / 1e6
            self._committer.window = cfg.commit_window
        if "log_window_us" in changes:
            cfg.log_window = changes["log_window_us"] / 1e6
            self._log_batcher.window = cfg.log_window
        retune: dict = {}
        if "bypass_watermark" in changes:
            cfg.bypass_watermark = changes["bypass_watermark"]
            if self._total_cache_slots:
                retune["watermark_slots"] = max(1, int(
                    cfg.bypass_watermark * self._total_cache_slots))
        if "scan_threshold" in changes:
            cfg.scan_threshold = int(changes["scan_threshold"])
            retune["scan_threshold"] = cfg.scan_threshold
        if retune:
            self.admission.retune(**retune)
        if "hedge_delay_us" in changes:
            cfg.hedge_delay_us = changes["hedge_delay_us"]

    # --------------------------------------------------------- async frontend
    def aio_engine(self, *, n_workers: int | None = None,
                   max_inflight_per_tenant: int | None = None) \
            -> AsyncIOEngine:
        """The volume's :class:`~repro.volume.aio.AsyncIOEngine`,
        created on first use.  ``n_workers=0`` selects deterministic
        inline mode (ops execute during ``poll``/``wait`` — the crash
        harness's replayable schedule).  The kwargs configure the FIRST
        call only; an explicit kwarg that contradicts the live engine
        asserts instead of silently handing back the wrong mode (a
        crash harness must never silently get a threaded engine)."""
        if self._aio is None:
            self._aio = AsyncIOEngine(
                self,
                n_workers=self.cfg.aio_workers if n_workers is None
                else n_workers,
                max_inflight_per_tenant=self.cfg.max_inflight
                if max_inflight_per_tenant is None
                else max_inflight_per_tenant)
        else:
            assert n_workers is None \
                or n_workers == len(self._aio._workers), \
                f"aio engine already running {len(self._aio._workers)} " \
                f"workers; cannot switch to {n_workers}"
            assert max_inflight_per_tenant is None \
                or max_inflight_per_tenant \
                == self._aio.max_inflight_per_tenant, \
                "aio engine already running a different in-flight bound"
        return self._aio

    def submit(self, op: str, lba: int = 0, data=None, blocks=None,
               tenant: str | None = None, block: bool = False,
               link_to=None, out=None, replica: int = 0):
        """Asynchronous submission: queue ``op`` ('write' | 'write_multi'
        | 'read' | 'fsync' | 'flush') and return its ticket immediately.
        Completions surface on :meth:`poll`; per-op failures (injected
        device errors, journal-ring overflow, a tenant over its
        in-flight bound) fail the TICKET, never the stack.
        ``block=True`` waits out the in-flight window instead of failing
        the ticket (blocking backpressure for batch producers).
        ``link_to=`` chains the ticket behind a parent (IO_LINK: failed
        parent cancels the chain with ECANCELED); ``out=`` lands a read
        directly in the caller's (registered) array; ``replica=`` routes
        a read to that copy (the hedge path's backup leg)."""
        return self.aio_engine().submit(op, lba=lba, data=data,
                                        blocks=blocks, tenant=tenant,
                                        block=block, link_to=link_to,
                                        out=out, replica=replica)

    def try_submit(self, op: str, lba: int = 0, data=None, blocks=None,
                   tenant: str | None = None, link_to=None, out=None,
                   replica: int = 0):
        """Non-blocking window probe: None when the tenant is at its
        in-flight bound (not counted as a failure), a ticket otherwise."""
        return self.aio_engine().try_submit(op, lba=lba, data=data,
                                            blocks=blocks, tenant=tenant,
                                            link_to=link_to, out=out,
                                            replica=replica)

    def register_buffers(self, n_buffers: int,
                         buf_bytes: int | None = None):
        """Register a zero-copy buffer pool on the volume's async engine
        (``buf_bytes`` defaults to the block size).  Returns the
        :class:`~repro.volume.aio.BufferRegistry`."""
        return self.aio_engine().register_buffers(
            n_buffers, self.block_size if buf_bytes is None else buf_bytes)

    def poll(self, max_ops: int | None = None) -> list:
        """Drain the shared completion ring (empty when nothing was ever
        submitted)."""
        if self._aio is None:
            return []
        return self._aio.poll(max_ops)

    def wait(self, ticket, timeout: float | None = None):
        return self.aio_engine().wait(ticket, timeout=timeout)

    def max_atomic_write_blocks(self) -> int:
        """Largest ``write_multi`` the chained journal can commit
        atomically (ring bound: n_slots links of span blocks)."""
        return self.journal.max_chain_blocks()

    def flush(self) -> int:
        for d in self.shards:
            d.flush()
        return 0

    def fsync(self) -> int:
        """Group-committed durability point: concurrent callers coalesce
        behind one leader that drains every shard, persists the crc
        ledger, and checkpoints the journal in a single superblock pass
        (``commit_window`` gathers followers before committing)."""
        led = self._committer.sync()
        self.metrics.bump("group_commits" if led else "group_commit_waiters")
        return 0

    def _commit_group(self) -> None:
        with self._txlock:
            self._checkpoint_locked()

    def _checkpoint_locked(self, upto: int | None = None) -> None:
        for d in self.shards:
            d.fsync()
        upto = self.journal.last_txid() if upto is None else upto
        self.journal.mark_applied(upto)
        if self.cfg.persist_ledger:
            self._write_ledger()
        self._write_superblocks()

    # ------------------------------------------------------------- metadata
    def _write_ledger(self) -> None:
        """Persist the write-crc ledger into the reserved meta region
        (blocks striped round-robin over the shards), so a reopened
        volume verifies reads before its first overwrite.  The entry
        count + payload crc land in the superblock (written after this,
        so a torn ledger write is detected and ignored at load)."""
        items = list(self._crcs.items())
        bs = self.block_size
        cap = self.cfg.ledger_blocks_per_shard * self.cfg.n_shards \
            * (bs // _LEDGER_ENTRY_SIZE)
        if len(items) > cap:               # summary: persist what fits
            items = items[:cap]
        payload = b"".join(struct.pack(_LEDGER_ENTRY, lba, crc)
                           for lba, crc in items)
        self._ledger_count = len(items)
        self._ledger_crc = zlib.crc32(payload)
        per_block = (bs // _LEDGER_ENTRY_SIZE) * _LEDGER_ENTRY_SIZE
        n_blocks = -(-len(payload) // per_block) if payload else 0
        for j in range(n_blocks):
            chunk = payload[j * per_block:(j + 1) * per_block]
            chunk = chunk + b"\x00" * (bs - len(chunk))
            shard = j % self.cfg.n_shards
            local = 1 + j // self.cfg.n_shards
            assert local <= self.cfg.ledger_blocks_per_shard
            self.shards[shard].impl.btt.write(
                local, np.frombuffer(chunk, np.uint8))
        for d in self.shards:
            d.impl.btt.flush()

    def _load_ledger(self, count: int, crc: int) -> bool:
        """Rebuild the crc ledger from the meta region; False when the
        stored summary is absent or fails its own crc (torn write)."""
        if count <= 0:
            return False
        bs = self.block_size
        per_block = (bs // _LEDGER_ENTRY_SIZE) * _LEDGER_ENTRY_SIZE
        nbytes = count * _LEDGER_ENTRY_SIZE
        n_blocks = -(-nbytes // per_block)
        if n_blocks > self.cfg.ledger_blocks_per_shard * self.cfg.n_shards:
            return False
        chunks = []
        for j in range(n_blocks):
            shard = j % self.cfg.n_shards
            local = 1 + j // self.cfg.n_shards
            chunks.append(bytes(self.shards[shard].impl.btt.read(local))
                          [:per_block])
        payload = b"".join(chunks)[:nbytes]
        if zlib.crc32(payload) != crc:
            return False
        for off in range(0, nbytes, _LEDGER_ENTRY_SIZE):
            lba, c = struct.unpack_from(_LEDGER_ENTRY, payload, off)
            self._crcs[lba] = c
        self._ledger_count, self._ledger_crc = count, crc
        return True

    def _write_superblocks(self) -> None:
        for i, d in enumerate(self.shards):
            sb = self.cfg.to_sb(i, self.uuid,
                                applied_txid=self.journal.applied_txid)
            if self.cfg.persist_ledger:
                sb["ledger_count"] = self._ledger_count
                sb["ledger_crc"] = self._ledger_crc
            raw = json.dumps(sb).encode()
            raw = raw + b"\x00" * (self.block_size - len(raw))
            d.impl.btt.write(0, np.frombuffer(raw, np.uint8))
            d.impl.btt.flush()

    @staticmethod
    def read_superblock(dev) -> dict | None:
        raw = bytes(dev.impl.btt.read(0)).rstrip(b"\x00")
        if not raw:
            return None
        try:
            sb = json.loads(raw.decode())
        except (UnicodeDecodeError, json.JSONDecodeError):
            return None
        return sb if sb.get("magic") == _SB_MAGIC else None

    # ------------------------------------------------------------- recovery
    def recover(self) -> dict:
        """Replay the volume journal (per-shard Flog replay already happened
        when the shard devices were opened)."""
        records = self.journal.scan()
        for txid, lba, blocks in records:
            for i, blk in enumerate(blocks):
                if self.cfg.verify_reads:
                    self._crcs[lba + i] = zlib.crc32(blk)
                for r in range(self.cfg.replicas):
                    shard, local = self._map(lba + i, r)
                    self.shards[shard].impl.btt.write(
                        local, np.frombuffer(blk, np.uint8))
        last = max([t for t, _, _ in records],
                   default=self.journal.applied_txid)
        self.journal.next_txid = max(self.journal.next_txid, last + 1)
        self.journal.mark_applied(last)
        for d in self.shards:
            d.impl.btt.flush()
        if self.cfg.persist_ledger:
            self._write_ledger()       # replayed records refreshed crcs
        self._write_superblocks()
        stats = {
            "replayed_txs": len(records),
            "shards": [getattr(d.impl.btt, "recovery_stats", {})
                       for d in self.shards],
        }
        self.recovery_stats = stats
        return stats

    def scrub_replicas_detail(self, sample_every: int = 1) \
            -> list[tuple[int, int, int, int]]:
        """Compare every copy of every sampled block below the caches and
        return the DIVERGENT copies as (lba, replica, shard, local_lba)
        tuples — exactly what the resyncer needs to target repairs.  The
        bad copy is whichever disagrees with the trusted image (write-crc
        ledger, else majority/primary — see ``_pick_good_copy``)."""
        if self.cfg.replicas < 2:
            return []
        out = []
        for lba in range(0, self.n_lbas, sample_every):
            copies = []
            for r in range(self.cfg.replicas):
                shard, local = self._map(lba, r)
                copies.append((r, shard, local,
                               bytes(self.shards[shard].impl.btt.read(local))))
            datas = [c[3] for c in copies]
            if all(d == datas[0] for d in datas[1:]):
                continue
            good = self._pick_good_copy(lba, datas)
            if good is None:
                good = datas[0]     # nothing verifiable: primary wins
            out.extend((lba, r, shard, local)
                       for r, shard, local, d in copies if d != good)
        return out

    def scrub_replicas(self, sample_every: int = 1) -> int:
        """Count-compatible wrapper over :meth:`scrub_replicas_detail`."""
        return len(self.scrub_replicas_detail(sample_every))

    def scrub(self, sample_every: int = 1) -> dict:
        """Operator-facing scrub report: replica divergence plus the
        per-shard service-time EWMAs (``Metrics.per_node``) — the
        fail-slow signal a limping DIMM set shows long before it fails
        outright (one shard's EWMA drifting off its peers)."""
        detail = self.scrub_replicas_detail(sample_every)
        out = {"divergent": len(detail),
               "divergent_detail": detail,
               "per_shard_svc": self.metrics.per_node()}
        # tail-latency layer: refresh the scorer (installing steering
        # penalties as a side effect) and surface the verdicts + the
        # hedge counter balance
        states = self.refresh_tail_state()
        out["tail"] = {"states": states,
                       "shards": self.scorer.table(),
                       "hedge_delay_us": round(self.hedge_delay() * 1e6, 3),
                       **self.metrics.tail_path()}
        if self._aio is not None:
            s = self._aio.stats()
            out["zerocopy"] = {k: s[k] for k in (
                "copies_avoided", "bytes_pinned", "staging_copies",
                "staging_copy_bytes", "links_submitted", "link_cancelled",
                "link_depth_max")}
            if "registry" in s:
                out["zerocopy"]["registry"] = s["registry"]
        if self.autotuner is not None:
            out["autotune"] = self.autotuner.stats()
        return out

    # ---------------------------------------------------------------- stats
    def occupancy(self) -> float:
        if not self._caches:
            return 0.0
        return float(np.mean([d.occupancy() for d in self.shards]))

    def metrics_snapshot(self) -> dict:
        out = {"bypass_writes": 0, "bg_evictions": 0, "read_hits": 0,
               "read_misses": 0, "read_tier_hits": 0, "read_tier_fills": 0,
               "tier_fill_bypassed": 0}
        for d in self.shards:
            snap = d.metrics.snapshot()["count"]
            for k in out:
                out[k] += snap.get(k, 0)
        vol = self.metrics.snapshot()["count"]
        for k in ("verify_failures", "degraded_reads", "verify_races",
                  "unrecoverable_reads", "resync_repairs", "chain_txs",
                  "group_commits", "group_commit_waiters", "log_batches",
                  "log_batch_links", "log_batch_coalesced"):
            out[k] = vol.get(k, 0)
        out["journal_txs"] = self.journal.last_txid()
        out["applied_txid"] = self.journal.applied_txid
        out["chains_logged"] = self.journal.chains_logged
        out["group_commit"] = self._committer.stats()
        out["log_batcher"] = self._log_batcher.stats()
        if self._aio is not None:
            out["aio"] = self._aio.stats()
        out["admission"] = self.admission.stats()
        out["per_shard_svc"] = self.metrics.per_node()
        out["tail"] = {"states": self.scorer.states(),
                       **self.metrics.tail_path()}
        out["wfq_vbytes"] = self.metrics.per_tenant("wfq_vbytes")
        if self._gate is not None:
            out["wfq"] = self._gate.stats()
        if self.read_tier is not None:
            out["read_tier"] = self.read_tier.stats()
        if self.autotuner is not None:
            out["autotune"] = {**self.autotuner.stats(),
                               **self.metrics.autotune_path()}
        return out

    def close(self) -> None:
        if self._aio is not None:
            self._aio.close()        # drain in-flight tickets first
        self.fsync()
        if self.resyncer is not None:
            self.resyncer.close()
        for d in self.shards:
            d.close()
        if self.pool is not None:
            self.pool.close()


def make_volume(policy: str = "caiti", *, n_lbas: int, n_shards: int = 4,
                stripe_blocks: int = 64, replicas: int = 1,
                block_size: int = 4096, cache_bytes: int = 64 << 20,
                shared_workers: int = 4, bypass_watermark: float = 0.9,
                journal_slots: int = 64, journal_span: int = 8,
                backend: str = "ram", path: str | None = None,
                latency: LatencyModel | None = None,
                tenants: list[TenantSpec] | None = None,
                nfree: int | None = None,
                max_inflight: int = 16, read_tier_bytes: int = 0,
                n_sockets: int = 1,
                verify_reads: bool | None = None,
                commit_window: float = 0.0,
                log_window: float = 0.0,
                scan_threshold: int = 64,
                tier_hit_cost_frac: float = 0.125,
                persist_ledger: bool = True,
                aio_workers: int = 2,
                hedge_delay_us: float = 0.0,
                autotune: Controller | bool | None = None) -> StripedVolume:
    """Build (or reopen + recover) a striped volume.

    ``path`` is a prefix for file-backed shards (``{path}.shard{i}``); a
    prefix whose shard files already carry volume superblocks is RECOVERED
    (per-shard Flog replay + volume journal replay), not re-formatted.

    ``read_tier_bytes > 0`` puts one shared clean DRAM read tier in front
    of all shards (caiti policies).  ``n_sockets > 1`` splits the shared
    eviction pool into per-socket worker banks and pins shard *i* to
    socket ``i % n_sockets`` (the socket owning its PMem DIMM set).

    NOTE: the crc-ledger meta region (``persist_ledger``, on by default
    for replicated volumes) changes the on-media geometry.  A replicated
    volume formatted BEFORE the ledger existed must be reopened with
    ``persist_ledger=False`` — the geometry check rejects the mismatch
    rather than silently misplacing the journal/data regions.
    """
    cfg = VolumeConfig(n_lbas=n_lbas, n_shards=n_shards,
                       stripe_blocks=stripe_blocks, replicas=replicas,
                       policy=policy, block_size=block_size,
                       cache_bytes=cache_bytes, shared_workers=shared_workers,
                       bypass_watermark=bypass_watermark,
                       journal_slots=journal_slots, journal_span=journal_span,
                       max_inflight=max_inflight,
                       read_tier_bytes=read_tier_bytes, n_sockets=n_sockets,
                       verify_reads=verify_reads,
                       commit_window=commit_window,
                       log_window=log_window,
                       scan_threshold=scan_threshold,
                       tier_hit_cost_frac=tier_hit_cost_frac,
                       persist_ledger=persist_ledger,
                       aio_workers=aio_workers,
                       hedge_delay_us=hedge_delay_us)
    paths = [None] * n_shards
    if backend == "file":
        assert path is not None, "file backend needs a path prefix"
        paths = [f"{path}.shard{i}" for i in range(n_shards)]
    pool = SharedEvictionPool(shared_workers, name="vol",
                              n_sockets=n_sockets) \
        if policy.startswith("caiti") else None
    tier = ReadTier(read_tier_bytes, block_size) \
        if read_tier_bytes > 0 and policy.startswith("caiti") else None
    shards = []
    per_shard_cache = max(block_size, cache_bytes // n_shards)
    for i in range(n_shards):
        shards.append(make_device(
            policy, n_lbas=cfg.shard_n_lbas, block_size=block_size,
            cache_bytes=per_shard_cache, backend=backend, path=paths[i],
            latency=latency, nfree=nfree, evict_pool=pool,
            read_tier=tier, tier_ns=i))
        if pool is not None:
            pool.assign_socket(shards[-1].impl, i % max(1, n_sockets))

    sbs = [StripedVolume.read_superblock(d) for d in shards]
    existing = all(sb is not None for sb in sbs)
    # a PARTIAL member set is a damaged volume, never a fresh one:
    # re-formatting would silently orphan the surviving shards' data
    assert existing or not any(sb is not None for sb in sbs), \
        "volume member missing/damaged: shards without superblock " \
        f"{[i for i, sb in enumerate(sbs) if sb is None]}"
    if existing:
        # geometry + membership must agree before we trust the stripes
        uuids = {sb["uuid"] for sb in sbs}
        assert len(uuids) == 1, f"mixed volumes: {uuids}"
        for i, sb in enumerate(sbs):
            assert sb["shard"] == i, f"shard {i} holds member {sb['shard']}"
            want = cfg.to_sb(i, sb["uuid"])
            mismatch = [k for k in ("n_shards", "n_lbas", "stripe_blocks",
                                    "replicas", "journal_slots",
                                    "journal_span", "ledger_blocks")
                        if sb.get(k, 0) != want[k]]
            assert not mismatch, \
                f"geometry mismatch on shard {i}: {mismatch}"
        vol = StripedVolume(shards, cfg, uuid=sbs[0]["uuid"], evict_pool=pool,
                            read_tier=tier)
        vol.journal.applied_txid = max(sb.get("applied_txid", 0)
                                       for sb in sbs)
        vol.journal.next_txid = vol.journal.applied_txid + 1
        if cfg.persist_ledger:
            # newest checkpoint wins: the shard sb with the highest
            # applied mark carries the matching ledger summary
            newest = max(sbs, key=lambda s: s.get("applied_txid", 0))
            vol._load_ledger(newest.get("ledger_count", 0),
                             newest.get("ledger_crc", 0))
        vol.recover()
    else:
        uuid = os.urandom(8).hex()
        vol = StripedVolume(shards, cfg, uuid=uuid, evict_pool=pool,
                            read_tier=tier)
        vol._write_superblocks()
    for t in (tenants or []):
        vol.add_tenant(t.name, weight=t.weight, rate_mbps=t.rate_mbps,
                       burst_bytes=t.burst_bytes)
    # self-tuning control plane: autotune=True attaches the stock
    # controller, a Controller instance attaches that one; None/False
    # leaves every knob frozen at its configured value
    if autotune:
        vol.attach_autotuner(None if autotune is True else autotune)
    return vol
