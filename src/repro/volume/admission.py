"""Unified admission layer for the write/read pipeline.

Before this module the stack's admission decisions were scattered: the
volume installed a ``bypass_hook`` closure on every shard cache (global
conditional-bypass watermark), the read tier filled on every miss
unconditionally, and QoS debiting lived inline in ``StripedVolume`` and
priced every read like a PMem round trip.  :class:`AdmissionPolicy` pulls
all three behind one object that ``CaitiCache``, ``StripedVolume``,
``ReadTier`` and ``TransitBuffer`` consult:

  * **write bypass** — ``should_bypass_write()`` is the volume's
    aggregate-staged watermark (the paper's conditional bypass extended
    volume-wide): when staged slots across all shards cross the
    watermark, a write miss transits straight to BTT even though its own
    shard still has free slots;
  * **read-tier fill admission** — ``admit_tier_fill(ns, lba)`` denies
    fills to *sequential scans*: a reader streaming a long contiguous
    range (backup, ``BlockStore.get`` of a giant object, table scan)
    would flush the tier's hot set for blocks it will never touch again.
    The detector tracks up to ``max_streams`` concurrent per-namespace
    runs (Linux-readahead style: an access extending a previously seen
    ``lba+1`` expectation lengthens that run); once a run exceeds
    ``scan_threshold`` blocks, further fills from it are dropped.  The
    first ``scan_threshold`` blocks of any scan still fill — random and
    short-run readers are unaffected;
  * **tier-aware QoS pricing** — ``read_charge(nbytes, source)`` is the
    byte cost a tenant's token bucket is debited for a read.  A transit-
    cache or read-tier hit is a DRAM copy, not a PMem round trip, so it
    is charged ``tier_hit_cost_frac`` of its size (default 1/8); only
    backend reads pay full price.  A tier-hot tenant therefore is not
    throttled like a PMem-bound one (ROADMAP follow-on);
  * **limping-shard steering** — ``set_shard_penalties`` installs the
    :class:`~repro.core.metrics.ShardScorer`'s per-shard price
    multipliers (healthy 1x, limping/dead higher), and ``op_charge``
    applies them when the caller tags the op with its target ``shard=``:
    work headed for a fail-slow device costs MORE virtual time, so the
    WFQ gate naturally schedules around the limper instead of feeding
    the queue that is already 25x slow.

The object is deliberately dumb and lock-cheap: every hook is O(1) under
one small lock, safe to call from foreground read/write paths and from
pool workers.
"""
from __future__ import annotations

import threading
from collections import OrderedDict


class ScanDetector:
    """Sequential-run tracker, keyed by (namespace, expected-next-lba).

    ``observe(ns, lba)`` returns the length of the run this access
    extends (1 for a random access).  Up to ``max_streams`` interleaved
    streams are tracked per namespace so two concurrent scanners (or a
    scanner plus random readers) do not reset each other.
    """

    def __init__(self, max_streams: int = 8) -> None:
        self.max_streams = max_streams
        # ns -> OrderedDict{expected_next_lba -> run_len}
        self._streams: dict[object, OrderedDict] = {}

    def observe(self, ns, lba: int) -> int:
        streams = self._streams.setdefault(ns, OrderedDict())
        run = streams.pop(lba, 0) + 1
        # expectation-key collision: a one-shot access at (stream head
        # - 1) writes the SAME next-lba key an established run already
        # owns — keep the longer counter instead of clobbering it (and
        # pop first so the entry really moves to MRU, keeping the
        # just-inserted-survives eviction rule honest)
        run_kept = max(run, streams.pop(lba + 1, 0))
        streams[lba + 1] = run_kept
        while len(streams) > self.max_streams:
            # Two-class eviction.  Run-length-1 entries (noise and
            # not-yet-established streams) churn in a NURSERY of up to
            # half the table: while they fit, the victim is instead the
            # least recently extended entry overall — so one-shot noise
            # cannot push out an established run counter, stale counters
            # from finished scans age out, and a brand-new stream's
            # first expectation survives moderate noise long enough to
            # establish.  Only when run-1 entries overflow the nursery
            # does the coldest of THEM (never the one this access just
            # inserted) get dropped — a noise rate of half the table per
            # stream step is the documented starvation bound.
            nursery = max(1, self.max_streams // 2)
            newest = next(reversed(streams))
            run1 = [k for k, v in streams.items()
                    if v <= 1 and k != newest]
            if len(run1) >= nursery:
                streams.pop(run1[0])         # noise churns in the nursery
            else:
                streams.popitem(last=False)  # aging: least recently
        return run                           # extended goes first

    def current_run(self, ns, lba: int) -> int:
        """Run length of the stream that ``lba`` belongs to (after its
        observe), without mutating detector state."""
        streams = self._streams.get(ns)
        if not streams:
            return 1
        return streams.get(lba + 1, 1)


class AdmissionPolicy:
    """One policy object for the three scattered admission decisions.

    ``staged_slots_fn``/``watermark_slots`` — aggregate bypass watermark
    (the volume wires its shard caches' staged-slot sum in here).
    ``scan_threshold`` — run length above which tier fills are denied
    (0 disables scan detection: every fill admitted).
    ``tier_hit_cost_frac`` — QoS price of a DRAM-served read relative to
    a backend (PMem) read of the same size.
    """

    def __init__(self, *, staged_slots_fn=None, watermark_slots: int = 0,
                 scan_threshold: int = 64, max_streams: int = 8,
                 tier_hit_cost_frac: float = 0.125) -> None:
        assert 0.0 <= tier_hit_cost_frac <= 1.0
        self.staged_slots_fn = staged_slots_fn
        self.watermark_slots = watermark_slots
        self.scan_threshold = scan_threshold
        self.tier_hit_cost_frac = tier_hit_cost_frac
        self._detector = ScanDetector(max_streams=max_streams)
        self._lock = threading.Lock()
        self.scan_fill_denials = 0
        # limping-shard steering: shard -> price multiplier (>= 1.0),
        # refreshed from the ShardScorer by the volume's tail-state pass
        self._shard_penalty: dict[int, float] = {}
        self.steered_charges = 0

    # ----------------------------------------------------------- retuning
    def retune(self, *, watermark_slots: int | None = None,
               scan_threshold: int | None = None,
               tier_hit_cost_frac: float | None = None) -> dict:
        """Online knob update from the control plane (``autotune``):
        each provided value replaces the live one under the policy lock,
        so foreground readers never see a torn update.  Returns the
        post-update values.  Callers (the volume's ``autotune_step``)
        are responsible for clamping — this layer only refuses
        nonsense."""
        with self._lock:
            if watermark_slots is not None:
                self.watermark_slots = max(0, int(watermark_slots))
            if scan_threshold is not None:
                self.scan_threshold = max(0, int(scan_threshold))
            if tier_hit_cost_frac is not None:
                assert 0.0 <= tier_hit_cost_frac <= 1.0
                self.tier_hit_cost_frac = tier_hit_cost_frac
            return {"watermark_slots": self.watermark_slots,
                    "scan_threshold": self.scan_threshold,
                    "tier_hit_cost_frac": self.tier_hit_cost_frac}

    # -------------------------------------------------- fail-slow steering
    def set_shard_penalties(self, penalties: dict[int, float]) -> None:
        """Install the scorer's per-shard price multipliers (1.0 =
        healthy; entries at 1.0 are dropped so the hot path dict stays
        tiny)."""
        with self._lock:
            self._shard_penalty = {s: p for s, p in penalties.items()
                                   if p > 1.0}

    def shard_penalty(self, shard) -> float:
        with self._lock:
            return self._shard_penalty.get(shard, 1.0)

    # ------------------------------------------------------- write bypass
    def should_bypass_write(self) -> bool:
        """Volume-wide conditional bypass: aggregate staged slots crossed
        the watermark — one PMem write beats evict-then-fill."""
        if self.staged_slots_fn is None or self.watermark_slots <= 0:
            return False
        return self.staged_slots_fn() >= self.watermark_slots

    # --------------------------------------------------- read observation
    def observe_read(self, ns, lba: int) -> int:
        """Feed one read access to the scan detector; returns the run
        length this access extends.  Call once per read, before the fill
        decision."""
        if self.scan_threshold <= 0:
            return 1
        with self._lock:
            return self._detector.observe(ns, lba)

    def observe_and_admit(self, ns, lba: int) -> bool:
        """One-lock fast path for the cache read miss: feed the detector
        AND decide the fill in a single acquisition (the observe/admit
        split costs two lock round trips per miss on a shared policy)."""
        if self.scan_threshold <= 0:
            return True
        with self._lock:
            if self._detector.observe(ns, lba) <= self.scan_threshold:
                return True
            self.scan_fill_denials += 1
            return False

    def admit_tier_fill(self, ns, lba: int) -> bool:
        """May this read-miss fill the clean read tier?  False once the
        access belongs to a sequential run longer than the threshold —
        giant scans bypass the tier instead of flushing the hot set.
        Pure (no detector update): safe to re-check at insert time."""
        if self.scan_threshold <= 0:
            return True
        with self._lock:
            if self._detector.current_run(ns, lba) <= self.scan_threshold:
                return True
            self.scan_fill_denials += 1
            return False

    def admit_key_fill(self, key) -> bool:
        """Tier-side hook for ``ReadTier.insert``: unpack the volume's
        ``(ns, lba)`` block keys; object-mode keys are always admitted
        (no address locality to detect scans on)."""
        if (isinstance(key, tuple) and len(key) == 2
                and isinstance(key[1], int)):
            return self.admit_tier_fill(key[0], key[1])
        return True

    # ------------------------------------------------------- QoS pricing
    def read_charge(self, nbytes: int, source: str) -> int:
        """Token-bucket debit for a read served from ``source``
        ('transit' | 'tier' | 'backend').  DRAM hits cost a fraction."""
        if source == "backend":
            return nbytes
        return int(nbytes * self.tier_hit_cost_frac)

    def write_charge(self, nbytes: int) -> int:
        return nbytes

    def op_charge(self, nbytes: int, op: str, tier: str | None = None,
                  shard=None) -> int:
        """Virtual-time price of one op for the tier-aware WFQ gate.

        Reads are priced like :meth:`read_charge` by their PROBED tier
        (``CaitiCache.probe``): a read the probe found DRAM-resident
        ('transit'/'tier') admits at the DRAM fraction; an untagged read
        (``tier=None`` — probe says it is headed for the backend) pays
        the full PMem price up front.  The probe can race the stack, so
        the volume settles one-sidedly post-service (``_debit_read``): a
        read that cost MORE than its tag charges the remainder via
        ``WFQGate.charge``; the rare cheaper-than-tagged read (a fill
        landed mid-flight) keeps its conservative price.  Writes
        (including batched ``log`` flushes) pay full byte price.

        ``shard=`` tags the op with its target device: an op headed for
        a limping shard is priced UP by the installed penalty
        multiplier (fail-slow steering — see module docstring)."""
        if op == "read":
            cost = self.read_charge(nbytes, tier or "backend")
        else:
            cost = self.write_charge(nbytes)
        if shard is not None and self._shard_penalty:
            with self._lock:
                pen = self._shard_penalty.get(shard, 1.0)
            if pen > 1.0:
                self.steered_charges += 1
                cost = int(cost * pen)
        return cost

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {"scan_fill_denials": self.scan_fill_denials,
                "scan_threshold": self.scan_threshold,
                "watermark_slots": self.watermark_slots,
                "tier_hit_cost_frac": self.tier_hit_cost_frac,
                "shard_penalties": dict(self._shard_penalty),
                "steered_charges": self.steered_charges}
