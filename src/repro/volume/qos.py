"""Per-tenant QoS for the striped volume: rate limits + weighted fairness.

Two cooperating mechanisms, both standard in block-layer QoS stacks
(blk-iocost / dm-qos lineage):

  * :class:`TokenBucket` — hard per-tenant throughput cap.  Tokens are
    bytes, refilled continuously at ``rate_bytes_s`` up to ``burst_bytes``;
    ``acquire`` blocks the submitting thread until the deficit drains.
  * :class:`WFQGate` — start-time fair queuing (SFQ) over a bounded
    in-flight window.  Each admitted request gets a virtual start tag
    ``S = max(V, F_tenant)`` and advances its tenant's finish tag by
    ``nbytes / weight``; the gate dispatches the waiter with the smallest
    start tag whenever an in-flight slot frees.  When the volume is the
    bottleneck, tenant throughput converges to the weight ratio.

Both are time-driven with ``time.monotonic`` — real-thread QoS for the
threaded volume.  The discrete-event simulator reimplements the same two
disciplines in virtual time (``repro.core.sim.run_volume_sim_workload``)
so the fairness claims are measurable deterministically.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from dataclasses import dataclass


class QoSError(RuntimeError):
    pass


@dataclass(frozen=True)
class TenantSpec:
    """Declarative tenant description for ``make_volume(tenants=[...])``."""

    name: str
    weight: float = 1.0              # WFQ share when the volume saturates
    rate_mbps: float = 0.0           # hard cap; 0 = unlimited
    burst_bytes: int = 4 << 20


class TokenBucket:
    """Continuous-refill token bucket (tokens are bytes)."""

    def __init__(self, rate_bytes_s: float, burst_bytes: int = 4 << 20,
                 clock=time.monotonic) -> None:
        assert rate_bytes_s > 0
        self.rate = float(rate_bytes_s)
        self.burst = float(burst_bytes)
        self._clock = clock
        self._tokens = self.burst
        self._last = clock()
        self._lock = threading.Lock()

    def _refill(self, now: float) -> None:
        self._tokens = min(self.burst,
                           self._tokens + (now - self._last) * self.rate)
        self._last = now

    def acquire(self, nbytes: int) -> float:
        """Block until ``nbytes`` tokens are available; returns wait seconds."""
        waited = 0.0
        while True:
            with self._lock:
                now = self._clock()
                self._refill(now)
                if self._tokens >= nbytes:
                    self._tokens -= nbytes
                    return waited
                need = (nbytes - self._tokens) / self.rate
            time.sleep(min(need, 0.05))
            waited += need

    def try_acquire(self, nbytes: int) -> bool:
        with self._lock:
            self._refill(self._clock())
            if self._tokens >= nbytes:
                self._tokens -= nbytes
                return True
            return False

    def charge(self, nbytes: int) -> None:
        """Non-blocking post-service debit: the balance may go negative
        and the debt settles at the next refill, so a cheap DRAM-served
        read is accounted for without ever sleeping on the PMem budget
        (blk-iocost-style debt).  Subsequent ``acquire`` calls wait the
        debt out."""
        with self._lock:
            self._refill(self._clock())
            self._tokens -= nbytes


class WFQGate:
    """Start-time fair queuing admission gate with a bounded window.

    ``admit(tenant, nbytes)`` blocks until the request is scheduled and an
    in-flight slot is free, then returns a ticket; ``done(ticket)`` frees
    the slot.  Weights are set per tenant via ``set_tenant``.
    """

    def __init__(self, max_inflight: int = 16) -> None:
        assert max_inflight >= 1
        self.max_inflight = max_inflight
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._weights: dict[str, float] = {}
        self._finish: dict[str, float] = {}   # per-tenant virtual finish tag
        self._vtime = 0.0                     # virtual time = last start tag
        self._inflight = 0
        self._waiting: list[tuple[float, int]] = []   # heap of (S, seq)
        self._seq = itertools.count()
        self.admitted_bytes: dict[str, int] = {}

    def set_tenant(self, name: str, weight: float = 1.0) -> None:
        with self._lock:
            assert weight > 0
            self._weights[name] = float(weight)
            self._finish.setdefault(name, 0.0)
            self.admitted_bytes.setdefault(name, 0)

    def admit(self, tenant: str, nbytes: int) -> tuple[float, int]:
        with self._cond:
            if tenant not in self._weights:
                raise QoSError(f"unknown tenant {tenant!r}")
            s_tag = max(self._vtime, self._finish[tenant])
            self._finish[tenant] = s_tag + nbytes / self._weights[tenant]
            seq = next(self._seq)
            heapq.heappush(self._waiting, (s_tag, seq))
            while not (self._inflight < self.max_inflight
                       and self._waiting and self._waiting[0][1] == seq):
                self._cond.wait(timeout=0.5)
            heapq.heappop(self._waiting)
            self._inflight += 1
            self._vtime = max(self._vtime, s_tag)
            self.admitted_bytes[tenant] += nbytes
            self._cond.notify_all()
            return (s_tag, seq)

    def done(self, ticket) -> None:
        with self._cond:
            self._inflight -= 1
            self._cond.notify_all()
