"""Per-tenant QoS for the striped volume: rate limits + weighted fairness.

Two cooperating mechanisms, both standard in block-layer QoS stacks
(blk-iocost / dm-qos lineage):

  * :class:`TokenBucket` — hard per-tenant throughput cap.  Tokens are
    bytes, refilled continuously at ``rate_bytes_s`` up to ``burst_bytes``;
    ``acquire`` blocks the submitting thread until the deficit drains.
  * :class:`WFQGate` — start-time fair queuing (SFQ) over a bounded
    in-flight window.  Each admitted request gets a virtual start tag
    ``S = max(V, F_tenant)`` and advances its tenant's finish tag by
    ``priced_bytes / weight``; the gate dispatches the waiter with the
    smallest start tag whenever an in-flight slot frees.  When the volume
    is the bottleneck, tenant *cost* throughput converges to the weight
    ratio.  Pricing is tier-aware (op/tier tags consulting the unified
    :class:`~repro.volume.admission.AdmissionPolicy`): a DRAM-served read
    costs ``tier_hit_cost_frac`` of a PMem one, and batched journal
    writes are charged once per batch (``charge_batch``) instead of once
    per ``log()`` call.

Both are time-driven with ``time.monotonic`` — real-thread QoS for the
threaded volume.  The discrete-event simulator reimplements the same two
disciplines in virtual time (``repro.core.sim.run_volume_sim_workload``)
so the fairness claims are measurable deterministically.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from dataclasses import dataclass


class QoSError(RuntimeError):
    pass


@dataclass(frozen=True)
class TenantSpec:
    """Declarative tenant description for ``make_volume(tenants=[...])``."""

    name: str
    weight: float = 1.0              # WFQ share when the volume saturates
    rate_mbps: float = 0.0           # hard cap; 0 = unlimited
    burst_bytes: int = 4 << 20


class TokenBucket:
    """Continuous-refill token bucket (tokens are bytes)."""

    def __init__(self, rate_bytes_s: float, burst_bytes: int = 4 << 20,
                 clock=time.monotonic) -> None:
        assert rate_bytes_s > 0
        self.rate = float(rate_bytes_s)
        self.burst = float(burst_bytes)
        self._clock = clock
        self._tokens = self.burst
        self._last = clock()
        self._lock = threading.Lock()

    def _refill(self, now: float) -> None:
        self._tokens = min(self.burst,
                           self._tokens + (now - self._last) * self.rate)
        self._last = now

    def acquire(self, nbytes: int) -> float:
        """Block until ``nbytes`` tokens are available; returns wait seconds."""
        waited = 0.0
        while True:
            with self._lock:
                now = self._clock()
                self._refill(now)
                if self._tokens >= nbytes:
                    self._tokens -= nbytes
                    return waited
                need = (nbytes - self._tokens) / self.rate
            time.sleep(min(need, 0.05))
            waited += need

    def try_acquire(self, nbytes: int) -> bool:
        with self._lock:
            self._refill(self._clock())
            if self._tokens >= nbytes:
                self._tokens -= nbytes
                return True
            return False

    def charge(self, nbytes: int) -> None:
        """Non-blocking post-service debit: the balance may go negative
        and the debt settles at the next refill, so a cheap DRAM-served
        read is accounted for without ever sleeping on the PMem budget
        (blk-iocost-style debt).  Subsequent ``acquire`` calls wait the
        debt out."""
        with self._lock:
            self._refill(self._clock())
            self._tokens -= nbytes


class WFQGate:
    """Tier-aware start-time fair queuing admission gate.

    ``admit(tenant, nbytes, op=, tier=)`` blocks until the request is
    scheduled and an in-flight slot is free, then returns a ticket;
    ``done(ticket)`` frees the slot.  Weights are set per tenant via
    ``set_tenant``.

    Virtual time is charged by *op cost*, not raw bytes: with a
    ``policy`` (:class:`~repro.volume.admission.AdmissionPolicy`)
    installed, a read tagged ``tier='transit'``/``'tier'`` — a DRAM copy,
    not a PMem round trip — advances its tenant's finish tag by only
    ``tier_hit_cost_frac`` of its size; an untagged read pays the full
    PMem price up front, and a read that served WORSE than its tag
    settles the remainder post-service via :meth:`charge` (the same debt
    model as ``TokenBucket.charge``).  Batched journal writes occupy a
    slot via ``admit(0, op='log')`` (ordering + inflight bounding, one
    clamped vbyte) and are charged their real bytes once per batch
    through :meth:`charge_batch` — one lock acquisition advances every
    constituent tenant's tag by its aggregate priced bytes.

    Zero-byte ops clamp to one byte: an admit that advanced no virtual
    time would hand its tenant an identical start tag for the *next*
    request, letting it leapfrog earlier waiters in the (S, seq) heap.
    """

    def __init__(self, max_inflight: int = 16, policy=None) -> None:
        assert max_inflight >= 1
        self.max_inflight = max_inflight
        self.policy = policy                  # optional AdmissionPolicy
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._weights: dict[str, float] = {}
        self._finish: dict[str, float] = {}   # per-tenant virtual finish tag
        self._vtime = 0.0                     # virtual time = last start tag
        self._inflight = 0
        self._waiting: list[tuple[float, int]] = []   # heap of (S, seq)
        self._seq = itertools.count()
        self.admitted_bytes: dict[str, int] = {}
        self.vtime_charged: dict[str, float] = {}   # priced bytes per tenant
        self.zero_byte_admits = 0
        self.post_charges = 0                 # charge()/charge_batch debits

    def set_tenant(self, name: str, weight: float = 1.0) -> None:
        with self._lock:
            assert weight > 0
            self._weights[name] = float(weight)
            self._finish.setdefault(name, 0.0)
            self.admitted_bytes.setdefault(name, 0)
            self.vtime_charged.setdefault(name, 0.0)

    def _price(self, nbytes: int, op: str, tier: str | None,
               shard=None) -> float:
        """Priced (virtual-time) bytes of one op.  Clamps ``nbytes >= 1``
        — a zero-byte op must still advance the finish tag (heap-order
        regression) — and never prices below one byte.  ``shard=`` tags
        the op's target device so the policy's limping-shard penalty
        multiplier applies (fail-slow steering)."""
        nbytes = max(1, int(nbytes))
        if self.policy is not None:
            return max(1.0, float(self.policy.op_charge(nbytes, op, tier,
                                                        shard=shard)))
        return float(nbytes)

    def _charge_locked(self, tenant: str, cost: float) -> None:
        base = max(self._vtime, self._finish[tenant])
        self._finish[tenant] = base + cost / self._weights[tenant]
        self.vtime_charged[tenant] += cost

    def admit(self, tenant: str, nbytes: int, op: str = "write",
              tier: str | None = None,
              shard=None) -> tuple[float, int]:
        with self._cond:
            if tenant not in self._weights:
                raise QoSError(f"unknown tenant {tenant!r}")
            if nbytes <= 0 and op != "log":
                # op='log' admits are INTENTIONALLY byte-free (the batch
                # charges the real bytes); anything else is the caller
                # bug the clamp exists for
                self.zero_byte_admits += 1
            cost = self._price(nbytes, op, tier, shard=shard)
            s_tag = max(self._vtime, self._finish[tenant])
            self._finish[tenant] = s_tag + cost / self._weights[tenant]
            self.vtime_charged[tenant] += cost
            seq = next(self._seq)
            heapq.heappush(self._waiting, (s_tag, seq))
            while not (self._inflight < self.max_inflight
                       and self._waiting and self._waiting[0][1] == seq):
                self._cond.wait(timeout=0.5)
            heapq.heappop(self._waiting)
            self._inflight += 1
            self._vtime = max(self._vtime, s_tag)
            self.admitted_bytes[tenant] += max(0, nbytes)
            self._cond.notify_all()
            return (s_tag, seq)

    def done(self, ticket) -> None:
        with self._cond:
            self._inflight -= 1
            self._cond.notify_all()

    def charge(self, tenant: str, nbytes: int, op: str = "write",
               tier: str | None = None) -> float:
        """Non-blocking post-service virtual-time debit (the WFQ analogue
        of ``TokenBucket.charge``): advances the tenant's finish tag
        without queueing or occupying a slot — the debt settles as the
        tenant's NEXT admits inherit the later tag.  The volume uses it
        to settle the PMem remainder of a read that was admitted at the
        optimistic DRAM price but missed every DRAM tier.  Returns the
        priced bytes."""
        with self._lock:
            if tenant not in self._weights:
                raise QoSError(f"unknown tenant {tenant!r}")
            cost = self._price(nbytes, op, tier)
            self._charge_locked(tenant, cost)
            self.post_charges += 1
            return cost

    def charge_batch(self, nbytes_by_tenant: dict,
                     op: str = "log") -> dict[str, float]:
        """Charge a batched log flush to its constituent tenants in ONE
        lock acquisition: each tenant's finish tag advances once by its
        aggregate priced bytes for the batch (instead of once per
        ``log()`` call).  Returns the priced bytes per tenant."""
        out: dict[str, float] = {}
        with self._lock:
            for tenant, nbytes in nbytes_by_tenant.items():
                if tenant not in self._weights:
                    raise QoSError(f"unknown tenant {tenant!r}")
                cost = self._price(nbytes, op, None)
                self._charge_locked(tenant, cost)
                out[tenant] = cost
            if nbytes_by_tenant:
                self.post_charges += 1
        return out

    def stats(self) -> dict:
        with self._lock:
            return {
                "vtime": self._vtime,
                "finish": dict(self._finish),
                "vtime_charged": {t: int(c)
                                  for t, c in self.vtime_charged.items()},
                "admitted_bytes": dict(self.admitted_bytes),
                "zero_byte_admits": self.zero_byte_admits,
                "post_charges": self.post_charges,
            }
