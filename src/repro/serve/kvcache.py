"""Paged KV cache — BTT + Caiti re-expressed for the HBM/host tier pair.

Mapping of the paper's structures:

  BTT map (lba -> pba)        -> per-sequence block table (logical page ->
                                 physical page in the HBM pool)
  BTT lanes / free blocks     -> the pool's free list (CAS-style pops)
  DRAM transit cache          -> the HBM pool itself is the *fast* tier;
                                 the host tier (int8-packed) is the slow one
  eager eviction              -> cold sequences' pages are packed
                                 (gather_quantize) to the host tier as soon
                                 as the sequence stops decoding
  conditional bypass          -> a page allocation against a full pool goes
                                 straight to the host tier (no stall evicting
                                 someone else's hot page on the decode path)
  fsync / PREFLUSH            -> ``barrier()``: complete all pending
                                 migrations (used before pool reshape)
  volume read tier            -> a small CLOCK cache of *dequantized* host
                                 pages (``repro.volume.ReadTier`` in object
                                 mode): hybrid attention re-reads the same
                                 cold pages every decode step, so the
                                 int8->f32 unpack is paid once per residency
                                 instead of once per step.  Clean data only
                                 (host pages are immutable while live), so
                                 invalidation is just page-in/release.
  durable tier                -> an optional :class:`~repro.serve.kvpager
                                 .KVPager` spills the host tier's overflow
                                 onto the async striped volume (chained
                                 write_multi records, content-hash dedup,
                                 decode-ahead linked-read prefetch) so
                                 session KV is bounded by the volume, not
                                 DRAM — the tier walk is HBM -> host
                                 (int8) -> volume, exactly the paper's
                                 transit-cache -> PMem descent.

The pool arrays live per layer: (P, page_size, Hkv, hd).  On TPU the decode
attention resolves the table inside the Pallas kernel; on the CPU container
the interpret-mode kernel (or the jnp ref) does the same resolution.

Concurrency contract: ``seq.table``, ``self._free``, the host tier and the
active flags are guarded by ``_tlock`` — public entry points take it,
``_locked`` helpers assume it (the eviction-pool workers' ``_evict_slot*``
hooks take the same lock, so a decode-thread ``append_token`` can never
interleave with a worker's page-out on the same free list).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core.metrics import Metrics
from repro.kernels import ref as kref
from repro.kernels.ops import (gather_quantize_crc, paged_attention,
                               scatter_dequantize_crc)
from repro.volume.read_tier import ReadTier


@dataclass
class PagedCacheConfig:
    n_layers: int
    n_kv_heads: int
    head_dim: int
    page_size: int = 16
    n_pages: int = 256            # HBM pool pages (per layer)
    host_pages: int = 1024        # host-tier page budget (spill target
                                  # when a KVPager is attached)
    max_pages_per_seq: int = 64
    dtype: object = jnp.bfloat16
    eager_eviction: bool = True
    conditional_bypass: bool = True
    read_tier_pages: int = 128    # dequantized-page cache (0 disables)


class HostTier:
    """The slow tier: int8-packed pages + scales + the wire checksum the
    fused transit kernel computed at spill time, keyed (layer, handle)."""

    def __init__(self) -> None:
        self.pages: dict[tuple[int, int],
                         tuple[np.ndarray, np.ndarray, int]] = {}
        self._next = 0

    def put(self, layer: int, q: np.ndarray, scale: np.ndarray,
            crc: int = 0) -> int:
        h = self._next
        self._next += 1
        self.pages[(layer, h)] = (q, scale, crc)
        return h

    def get(self, layer: int, handle: int):
        return self.pages[(layer, handle)]

    def pop(self, layer: int, handle: int):
        return self.pages.pop((layer, handle))

    def __len__(self) -> int:
        return len(self.pages)


@dataclass
class Sequence:
    seq_id: int
    length: int = 0
    # logical page -> ("hbm", phys_page) | ("host", [(k_handle, v_handle)
    # per layer]) | ("host-fresh", {"k","v" raw f32}) | ("vol", pager handle)
    table: list = field(default_factory=list)
    active: bool = True


class PagedKVCache:
    """Host-side manager + on-device pools for one model's KV state."""

    def __init__(self, cfg: PagedCacheConfig,
                 metrics: Metrics | None = None,
                 evict_pool=None, pager=None) -> None:
        self.cfg = cfg
        self.metrics = metrics or Metrics()
        # optional SharedEvictionPool: eager page-out DMA runs on the
        # volume's eviction cores instead of the decode thread (the
        # paper's per-device eviction threads, shared).  jnp pools are
        # immutable so workers gather from a consistent snapshot; table /
        # free-list / host-tier mutations serialize on _tlock.
        self._tlock = threading.Lock()
        self._evict_cv = threading.Condition(self._tlock)
        self._evict_pool = evict_pool
        self._inflight_evictions = 0
        if evict_pool is not None:
            evict_pool.register(self)
        # optional volume-backed spill tier: host pages past
        # ``cfg.host_pages`` descend to KVPager records (see kvpager.py)
        self.pager = pager
        if pager is not None and getattr(pager, "own_metrics", False):
            pager.metrics = self.metrics     # unify the kv_* counters
            pager.own_metrics = False
        L, P, pg, H, hd = (cfg.n_layers, cfg.n_pages, cfg.page_size,
                          cfg.n_kv_heads, cfg.head_dim)
        self.k_pool = [jnp.zeros((P, pg, H, hd), cfg.dtype) for _ in range(L)]
        self.v_pool = [jnp.zeros((P, pg, H, hd), cfg.dtype) for _ in range(L)]
        self._free: list[int] = list(range(P))          # global free set
        self.host = HostTier()
        # clean read tier over the host tier: caches dequantized pages for
        # the hybrid-attention slow path (object mode — slots hold arrays)
        self.read_tier = (ReadTier(block_size=None,
                                   n_slots=cfg.read_tier_pages,
                                   metrics=self.metrics)
                          if cfg.read_tier_pages > 0 else None)
        self.seqs: dict[int, Sequence] = {}
        self._next_seq = 0

    # ------------------------------------------------------------ allocation
    def free_pages(self) -> int:
        return len(self._free)

    def new_sequence(self) -> int:
        with self._tlock:
            sid = self._next_seq
            self._next_seq += 1
            self.seqs[sid] = Sequence(sid)
            return sid

    def _alloc_page(self) -> int | None:
        if self._free:
            return self._free.pop()                      # CAS-style pop
        return None

    def _evict_coldest_locked(self) -> bool:
        """Sync eviction (the staging fallback): pack the coldest inactive
        sequence's first HBM page to the host tier."""
        for seq in self.seqs.values():
            if seq.active:
                continue
            for li, entry in enumerate(seq.table):
                if entry[0] == "hbm":
                    self._page_out_locked(seq, li)
                    return True
        return False

    # -------------------------------------------------------------- write path
    def append_token(self, sid: int, k_token, v_token) -> None:
        """k/v_token: per-layer list of (Hkv, hd) arrays for ONE new token."""
        with self._tlock:
            seq = self.seqs[sid]
            pg = self.cfg.page_size
            off = seq.length % pg
            if off == 0:                                 # need a fresh page
                # max_pages_per_seq bounds the DENSE block table the fast
                # attention path builds — a longer sequence never gets an
                # HBM page (it would index past table_for's array)
                over = len(seq.table) >= self.cfg.max_pages_per_seq
                page = None if over else self._alloc_page()
                if page is None:
                    if over and not self.cfg.conditional_bypass:
                        raise MemoryError(
                            f"seq {sid} would grow to {len(seq.table) + 1} "
                            f"pages, past max_pages_per_seq="
                            f"{self.cfg.max_pages_per_seq}; raise the bound "
                            f"or enable conditional_bypass to let long "
                            f"sequences overflow to the host tier")
                    if self.cfg.conditional_bypass:
                        # pool full (or table full) -> host tier
                        self.metrics.bump("bypass_pages")
                        if over:
                            self.metrics.bump("long_seq_bypass")
                        seq.table.append(("host-fresh",
                                          self._host_fresh_page()))
                        self._maybe_spill_locked()
                    else:
                        with self.metrics.timer("cache_eviction_and_write"):
                            if not self._evict_coldest_locked():
                                raise MemoryError("KV pool exhausted")
                        self._maybe_spill_locked()
                        page = self._alloc_page()
                        seq.table.append(("hbm", page))
                else:
                    seq.table.append(("hbm", page))
            entry = seq.table[seq.length // pg]
            if entry[0] == "hbm":
                page = entry[1]
                for li in range(self.cfg.n_layers):
                    self.k_pool[li] = self.k_pool[li].at[page, off].set(
                        k_token[li].astype(self.cfg.dtype))
                    self.v_pool[li] = self.v_pool[li].at[page, off].set(
                        v_token[li].astype(self.cfg.dtype))
            else:                                        # host-resident page
                buf = entry[1]
                for li in range(self.cfg.n_layers):
                    buf["k"][li][off] = np.asarray(k_token[li], np.float32)
                    buf["v"][li][off] = np.asarray(v_token[li], np.float32)
            seq.length += 1

    def overwrite_token(self, sid: int, layer: int, kv) -> None:
        """Rewrite the LAST appended token's k/v for one layer (the decode
        loop appends at layer 0, then fills layers > 0 in place)."""
        with self._tlock:
            seq = self.seqs[sid]
            pgsz = self.cfg.page_size
            tpos = seq.length - 1
            entry = seq.table[tpos // pgsz]
            off = tpos % pgsz
            k_t, v_t = kv
            if entry[0] == "hbm":
                page = entry[1]
                self.k_pool[layer] = self.k_pool[layer].at[page, off].set(
                    k_t.astype(self.cfg.dtype))
                self.v_pool[layer] = self.v_pool[layer].at[page, off].set(
                    v_t.astype(self.cfg.dtype))
            else:
                entry[1]["k"][layer][off] = np.asarray(k_t, np.float32)
                entry[1]["v"][layer][off] = np.asarray(v_t, np.float32)

    def _host_fresh_page(self) -> dict:
        L, pg, H, hd = (self.cfg.n_layers, self.cfg.page_size,
                        self.cfg.n_kv_heads, self.cfg.head_dim)
        return {"k": np.zeros((L, pg, H, hd), np.float32),
                "v": np.zeros((L, pg, H, hd), np.float32)}

    # ----------------------------------------------------------- transit ops
    def _page_out_locked(self, seq: Sequence, logical: int) -> None:
        """Transit one HBM page to the host tier via the FUSED kernel:
        gather + int8 pack + wire checksum in one VMEM pass (the old
        path quantized, then walked the packed bytes again on the host
        for the checksum)."""
        kind, page = seq.table[logical]
        assert kind == "hbm"
        handles = []
        ids = jnp.array([page], jnp.int32)
        for li in range(self.cfg.n_layers):
            pool_k = self.k_pool[li].reshape(self.cfg.n_pages,
                                             self.cfg.page_size, -1)
            pool_v = self.v_pool[li].reshape(self.cfg.n_pages,
                                             self.cfg.page_size, -1)
            qk, sk, ck = gather_quantize_crc(pool_k, ids)
            qv, sv, cv = gather_quantize_crc(pool_v, ids)
            hk = self.host.put(li, np.asarray(qk[0]), np.asarray(sk[0]),
                               int(ck[0]))
            hv = self.host.put(li, np.asarray(qv[0]), np.asarray(sv[0]),
                               int(cv[0]))
            self.metrics.bump("fused_kernel_passes", 2)
            self.metrics.bump("fused_kernel_bytes", qk.nbytes + qv.nbytes)
            handles.append((hk, hv))
        seq.table[logical] = ("host", handles)
        self._free.append(page)
        self.metrics.bump("pages_out")

    # ------------------------------------------------------ volume spill tier
    def host_page_count(self) -> int:
        """Logical pages currently in the host tier (packed or fresh)."""
        return sum(1 for seq in self.seqs.values()
                   for e in seq.table if e[0] in ("host", "host-fresh"))

    def _pack_page(self, handles) -> bytes:
        """Serialize one packed host page (all layers) for the pager:
        per layer, the fused-kernel crcs then the int8 payloads + f32
        scales.  The pager wraps this in its own wire crc32; page-in
        re-verifies the int8 bytes against the embedded kernel crcs via
        ``scatter_dequantize_crc`` — integrity end to end."""
        parts = []
        for li, (hk, hv) in enumerate(handles):
            qk, sk, ck = self.host.get(li, hk)
            qv, sv, cv = self.host.get(li, hv)
            parts.append(np.uint32(ck).tobytes())
            parts.append(np.uint32(cv).tobytes())
            parts.append(np.ascontiguousarray(qk, np.int8).tobytes())
            parts.append(np.ascontiguousarray(sk, "<f4").tobytes())
            parts.append(np.ascontiguousarray(qv, np.int8).tobytes())
            parts.append(np.ascontiguousarray(sv, "<f4").tobytes())
        return b"".join(parts)

    def _unpack_page(self, raw: bytes) -> list:
        """Inverse of :meth:`_pack_page` — per-layer
        ``(qk, sk, ck, qv, sv, cv)`` tuples (arrays not yet in the host
        tier; the caller decides whether to install them)."""
        pg = self.cfg.page_size
        D = self.cfg.n_kv_heads * self.cfg.head_dim
        qn, sn = pg * D, pg * 4
        out = []
        off = 0
        for _li in range(self.cfg.n_layers):
            ck = int(np.frombuffer(raw[off:off + 4], np.uint32)[0])
            cv = int(np.frombuffer(raw[off + 4:off + 8], np.uint32)[0])
            off += 8
            qk = np.frombuffer(raw[off:off + qn], np.int8).reshape(pg, D)
            off += qn
            sk = np.frombuffer(raw[off:off + sn], "<f4").astype(np.float32)
            off += sn
            qv = np.frombuffer(raw[off:off + qn], np.int8).reshape(pg, D)
            off += qn
            sv = np.frombuffer(raw[off:off + sn], "<f4").astype(np.float32)
            off += sn
            out.append((qk, sk, ck, qv, sv, cv))
        return out

    def _maybe_spill_locked(self) -> None:
        """Descend host-tier overflow onto the volume: while the host
        holds more than ``cfg.host_pages`` logical pages, spill the
        oldest INACTIVE sequence's packed pages as pager records
        (content-hash dedup makes prefix-shared pages one record).
        Host-fresh pages (raw f32, still being written) never spill."""
        if self.pager is None:
            return
        while self.host_page_count() > self.cfg.host_pages:
            victim = None
            for seq in self.seqs.values():               # oldest sid first
                if seq.active:
                    continue
                for li, entry in enumerate(seq.table):
                    if entry[0] == "host":
                        victim = (seq, li, entry[1])
                        break
                if victim is not None:
                    break
            if victim is None:                           # all hot: tolerate
                return
            seq, li, handles = victim
            payload = self._pack_page(handles)
            handle = self.pager.spill(payload)
            for lj, (hk, hv) in enumerate(handles):
                if self.read_tier is not None:
                    self.read_tier.invalidate(("page", lj, hk, hv))
                self.host.pop(lj, hk)
                self.host.pop(lj, hv)
            seq.table[li] = ("vol", handle)

    def prefetch(self, sid: int) -> int:
        """Decode-ahead restore for a suspended sequence: issue linked
        async reads for its volume records so ``activate()`` finds the
        payloads already in flight.  Returns chains issued."""
        if self.pager is None:
            return 0
        with self._tlock:
            seq = self.seqs.get(sid)
            if seq is None:
                return 0
            handles = [e[1] for e in seq.table if e[0] == "vol"]
        if not handles:
            return 0
        return self.pager.prefetch(handles)

    def _page_in_locked(self, seq: Sequence, logical: int) -> bool:
        """Bring a cold page back into the pool (dequantize+scatter).

        A volume record is promoted to the host tier first (wire-crc
        verified in the pager), then the fused restore kernel re-verifies
        the int8 payload against the spill-time checksums.  On a checksum
        mismatch the allocated pool page goes back to the free list and
        the host entries stay put (nothing is popped until the whole
        page verified) — an IOError never leaks pool capacity."""
        kind, payload = seq.table[logical]
        if kind == "vol":
            raw = self.pager.fetch(payload)              # may raise IOError
            handles = []
            for li, (qk, sk, ck, qv, sv, cv) in \
                    enumerate(self._unpack_page(raw)):
                handles.append((self.host.put(li, qk, sk, ck),
                                self.host.put(li, qv, sv, cv)))
            self.pager.release(payload)
            seq.table[logical] = ("host", handles)
            kind, payload = "host", handles
        page = self._alloc_page()
        if page is None:
            return False
        pg, H, hd = self.cfg.page_size, self.cfg.n_kv_heads, self.cfg.head_dim
        if kind == "host":
            ids = jnp.array([page], jnp.int32)
            new_k, new_v = [], []
            try:
                for li, (hk, hv) in enumerate(payload):
                    qk, sk, ck = self.host.get(li, hk)
                    qv, sv, cv = self.host.get(li, hv)
                    pool_k = self.k_pool[li].reshape(self.cfg.n_pages, pg, -1)
                    pool_v = self.v_pool[li].reshape(self.cfg.n_pages, pg, -1)
                    # fused restore: dequantize+scatter AND checksum the int8
                    # payload as received, in the same pass — verified against
                    # the spill-time value before the page goes live
                    pool_k, rck = scatter_dequantize_crc(
                        pool_k, ids, jnp.asarray(qk)[None],
                        jnp.asarray(sk)[None])
                    pool_v, rcv = scatter_dequantize_crc(
                        pool_v, ids, jnp.asarray(qv)[None],
                        jnp.asarray(sv)[None])
                    self.metrics.bump("fused_kernel_passes", 2)
                    self.metrics.bump("fused_kernel_bytes",
                                      qk.nbytes + qv.nbytes)
                    if int(rck[0]) != ck or int(rcv[0]) != cv:
                        self.metrics.bump("transit_crc_errors")
                        raise IOError(
                            f"KV transit checksum mismatch: layer {li} page "
                            f"{logical} of seq {seq.seq_id} tore in transit")
                    new_k.append(pool_k.reshape(self.cfg.n_pages, pg, H, hd))
                    new_v.append(pool_v.reshape(self.cfg.n_pages, pg, H, hd))
            except IOError:
                self._free.append(page)                  # no capacity leak
                raise
            for li, (hk, hv) in enumerate(payload):      # verified: commit
                if self.read_tier is not None:
                    self.read_tier.invalidate(("page", li, hk, hv))
                self.host.pop(li, hk)
                self.host.pop(li, hv)
                self.k_pool[li] = new_k[li]
                self.v_pool[li] = new_v[li]
        else:                                            # host-fresh (raw f32)
            for li in range(self.cfg.n_layers):
                self.k_pool[li] = self.k_pool[li].at[page].set(
                    jnp.asarray(payload["k"][li], self.cfg.dtype))
                self.v_pool[li] = self.v_pool[li].at[page].set(
                    jnp.asarray(payload["v"][li], self.cfg.dtype))
        seq.table[logical] = ("hbm", page)
        self.metrics.bump("pages_in")
        return True

    def deactivate(self, sid: int) -> None:
        """Sequence paused/finished: eagerly transit its pages out.

        With an eviction pool attached, the page-out DMA (fused
        gather+quantize+checksum) is submitted to the volume's shared
        eviction cores instead of running on the decode thread.  The
        sync fallback runs the whole page-out loop under ``_tlock`` —
        a concurrent deactivate of the same sequence sees "host"
        entries and skips, instead of double-freeing pool pages."""
        items = []
        with self._tlock:
            seq = self.seqs[sid]
            seq.active = False
            if not self.cfg.eager_eviction:
                return
            if self._evict_pool is not None:
                for li, entry in enumerate(seq.table):
                    if entry[0] == "hbm":
                        self._inflight_evictions += 1
                        items.append((seq, li))
            else:
                for li, entry in enumerate(seq.table):
                    if entry[0] == "hbm":
                        self._page_out_locked(seq, li)
                self._maybe_spill_locked()
        for it in items:
            self._evict_pool.submit(self, it)

    # eviction-pool participant hooks (same contract as CaitiCache)
    def _evict_slot(self, item) -> None:
        seq, li = item
        with self._tlock:
            # a re-activated sequence cancels its pending page-outs
            if seq.active or seq.table[li][0] != "hbm":
                self.metrics.bump("evict_skipped")
                return
            self._page_out_locked(seq, li)
            self._maybe_spill_locked()

    def _evict_slots(self, items) -> None:
        """Batch drain hook: the pool hands several queued page-outs at
        once; one lock acquisition covers the whole batch."""
        self.metrics.bump("evict_batches")
        with self._tlock:
            for seq, li in items:
                if seq.active or seq.table[li][0] != "hbm":
                    self.metrics.bump("evict_skipped")
                    continue
                self._page_out_locked(seq, li)
            self._maybe_spill_locked()

    def _complete_eviction(self) -> None:
        with self._evict_cv:
            self._inflight_evictions -= 1
            self._evict_cv.notify_all()

    def drain_evictions(self, timeout: float = 10.0,
                        raise_on_timeout: bool = True) -> bool:
        """Barrier: wait until every submitted page-out has run (the
        pool-side analogue of ``barrier()``/PREFLUSH).  Returns True
        when the drain completed; on expiry raises TimeoutError (or
        returns False with ``raise_on_timeout=False``) — a silent
        timeout would let ``activate()`` read tables that page-out
        workers are still mutating."""
        with self._evict_cv:
            done = self._evict_cv.wait_for(
                lambda: self._inflight_evictions == 0, timeout=timeout)
            pending = self._inflight_evictions
        if not done and raise_on_timeout:
            raise TimeoutError(
                f"drain_evictions: {pending} page-outs still in flight "
                f"after {timeout}s")
        return done

    def activate(self, sid: int) -> None:
        """Resume a sequence: page everything back in (may bypass).

        Raises TimeoutError if the eviction barrier expires (page-outs
        still in flight — proceeding would race their table writes)."""
        if self._evict_pool is not None:
            self.drain_evictions()
        with self._tlock:
            seq = self.seqs[sid]
            seq.active = True
            for li, entry in enumerate(seq.table):
                if entry[0] in ("host", "host-fresh", "vol"):
                    if not self._page_in_locked(seq, li):
                        self.metrics.bump("activate_stalls")
                        return                            # partial: retry later

    def release(self, sid: int) -> None:
        with self._tlock:
            seq = self.seqs.pop(sid)
            for entry in seq.table:
                if entry[0] == "hbm":
                    self._free.append(entry[1])
                elif entry[0] == "host":
                    for li, (hk, hv) in enumerate(entry[1]):
                        if self.read_tier is not None:
                            self.read_tier.invalidate(("page", li, hk, hv))
                        self.host.pop(li, hk)
                        self.host.pop(li, hv)
                elif entry[0] == "vol":
                    if self.read_tier is not None:
                        for li in range(self.cfg.n_layers):
                            self.read_tier.invalidate(
                                ("vol-page", li, entry[1]))
                    self.pager.release(entry[1])

    # -------------------------------------------------------------- attention
    def table_for(self, sids: list[int]) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Dense (B, max_pages) physical table + (B,) lengths for attention.
        Sequences must be fully HBM-resident (activate() first)."""
        mp = self.cfg.max_pages_per_seq
        table = np.zeros((len(sids), mp), np.int32)
        lens = np.zeros((len(sids),), np.int32)
        with self._tlock:
            for bi, sid in enumerate(sids):
                seq = self.seqs[sid]
                if len(seq.table) > mp:
                    raise ValueError(
                        f"seq {sid} holds {len(seq.table)} pages > "
                        f"max_pages_per_seq={mp}: too long for the dense "
                        f"block table (serve it through the hybrid "
                        f"attention path)")
                lens[bi] = seq.length
                for li, entry in enumerate(seq.table):
                    assert entry[0] == "hbm", \
                        f"page {li} of seq {sid} not resident"
                    table[bi, li] = entry[1]
        return jnp.asarray(table), jnp.asarray(lens)

    def _page_kv(self, layer: int, entry) -> tuple[np.ndarray, np.ndarray]:
        """One logical page's (page_size, Hkv, hd) k/v from whichever tier
        holds it (the transit read path: cache hit OR backend read)."""
        pg, H, hd = self.cfg.page_size, self.cfg.n_kv_heads, self.cfg.head_dim
        if entry[0] == "hbm":
            return (np.asarray(self.k_pool[layer][entry[1]], np.float32),
                    np.asarray(self.v_pool[layer][entry[1]], np.float32))
        if entry[0] == "host":
            hk, hv = entry[1][layer]
            if self.read_tier is not None:
                cached = self.read_tier.lookup(("page", layer, hk, hv))
                if cached is not None:
                    return cached
            qk, sk, _ck = self.host.get(layer, hk)
            qv, sv, _cv = self.host.get(layer, hv)
            k = (qk.astype(np.float32) * sk[:, None]).reshape(pg, H, hd)
            v = (qv.astype(np.float32) * sv[:, None]).reshape(pg, H, hd)
            if self.read_tier is not None:
                self.read_tier.insert(("page", layer, hk, hv), (k, v))
            return k, v
        if entry[0] == "vol":
            # hybrid attention over a spilled page: restore the record
            # WITHOUT promoting it (the sequence stays cold); the read
            # tier amortizes the volume round trip across layers/steps
            handle = entry[1]
            if self.read_tier is not None:
                cached = self.read_tier.lookup(("vol-page", layer, handle))
                if cached is not None:
                    return cached
            raw = self.pager.fetch(handle)
            layers = self._unpack_page(raw)
            out = None
            for li, (qk, sk, _ck, qv, sv, _cv) in enumerate(layers):
                k = (qk.astype(np.float32) * sk[:, None]).reshape(pg, H, hd)
                v = (qv.astype(np.float32) * sv[:, None]).reshape(pg, H, hd)
                if self.read_tier is not None:
                    self.read_tier.insert(("vol-page", li, handle), (k, v))
                if li == layer:
                    out = (k, v)
            return out
        return (entry[1]["k"][layer].astype(np.float32),
                entry[1]["v"][layer].astype(np.float32))   # host-fresh

    def attention(self, layer: int, q, sids: list[int], *,
                  use_kernel: bool = True):
        """q: (B, H, hd) one decode step for the given sequences.

        Fast path: every page HBM-resident AND every table within the
        dense bound -> block-table kernel (lba->pba walk fused in).
        Slow path (pages bypassed to the host tier under pool pressure,
        or a sequence past max_pages_per_seq): materialize each
        sequence's KV from every tier — decode keeps running instead of
        stalling on page-in, the serving analogue of Caiti's conditional
        bypass."""
        mp = self.cfg.max_pages_per_seq
        resident = all(len(self.seqs[sid].table) <= mp
                       and all(e[0] == "hbm" for e in self.seqs[sid].table)
                       for sid in sids)
        if resident:
            table, lens = self.table_for(sids)
            if use_kernel:
                return paged_attention(q, self.k_pool[layer],
                                       self.v_pool[layer], table, lens)
            return kref.paged_attention_ref(q, self.k_pool[layer],
                                            self.v_pool[layer], table, lens)
        self.metrics.bump("hybrid_attention")
        pg, H, hd = self.cfg.page_size, self.cfg.n_kv_heads, self.cfg.head_dim
        B = len(sids)
        with self._tlock:
            S = max(len(self.seqs[s].table) for s in sids) * pg
            k = np.zeros((B, S, H, hd), np.float32)
            v = np.zeros((B, S, H, hd), np.float32)
            lens = np.zeros((B,), np.int32)
            for bi, sid in enumerate(sids):
                seq = self.seqs[sid]
                lens[bi] = seq.length
                for li, entry in enumerate(seq.table):
                    pk, pv = self._page_kv(layer, entry)
                    k[bi, li * pg:(li + 1) * pg] = pk
                    v[bi, li * pg:(li + 1) * pg] = pv
        # single-"page" ref attention over the materialized view
        kpool = jnp.asarray(k).reshape(B * 1, S, H, hd)
        vpool = jnp.asarray(v).reshape(B * 1, S, H, hd)
        table = jnp.arange(B, dtype=jnp.int32)[:, None]
        return kref.paged_attention_ref(q, kpool, vpool, table,
                                        jnp.asarray(lens))

    # ---------------------------------------------------------------- stats
    def occupancy(self) -> float:
        return 1.0 - len(self._free) / self.cfg.n_pages
